"""AOT lowering: jax → HLO *text* → ``artifacts/``.

HLO text (not ``HloModuleProto.serialize``) is the interchange format: the
sandbox's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction-id
protos; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts:
  artifacts/init.hlo.txt        (seed i32)                    -> params…
  artifacts/collate.hlo.txt     (flat [CAP] i32, off [B+1])   -> batch, mask
  artifacts/train_step.hlo.txt  (params…, batch, mask)        -> params…, loss
  artifacts/meta.json           shapes + arity for the rust runtime

Usage: python -m compile.aot --out ../artifacts [--d-model 128 ...]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, collate_fn, init, n_params, param_spec, train_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(cfg: ModelConfig, out_dir: str, token_capacity: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    spec = param_spec(cfg)
    param_structs = tuple(jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec)

    # ---- init ---------------------------------------------------------------
    init_lowered = jax.jit(lambda seed: init(cfg, seed)).lower(
        jax.ShapeDtypeStruct((), jnp.int32)
    )
    with open(os.path.join(out_dir, "init.hlo.txt"), "w") as f:
        f.write(to_hlo_text(init_lowered))

    # ---- collate -------------------------------------------------------------
    collate_lowered = jax.jit(lambda flat, off: collate_fn(cfg, flat, off)).lower(
        jax.ShapeDtypeStruct((token_capacity,), jnp.int32),
        jax.ShapeDtypeStruct((cfg.batch + 1,), jnp.int32),
    )
    with open(os.path.join(out_dir, "collate.hlo.txt"), "w") as f:
        f.write(to_hlo_text(collate_lowered))

    # ---- train step ------------------------------------------------------------
    step_lowered = jax.jit(
        lambda *args: train_step(cfg, args[:-2], args[-2], args[-1])
    ).lower(
        *param_structs,
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.float32),
    )
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(step_lowered))

    meta = {
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "lr": cfg.lr,
        "pad_id": cfg.pad_id,
        "token_capacity": token_capacity,
        "n_param_tensors": len(spec),
        "n_params": int(n_params(cfg)),
        "param_shapes": [list(s) for _, s in spec],
        "param_names": [n for n, _ in spec],
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)
    cfg = ModelConfig(
        vocab=args.vocab,
        d_model=args.d_model,
        n_heads=args.n_heads,
        n_layers=args.n_layers,
        seq_len=args.seq_len,
        batch=args.batch,
        lr=args.lr,
    )
    token_capacity = args.batch * args.seq_len * 2
    meta = lower_all(cfg, args.out, token_capacity)
    print(
        f"lowered model ({meta['n_params']:,} params, {meta['n_param_tensors']} tensors) "
        f"to {args.out}",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
