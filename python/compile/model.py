"""L2: the training-side consumer of GetBatch (§4 analog) — a decoder-only
transformer LM with a fused train step, written in JAX, calling the L1
Pallas attention kernel. Build-time only: ``aot.py`` lowers ``init``,
``collate_fn`` and ``train_step`` to HLO text once; the rust runtime
executes them via PJRT with no python on the training path.

Parameters travel as a flat list of arrays (stable order defined by
``param_spec``) so the rust side can thread outputs back into inputs
positionally without understanding the pytree.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.collate import collate


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256        # byte-level
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    seq_len: int = 128
    batch: int = 8
    lr: float = 3e-3
    pad_id: int = 0

    @property
    def d_head(self):
        return self.d_model // self.n_heads


def param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list — the flat-parameter ABI shared with rust."""
    d, v, t = cfg.d_model, cfg.vocab, cfg.seq_len
    spec = [("embed", (v, d)), ("pos", (t, d))]
    for l in range(cfg.n_layers):
        spec += [
            (f"l{l}.ln1_w", (d,)),
            (f"l{l}.ln1_b", (d,)),
            (f"l{l}.wqkv", (d, 3 * d)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.ln2_w", (d,)),
            (f"l{l}.ln2_b", (d,)),
            (f"l{l}.w1", (d, 4 * d)),
            (f"l{l}.b1", (4 * d,)),
            (f"l{l}.w2", (4 * d, d)),
            (f"l{l}.b2", (d,)),
        ]
    spec += [("lnf_w", (d,)), ("lnf_b", (d,)), ("head", (d, v))]
    return spec


def n_params(cfg: ModelConfig):
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def init(cfg: ModelConfig, seed):
    """Initialize the flat parameter list from an int32 seed (lowered to HLO
    so rust never computes initializers itself)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_b", ".b1", ".b2", "lnf_b")):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith(("ln1_w", "ln2_w", "lnf_w")):
            out.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            out.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            )
    return tuple(out)


def _layernorm(x, w, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * w + b


def forward(cfg: ModelConfig, params, tokens):
    """tokens: [B, T] int32 -> logits [B, T, V]."""
    it = iter(params)
    p = {name: next(it) for name, _ in param_spec(cfg)}
    b, t = tokens.shape
    x = p["embed"][tokens] + p["pos"][None, :t, :]
    for l in range(cfg.n_layers):
        h = _layernorm(x, p[f"l{l}.ln1_w"], p[f"l{l}.ln1_b"])
        qkv = h @ p[f"l{l}.wqkv"]                       # [B,T,3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        def heads(z):
            return z.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        o = attention(heads(q), heads(k), heads(v))     # L1 Pallas kernel
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        x = x + o @ p[f"l{l}.wo"]
        h = _layernorm(x, p[f"l{l}.ln2_w"], p[f"l{l}.ln2_b"])
        h = jax.nn.gelu(h @ p[f"l{l}.w1"] + p[f"l{l}.b1"])
        x = x + h @ p[f"l{l}.w2"] + p[f"l{l}.b2"]
    x = _layernorm(x, p["lnf_w"], p["lnf_b"])
    return x @ p["head"]


def loss_fn(cfg: ModelConfig, params, tokens, mask):
    """Next-token cross-entropy, masked by sample validity."""
    logits = forward(cfg, params, tokens)               # [B,T,V]
    tgt = tokens[:, 1:]                                 # predict t+1
    lg = logits[:, :-1, :]
    m = mask[:, 1:] * mask[:, :-1]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def train_step(cfg: ModelConfig, params, tokens, mask):
    """One fused SGD step: (params, batch) -> (new_params..., loss)."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens, mask))(params)
    new_params = tuple(p - cfg.lr * g for p, g in zip(params, grads))
    return new_params + (loss,)


def collate_fn(cfg: ModelConfig, flat_tokens, offsets):
    """The L1 collate kernel as its own lowerable graph:
    ([CAP] i32, [B+1] i32) -> ([B,T] i32, [B,T] f32)."""
    return collate(flat_tokens, offsets, cfg.seq_len, pad_id=cfg.pad_id)
