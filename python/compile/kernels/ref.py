"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package must match its ``*_ref`` twin to float
tolerance; pytest + hypothesis sweep shapes/dtypes in
``python/tests/test_kernels.py``.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v):
    """Causal scaled dot-product attention.

    q, k, v: [B, H, T, D] -> [B, H, T, D]
    """
    d = q.shape[-1]
    logits = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(d).astype(q.dtype)
    t = q.shape[2]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v)


def collate_ref(flat_tokens, offsets, seq_len, pad_id):
    """Gather variable-length samples into a padded [B, T] batch + mask.

    flat_tokens: [CAP] int32 — concatenated token streams of all samples
    offsets: [B+1] int32 — sample i occupies flat[offsets[i]:offsets[i+1]]
    Returns (batch [B, T] int32, mask [B, T] float32).
    """
    b = offsets.shape[0] - 1
    t = seq_len
    pos = jnp.arange(t, dtype=jnp.int32)

    def row(i):
        start = offsets[i]
        length = jnp.minimum(offsets[i + 1] - start, t)
        idx = jnp.clip(start + pos, 0, flat_tokens.shape[0] - 1)
        toks = flat_tokens[idx]
        valid = pos < length
        return jnp.where(valid, toks, pad_id), valid.astype(jnp.float32)

    rows = [row(i) for i in range(b)]
    batch = jnp.stack([r[0] for r in rows])
    mask = jnp.stack([r[1] for r in rows])
    return batch, mask
