"""L1 Pallas kernel: batch collation — the data-pipeline hot spot between
GetBatch's TAR stream and the model's dense tensors.

Samples arrive from the object store as variable-length token streams,
concatenated into one flat buffer with an offsets vector (built in rust,
zero-copy from the ordered batch). The kernel gathers each sample's window
into a padded [B, T] batch and emits the validity mask — one grid program
per row, so on TPU each program pulls exactly one sample's bytes HBM→VMEM
(BlockSpec over rows), the analogue of a threadblock-per-sample CUDA gather.

``interpret=True`` for CPU-PJRT executability (see attention.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _collate_kernel(pad_id, flat_ref, off_ref, batch_ref, mask_ref):
    """One program per batch row. flat/off are full-array refs; batch/mask
    refs are [1, T] row tiles."""
    i = pl.program_id(0)
    t = batch_ref.shape[1]
    start = off_ref[i]
    end = off_ref[i + 1]
    length = jnp.minimum(end - start, t)
    pos = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
    cap = flat_ref.shape[0]
    idx = jnp.clip(start + pos, 0, cap - 1)
    toks = flat_ref[idx]
    valid = pos < length
    batch_ref[0, :] = jnp.where(valid, toks, pad_id).astype(jnp.int32)
    mask_ref[0, :] = valid.astype(jnp.float32)


def collate(flat_tokens, offsets, seq_len, pad_id=0):
    """Gather + pad: ([CAP] i32, [B+1] i32) -> ([B,T] i32, [B,T] f32)."""
    b = offsets.shape[0] - 1
    t = seq_len
    row_spec = pl.BlockSpec((1, t), lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    kernel = lambda flat_ref, off_ref, batch_ref, mask_ref: _collate_kernel(
        pad_id, flat_ref, off_ref, batch_ref, mask_ref
    )
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[full(flat_tokens.shape), full(offsets.shape)],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, t), jnp.int32),
            jax.ShapeDtypeStruct((b, t), jnp.float32),
        ],
        interpret=True,
    )(flat_tokens, offsets)
