"""L1 Pallas kernel: fused causal attention.

TPU-structured (DESIGN.md §Hardware-Adaptation): the grid iterates over
(batch, head) so each program instance holds one [T, D] tile of Q/K/V in
VMEM and drives the MXU with two [T,T]x[T,D] matmuls fused with the softmax
— the HBM↔VMEM schedule a CUDA flash-attention kernel would express with
threadblocks is expressed here with BlockSpec. Lowered with
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic custom-calls,
so interpret mode is the correctness (and AOT) path; real-TPU efficiency is
estimated from the block shapes in DESIGN.md §Perf.

The kernel is wrapped in ``jax.custom_vjp`` (backward = the standard
attention gradient in plain jnp) so the L2 train step can differentiate
through it — plain ``pallas_call`` has no autodiff rule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    """One (batch, head) program: refs are [1, 1, T, D] VMEM tiles."""
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    t, d = q.shape
    logits = jnp.dot(q, k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    logits = jnp.where(col <= row, logits, NEG_INF)
    # Numerically stable softmax, fused with both matmuls in one program.
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v).astype(o_ref.dtype)


def _attention_fwd_pallas(q, k, v):
    b, h, t, d = q.shape
    spec = pl.BlockSpec((1, 1, t, d), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _attn_kernel,
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        interpret=True,
    )(q, k, v)


@jax.custom_vjp
def attention(q, k, v):
    """Fused causal attention: [B,H,T,D]^3 -> [B,H,T,D]."""
    return _attention_fwd_pallas(q, k, v)


def _fwd(q, k, v):
    return _attention_fwd_pallas(q, k, v), (q, k, v)


def _bwd(res, g):
    q, k, v = res
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    t = q.shape[2]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    dv = jnp.einsum("bhts,bhtd->bhsd", p, g)
    dp = jnp.einsum("bhtd,bhsd->bhts", g, v)
    # softmax backward
    dlogits = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dlogits = jnp.where(mask, dlogits, 0.0)
    dq = jnp.einsum("bhts,bhsd->bhtd", dlogits, k) * scale
    dk = jnp.einsum("bhts,bhtd->bhsd", dlogits, q) * scale
    return dq, dk, dv


attention.defvjp(_fwd, _bwd)


@functools.lru_cache(maxsize=None)
def vmem_footprint_bytes(t, d, dtype_bytes=4):
    """Per-program VMEM estimate for DESIGN.md §Perf: q,k,v,o tiles plus the
    [T,T] logits/probs scratch (×2 for exp + normalize temporaries)."""
    return 4 * t * d * dtype_bytes + 2 * t * t * dtype_bytes
