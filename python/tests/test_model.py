"""L2 correctness: model shapes, loss behavior, train-step convergence on a
tiny synthetic task, and the flat-parameter ABI the rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    ModelConfig,
    collate_fn,
    forward,
    init,
    loss_fn,
    n_params,
    param_spec,
    train_step,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2, seq_len=16, batch=4, lr=1e-2)


def test_param_spec_abi_stable():
    spec = param_spec(CFG)
    names = [n for n, _ in spec]
    assert names[0] == "embed" and names[1] == "pos"
    assert names[-1] == "head"
    assert len(names) == 2 + 10 * CFG.n_layers + 3
    # init produces exactly the spec'd shapes in order
    params = init(CFG, jnp.int32(0))
    assert len(params) == len(spec)
    for p, (_, s) in zip(params, spec):
        assert p.shape == s


def test_n_params_counts():
    assert n_params(CFG) == sum(int(np.prod(s)) for _, s in param_spec(CFG))


def test_forward_shapes_and_finite():
    params = init(CFG, jnp.int32(1))
    toks = jnp.zeros((CFG.batch, CFG.seq_len), jnp.int32)
    logits = forward(CFG, params, toks)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_masked_positions_ignored():
    params = init(CFG, jnp.int32(2))
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (CFG.batch, CFG.seq_len), 1, CFG.vocab)
    full = jnp.ones((CFG.batch, CFG.seq_len), jnp.float32)
    l_full = loss_fn(CFG, params, toks, full)
    # corrupt masked-out tail; loss must not change
    half = full.at[:, CFG.seq_len // 2 :].set(0.0)
    toks2 = toks.at[:, CFG.seq_len // 2 + 1 :].set(63)
    l_half_a = loss_fn(CFG, params, toks, half)
    l_half_b = loss_fn(CFG, params, toks2, half)
    np.testing.assert_allclose(float(l_half_a), float(l_half_b), rtol=1e-6)
    assert not np.isclose(float(l_full), float(l_half_a))


def test_train_step_decreases_loss_on_fixed_batch():
    params = init(CFG, jnp.int32(3))
    key = jax.random.PRNGKey(1)
    # a memorizable repeating pattern
    row = jax.random.randint(key, (1, CFG.seq_len), 1, CFG.vocab)
    toks = jnp.tile(row, (CFG.batch, 1))
    mask = jnp.ones_like(toks, jnp.float32)
    step = jax.jit(lambda *a: train_step(CFG, a[:-2], a[-2], a[-1]))
    l0 = float(loss_fn(CFG, params, toks, mask))
    for _ in range(30):
        out = step(*params, toks, mask)
        params, loss = out[:-1], out[-1]
    assert float(loss) < l0 * 0.5, f"{l0} -> {float(loss)}"


def test_train_step_output_arity():
    params = init(CFG, jnp.int32(4))
    toks = jnp.zeros((CFG.batch, CFG.seq_len), jnp.int32)
    mask = jnp.ones_like(toks, jnp.float32)
    out = train_step(CFG, params, toks, mask)
    assert len(out) == len(params) + 1
    assert out[-1].shape == ()


def test_collate_fn_feeds_train_step():
    params = init(CFG, jnp.int32(5))
    flat = jnp.asarray(np.random.RandomState(0).randint(1, CFG.vocab, 200), jnp.int32)
    offsets = jnp.asarray([0, 40, 90, 150, 200], jnp.int32)
    batch, mask = collate_fn(CFG, flat, offsets)
    assert batch.shape == (CFG.batch, CFG.seq_len)
    out = train_step(CFG, params, batch, mask)
    assert np.isfinite(float(out[-1]))
