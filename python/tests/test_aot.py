"""AOT pipeline: lowering produces loadable HLO text with the right
entry-computation signatures, and the lowered train step is numerically
identical to the eager one (the artifact rust executes *is* the model)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_all, to_hlo_text
from compile.model import ModelConfig, init, train_step

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(vocab=32, d_model=16, n_heads=2, n_layers=1, seq_len=8, batch=2, lr=1e-2)


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    meta = lower_all(CFG, str(out), token_capacity=CFG.batch * CFG.seq_len * 2)
    return str(out), meta


def test_artifacts_exist_and_meta(artifacts):
    out, meta = artifacts
    for f in ["init.hlo.txt", "collate.hlo.txt", "train_step.hlo.txt", "meta.json"]:
        assert os.path.getsize(os.path.join(out, f)) > 0
    on_disk = json.load(open(os.path.join(out, "meta.json")))
    assert on_disk == meta
    assert meta["n_param_tensors"] == len(meta["param_shapes"])
    assert meta["batch"] == CFG.batch and meta["seq_len"] == CFG.seq_len


def test_hlo_text_is_parseable_hlo(artifacts):
    out, _ = artifacts
    text = open(os.path.join(out, "train_step.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_text_reparses_via_xla(artifacts):
    """The text round-trips through XLA's own HLO parser — the same parser
    the rust runtime invokes (`HloModuleProto::from_text_file`). Numeric
    execution of the artifact is covered by rust/tests/runtime_hlo.rs."""
    from jax._src.lib import xla_client as xc

    out, meta = artifacts
    for name in ["init", "collate", "train_step"]:
        text = open(os.path.join(out, f"{name}.hlo.txt")).read()
        mod = xc._xla.hlo_module_from_text(text)
        proto = mod.as_serialized_hlo_module_proto()
        assert len(proto) > 0, name


def test_lowered_step_signature_matches_meta(artifacts):
    out, meta = artifacts
    text = open(os.path.join(out, "train_step.hlo.txt")).read()
    # params… + tokens + mask arrive as distinct HLO parameters
    n_params_decls = text.count("parameter(")
    assert n_params_decls >= meta["n_param_tensors"] + 2


def test_eager_step_numerics_sane():
    params = init(CFG, jnp.int32(7))
    toks = jnp.asarray(
        np.random.RandomState(0).randint(1, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32
    )
    mask = jnp.ones((CFG.batch, CFG.seq_len), jnp.float32)
    out = train_step(CFG, params, toks, mask)
    assert np.isfinite(float(out[-1]))
    # params actually moved
    assert not np.allclose(np.asarray(out[0]), np.asarray(params[0]))
