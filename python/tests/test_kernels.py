"""L1 correctness: Pallas kernels vs pure-jnp oracles, with hypothesis
sweeping shapes/dtypes — the core correctness signal for the kernels that
end up inside the AOT artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, vmem_footprint_bytes
from compile.kernels.collate import collate
from compile.kernels.ref import attention_ref, collate_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.sampled_from([1, 2, 8, 17, 32]),
    d=st.sampled_from([4, 8, 16]),
)
def test_attention_matches_ref(b, h, t, d):
    q, k, v = (rand(i, (b, h, t, d)) for i in range(3))
    np.testing.assert_allclose(
        np.asarray(attention(q, k, v)), np.asarray(attention_ref(q, k, v)),
        rtol=1e-5, atol=1e-5,
    )


def test_attention_causality():
    # Future positions must not influence earlier outputs.
    q, k, v = (rand(i, (1, 1, 16, 8)) for i in range(3))
    o1 = attention(q, k, v)
    k2 = k.at[:, :, 10:, :].set(99.0)
    v2 = v.at[:, :, 10:, :].set(-99.0)
    o2 = attention(q, k2, v2)
    np.testing.assert_allclose(np.asarray(o1[:, :, :10]), np.asarray(o2[:, :, :10]), rtol=1e-5)


def test_attention_grads_match_ref():
    q, k, v = (rand(i, (2, 2, 12, 8)) for i in range(3))

    def f(fn):
        return jax.grad(lambda q, k, v: jnp.sum(jnp.tanh(fn(q, k, v))), argnums=(0, 1, 2))(q, k, v)

    for a, b in zip(f(attention), f(attention_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_attention_bf16():
    q, k, v = (rand(i, (1, 2, 8, 8), jnp.bfloat16) for i in range(3))
    o = attention(q, k, v)
    r = attention_ref(q, k, v)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32), rtol=5e-2, atol=5e-2
    )


def test_attention_under_jit_and_vmem_budget():
    q, k, v = (rand(i, (2, 4, 32, 16)) for i in range(3))
    o = jax.jit(attention)(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(attention_ref(q, k, v)), rtol=1e-5, atol=1e-5)
    # VMEM估 per program must fit the ~16 MiB TPU budget for production shapes.
    assert vmem_footprint_bytes(2048, 128) < 48 * (1 << 20)
    assert vmem_footprint_bytes(128, 64) < (1 << 20)


# ------------------------------------------------------------------ collate

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 6),
    t=st.sampled_from([4, 16, 33]),
    seed=st.integers(0, 2**31 - 1),
)
def test_collate_matches_ref(b, t, seed):
    rng = np.random.RandomState(seed)
    lens = rng.randint(0, 2 * t, size=b)
    cap = max(int(lens.sum()), 1) + rng.randint(0, 8)
    flat = jnp.asarray(rng.randint(1, 250, size=cap), jnp.int32)
    offsets = jnp.asarray(np.concatenate([[0], np.cumsum(lens)]), jnp.int32)
    got_b, got_m = collate(flat, offsets, t, pad_id=0)
    ref_b, ref_m = collate_ref(flat, offsets, t, 0)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(ref_b))
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))


def test_collate_empty_and_overlong_rows():
    flat = jnp.arange(1, 51, dtype=jnp.int32)
    offsets = jnp.asarray([0, 0, 50, 50], jnp.int32)  # empty, overlong, empty
    b, m = collate(flat, offsets, 8, pad_id=-7)
    assert np.all(np.asarray(b[0]) == -7) and np.all(np.asarray(m[0]) == 0)
    np.testing.assert_array_equal(np.asarray(b[1]), np.arange(1, 9))
    assert np.all(np.asarray(m[1]) == 1)  # truncated to T, all valid
    assert np.all(np.asarray(m[2]) == 0)


def test_collate_under_jit():
    flat = jnp.arange(100, dtype=jnp.int32)
    offsets = jnp.asarray([0, 30, 60, 100], jnp.int32)
    f = jax.jit(lambda fl, of: collate(fl, of, 32, pad_id=0))
    b, m = f(flat, offsets)
    rb, rm = collate_ref(flat, offsets, 32, 0)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(m), np.asarray(rm))


def test_collate_mask_counts_tokens():
    flat = jnp.ones(64, jnp.int32)
    offsets = jnp.asarray([0, 10, 25, 64], jnp.int32)
    _, m = collate(flat, offsets, 128, pad_id=0)
    assert np.asarray(m).sum() == 64  # every real token visible once
