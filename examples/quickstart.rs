//! Quickstart: boot an in-process cluster, PUT objects and a TAR shard,
//! fetch a mixed batch with one GetBatch call, and print what came back —
//! the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use getbatch::util::error as anyhow;
use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::sdk::Client;
use getbatch::cluster::node::Cluster;
use getbatch::config::ClusterConfig;
use getbatch::tar::{write_archive, Entry};

fn main() -> anyhow::Result<()> {
    // 1. A 4-target, 1-proxy cluster on localhost (real TCP, temp-dir stores).
    let cluster = Cluster::start(ClusterConfig { targets: 4, ..Default::default() })?;
    let client = Client::new(&cluster.proxy_addr());
    println!("cluster up: proxy {}", cluster.proxy_addr());

    // 2. PUT some standalone objects (routed to HRW owners via the proxy).
    for i in 0..8 {
        client.put("images", &format!("img-{i}.jpg"), format!("<jpeg bytes {i}>").as_bytes())?;
    }
    // ...and a TAR shard of audio samples.
    let shard = write_archive(&[
        Entry { name: "utt-0001.wav".into(), data: vec![1; 2048] },
        Entry { name: "utt-0002.wav".into(), data: vec![2; 3072] },
    ])?;
    client.put("audio", "shard-000000.tar", &shard)?;

    // 3. One GetBatch spanning buckets, shard members and a missing entry.
    let req = BatchRequest::new(vec![
        BatchEntry::obj("images", "img-3.jpg"),
        BatchEntry::member("audio", "shard-000000.tar", "utt-0002.wav"),
        BatchEntry::obj("images", "img-0.jpg"),
        BatchEntry::obj("images", "img-does-not-exist.jpg"), // placeholder w/ coer
    ])
    .continue_on_err(true);

    let (items, stats) = client.get_batch_timed(&req)?;

    // 4. Results arrive in exact request order.
    for (i, item) in items.iter().enumerate() {
        match item.data() {
            Some(d) => println!("  [{i}] {:<40} {} bytes", item.name(), d.len()),
            None => println!("  [{i}] {:<40} MISSING (placeholder)", item.name()),
        }
    }
    println!(
        "one request, {} items, {} bytes, {:.1} ms (ttfb {:.1} ms)",
        stats.items,
        stats.bytes,
        stats.total.as_secs_f64() * 1e3,
        stats.ttfb.as_secs_f64() * 1e3
    );
    Ok(())
}
