//! END-TO-END VALIDATION (DESIGN.md E2E): train the AOT-compiled
//! transformer LM on a synthetic corpus stored in the live cluster, once
//! per data-access method, proving all three layers compose:
//!
//!   L3 rust cluster (GetBatch) → collate HLO (L1 Pallas kernel inside) →
//!   train-step HLO (L2 JAX fwd/bwd with the L1 attention kernel) via PJRT.
//!
//! Prerequisite: `make artifacts`. Run:
//!     cargo run --release --example train_e2e [-- --steps 200]
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use getbatch::util::error as anyhow;
use getbatch::client::loader::{AccessMode, DataLoader};
use getbatch::client::sdk::Client;
use getbatch::runtime::pjrt::Runtime;
use getbatch::runtime::trainer::{artifacts_dir, final_loss, train};
use getbatch::testutil::fixtures;
use getbatch::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 200);

    let rt = Runtime::load(&artifacts_dir()?)?;
    println!(
        "model: {} params ({} tensors), batch {}, seq {}, platform {}",
        rt.meta.n_params,
        rt.meta.n_param_tensors,
        rt.meta.batch,
        rt.meta.seq_len,
        rt.platform()
    );

    // Synthetic byte-level corpus: structured text so the LM has signal.
    let cluster = fixtures::cluster(4);
    let mut manifest = getbatch::client::loader::Manifest::default();
    {
        use getbatch::tar::{write_archive, Entry};
        let phrases = ["the quick brown fox ", "jumps over the lazy dog ", "pack my box ", "with five dozen jugs "];
        let mut rng = getbatch::util::rng::Rng::new(17);
        for s in 0..12 {
            let entries: Vec<Entry> = (0..32)
                .map(|i| {
                    let mut text = String::new();
                    while text.len() < 64 + rng.usize_below(128) {
                        text.push_str(phrases[rng.usize_below(phrases.len())]);
                    }
                    Entry { name: format!("doc-{s:03}-{i:03}.txt"), data: text.into_bytes() }
                })
                .collect();
            let shard = format!("shards/s-{s:05}.tar");
            cluster.put_direct("corpus", &shard, &write_archive(&entries)?)?;
            for e in &entries {
                manifest.samples.push(getbatch::client::loader::SampleRef {
                    bucket: "corpus".into(),
                    shard: Some(shard.clone()),
                    name: e.name.clone(),
                    size: e.data.len() as u64,
                });
            }
        }
    }
    println!("corpus: {} docs in 12 shards\n", manifest.len());

    for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
        let mut loader =
            DataLoader::new(Client::new(&cluster.proxy_addr()), manifest.clone(), mode, rt.meta.batch, 5);
        let report = train(&rt, &mut loader, steps, 0)?;
        let first = report.losses.first().copied().unwrap_or(f32::NAN);
        let last = final_loss(&report.losses, 20);
        println!("{:<16} loss {first:.3} -> {last:.3} over {steps} steps ({:.1}s)", report.mode, report.total_secs);
        println!("                 data-load  {}", report.load_ms);
        println!("                 train-step {}", report.step_ms);
        // loss curve (every steps/10)
        let stride = (steps / 10).max(1);
        let curve: Vec<String> = report
            .losses
            .iter()
            .step_by(stride)
            .map(|l| format!("{l:.2}"))
            .collect();
        println!("                 curve: {}\n", curve.join(" "));
        anyhow::ensure!(last < first, "{mode:?}: loss should decrease");
    }
    println!("all three layers compose: cluster fetch -> Pallas collate -> JAX train step (PJRT)");
    Ok(())
}
