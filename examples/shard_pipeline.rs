//! Shard-centric data pipeline: stage a sharded "speech" dataset, then show
//! the three access patterns of §4.1 side by side on the same manifest —
//! sequential shard reads, per-sample random GETs, and GetBatch — printing
//! per-batch latency and the requests each method issued.
//!
//!     cargo run --release --example shard_pipeline

use getbatch::util::error as anyhow;
use getbatch::client::loader::{AccessMode, DataLoader};
use getbatch::client::sdk::Client;
use getbatch::metrics::GetBatchMetrics;
use getbatch::testutil::fixtures;

fn main() -> anyhow::Result<()> {
    let cluster = fixtures::cluster(4);
    println!("staging 16 shards x 64 samples (log-normal sizes, median 8KiB)...");
    let manifest = fixtures::stage_shards(&cluster, "speech", 16, 64, 8192.0, 7);
    println!("manifest: {} samples in {} shards\n", manifest.len(), manifest.shards().len());

    for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
        let client = Client::new(&cluster.proxy_addr());
        let mut dl = DataLoader::new(client.clone(), manifest.clone(), mode, 32, 99);
        let dt_before: u64 = cluster.targets.iter().map(|t| t.metrics.dt_requests.get()).sum();
        let mut total_ms = 0.0;
        let mut samples = 0usize;
        for _ in 0..6 {
            let (batch, timing) = dl.next_batch()?;
            samples += batch.len();
            total_ms += timing.batch.as_secs_f64() * 1e3;
        }
        let dt_after: u64 = cluster.targets.iter().map(|t| t.metrics.dt_requests.get()).sum();
        println!(
            "{:<16} 6 batches, {samples} samples, {total_ms:.1} ms total, {} GetBatch executions",
            mode.name(),
            dt_after - dt_before
        );
    }

    // workload composition from the metrics (§2.4.4)
    let mut members = 0.0;
    for t in &cluster.targets {
        let m = GetBatchMetrics::parse(&t.metrics.render(&t.info.id));
        members += m["ais_getbatch_members_extracted_total"];
    }
    println!("\nshard extractions recorded by metrics: {members}");
    Ok(())
}
