//! The §4.2 latency study on the live cluster: N loader workers per access
//! method, percentile report in the paper's Table-2 format, plus the
//! P99-P50 spread analysis of §4.2.2. (The paper-scale version with 256
//! loaders runs in the simulator: `cargo bench --bench table2`.)
//!
//!     cargo run --release --example latency_study [-- --loaders 8 --steps 15]

use getbatch::util::error as anyhow;
use getbatch::client::loader::{AccessMode, DataLoader};
use getbatch::client::sdk::Client;
use getbatch::testutil::fixtures;
use getbatch::util::cli::Args;
use getbatch::util::stats::Samples;
use getbatch::util::threadpool::scoped_map;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let loaders = args.usize_or("loaders", 8);
    let steps = args.usize_or("steps", 15);
    let batch = args.usize_or("batch", 32);

    let cluster = fixtures::cluster(4);
    let manifest = fixtures::stage_shards(&cluster, "speech", 16, 64, 8192.0, 3);
    println!(
        "{} loaders x {} steps, batch {}, {} samples staged\n",
        loaders, steps, batch, manifest.len()
    );
    println!("{:<16} {:>44}  {:>44}", "method", "batch ms (P50/P95/P99/Avg)", "per-object ms (P50/P95/P99/Avg)");

    let mut rows = Vec::new();
    for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
        let per: Vec<(Samples, Samples)> =
            scoped_map(&(0..loaders as u64).collect::<Vec<_>>(), loaders, |_, &w| {
                let mut dl = DataLoader::new(
                    Client::new(&cluster.proxy_addr()),
                    manifest.clone(),
                    mode,
                    batch,
                    w * 31 + 5,
                );
                let mut bs = Samples::new();
                let mut os = Samples::new();
                for _ in 0..steps {
                    if let Ok((_, t)) = dl.next_batch() {
                        bs.add(t.batch.as_secs_f64() * 1e3);
                        for d in t.per_object {
                            os.add(d.as_secs_f64() * 1e3);
                        }
                    }
                }
                (bs, os)
            });
        let mut bs = Samples::new();
        let mut os = Samples::new();
        for (b, o) in per {
            bs.merge(&b);
            os.merge(&o);
        }
        let brow = bs.row();
        println!(
            "{:<16} {:>10.1}/{:>10.1}/{:>10.1}/{:>9.1}  {:>10.2}/{:>10.2}/{:>10.2}/{:>9.2}",
            mode.name(),
            brow.p50, brow.p95, brow.p99, brow.avg,
            os.row().p50, os.row().p95, os.row().p99, os.row().avg,
        );
        rows.push((mode, brow));
    }
    let get = rows.iter().find(|(m, _)| *m == AccessMode::RandomGet).unwrap().1;
    let gb = rows.iter().find(|(m, _)| *m == AccessMode::GetBatch).unwrap().1;
    println!("\n§4.2.2 spread (P99-P50): GET {:.1} ms vs GetBatch {:.1} ms ({:.0}% reduction)",
             get.spread(), gb.spread(), (1.0 - gb.spread() / get.spread()) * 100.0);
    Ok(())
}
