#!/usr/bin/env bash
# Record the `hotpath` bench series per the EXPERIMENTS.md protocol:
# capture the machine fingerprint, run the series 3x release-mode, take
# per-scenario medians, fill BENCH_hotpath.json, and print the dated
# results block to append to EXPERIMENTS.md.
#
# Run on the pinned baseline machine (needs a Rust toolchain + python3):
#   scripts/record_hotpath.sh [extra cargo-bench flags...]
set -euo pipefail

cd "$(dirname "$0")/.."

command -v cargo >/dev/null || {
    echo "error: cargo not found — recording needs a Rust toolchain" >&2
    exit 1
}
command -v python3 >/dev/null || {
    echo "error: python3 not found (the median/JSON step needs it)" >&2
    exit 1
}

RUNS=3
OUT_DIR="$(mktemp -d)"
trap 'rm -rf "$OUT_DIR"' EXIT

echo "== machine fingerprint =="
CPU="$(lscpu 2>/dev/null | awk -F: '/Model name/ {gsub(/^ +/,"",$2); print $2; exit}')"
NCPU="$(nproc 2>/dev/null || echo '?')"
MEM_GIB="$(free -g 2>/dev/null | awk '/^Mem:/ {print $2}')"
KERNEL="$(uname -r)"
RUSTC="$(rustc --version)"
COMMIT="$(git rev-parse --short HEAD 2>/dev/null || echo 'unknown')"
DATE="$(date +%Y-%m-%d)"
LABEL="${BENCH_MACHINE_LABEL:-$(hostname)}"
printf 'machine:   %s\ncpu:       %s, %s cores\nmemory:    %s GiB\nkernel:    %s\nrustc:     %s\ndate:      %s\ncommit:    %s\n' \
    "$LABEL" "${CPU:-unknown}" "$NCPU" "${MEM_GIB:-?}" "$KERNEL" "$RUSTC" "$DATE" "$COMMIT"
echo

for i in $(seq 1 "$RUNS"); do
    echo "== run $i/$RUNS =="
    cargo bench --bench hotpath -- "$@" | tee "$OUT_DIR/run$i.txt"
done

python3 - "$OUT_DIR" "$RUNS" "$LABEL" "$CPU, $NCPU cores" "${MEM_GIB:-0}" \
    "$KERNEL" "$RUSTC" "$DATE" "$COMMIT" <<'PY'
import json, re, statistics, sys

out_dir, runs = sys.argv[1], int(sys.argv[2])
label, cpu, mem, kernel, rustc, date, commit = sys.argv[3:10]

UNIT_NS = {"ns": 1.0, "µs": 1e3, "us": 1e3, "ms": 1e6, "s": 1e9}
line_re = re.compile(r"^(.*?)\s+([0-9.]+)(ns|µs|us|ms|s)/iter\s+\(\d+ iters\)$")

with open("BENCH_hotpath.json") as f:
    manifest = json.load(f)
names = [s["name"] for s in manifest["scenarios"]]

per_run = []  # run -> {name: ns}
for i in range(1, runs + 1):
    got = {}
    with open(f"{out_dir}/run{i}.txt") as f:
        for line in f:
            m = line_re.match(line.rstrip())
            if m and m.group(1).rstrip() in names:
                got[m.group(1).rstrip()] = float(m.group(2)) * UNIT_NS[m.group(3)]
    missing = [n for n in names if n not in got]
    if missing:
        sys.exit(f"run {i} is missing scenarios {missing} — "
                 "bench output and BENCH_hotpath.json have drifted")
    per_run.append(got)

print("\n== medians (ns/iter) ==")
for s in manifest["scenarios"]:
    vals = [r[s["name"]] for r in per_run]
    med = statistics.median(vals)
    s["value"] = round(med, 1)
    spread = (max(vals) - min(vals)) / med if med else 0.0
    flag = "   ** deviation > 10% — rerun or annotate **" if spread > 0.10 else ""
    print(f'{s["name"]:<44} {med:>14.1f}{flag}')

manifest["machine"] = {
    "label": label, "cpu": cpu, "memory_gib": int(mem) if mem.isdigit() else None,
    "disk": manifest["machine"].get("disk"), "kernel": kernel, "rustc": rustc,
    "isolation": manifest["machine"].get("isolation"),
}
manifest["date"], manifest["commit"] = date, commit
with open("BENCH_hotpath.json", "w") as f:
    json.dump(manifest, f, indent=2)
    f.write("\n")
print("\nBENCH_hotpath.json updated. Append the fingerprint above and the"
      "\nverbatim run outputs to the Results section of EXPERIMENTS.md.")
PY
