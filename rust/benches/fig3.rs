//! Figure 3 reproduction: throughput scaling series (CSV) — one series per
//! object size over {GET, Batch 32, 64, 128}, both SIM and LIVE.
//!
//! Output is CSV so the figure can be re-plotted directly:
//!   config,object_size,mode,batch,gib_per_sec,speedup

use std::time::Duration;

use getbatch::aisloader::{self, LoadSpec};
use getbatch::config::GetBatchConfig;
use getbatch::sim::model::CostModel;
use getbatch::sim::workload::run_synthetic;
use getbatch::testutil::fixtures;
use getbatch::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    println!("config,object_size,mode,batch,gib_per_sec,speedup");
    let sizes: [u64; 3] = [10 << 10, 100 << 10, 1 << 20];
    let batches = [32usize, 64, 128];

    let m = CostModel::oci_16node();
    let secs = args.f64_or("sim-secs", 4.0);
    for &size in &sizes {
        let get = run_synthetic(&m, 80, size, None, secs, size);
        let g = get.throughput.gib_per_sec();
        println!("sim,{size},get,1,{g:.3},1.0");
        for &k in &batches {
            let r = run_synthetic(&m, 80, size, Some(k), secs, size + k as u64);
            let t = r.throughput.gib_per_sec();
            println!("sim,{size},getbatch,{k},{t:.3},{:.2}", t / g);
        }
    }

    if args.bool("no-live") {
        return;
    }
    let workers = args.usize_or("live-workers", 8);
    let ms = args.u64_or("live-ms", 1200);
    for &size in &sizes {
        let c = fixtures::cluster(4);
        let base = LoadSpec {
            object_size: size,
            workers,
            duration: Duration::from_millis(ms),
            num_objects: if size >= 1 << 20 { 128 } else { 512 },
            ..Default::default()
        };
        aisloader::stage_uniform(&c, "bench", &base);
        let get = aisloader::run(&c, "bench", &base);
        let g = get.throughput.gib_per_sec();
        println!("live,{size},get,1,{g:.3},1.0");
        for &k in &batches {
            let r = aisloader::run(&c, "bench", &LoadSpec { batch: Some(k), ..base.clone() });
            let t = r.throughput.gib_per_sec();
            println!("live,{size},getbatch,{k},{t:.3},{:.2}", t / g);
        }
    }

    // Memory-capped large-object series: 1 MiB objects streamed through a
    // DT budget of 512 KiB — the regime where chunked streaming + real
    // backpressure keeps memory bounded (labelled `live-capped`).
    let size = 1u64 << 20;
    let capped = fixtures::cluster_cfg(
        4,
        GetBatchConfig { chunk_bytes: 128 << 10, dt_buffer_bytes: 512 << 10, ..Default::default() },
    );
    let base = LoadSpec {
        object_size: size,
        workers,
        duration: Duration::from_millis(ms),
        num_objects: 64,
        ..Default::default()
    };
    aisloader::stage_uniform(&capped, "bench", &base);
    let get = aisloader::run(&capped, "bench", &base);
    let g = get.throughput.gib_per_sec();
    println!("live-capped,{size},get,1,{g:.3},1.0");
    for &k in &[8usize, 16, 32] {
        let r = aisloader::run(&capped, "bench", &LoadSpec { batch: Some(k), ..base.clone() });
        let t = r.throughput.gib_per_sec();
        println!("live-capped,{size},getbatch,{k},{t:.3},{:.2}", t / g);
    }
    let peak = capped.targets.iter().map(|t| t.budget.peak()).max().unwrap();
    eprintln!(
        "# live-capped: max DT resident {peak} B, budget {} B",
        capped.targets[0].budget.budget()
    );
}
