//! Table 2 reproduction: batch + per-object latency percentiles for the
//! three data-access methods under a training workload.
//!
//! SIM: 256 bursty loaders vs the 16-node model (the paper's reduced-client
//! §4.2.1 setup). LIVE: scaled-down training-shaped load on the in-process
//! cluster with loader workers sharing the cluster.

use getbatch::client::loader::{AccessMode, DataLoader};
use getbatch::client::sdk::Client;
use getbatch::sim::model::CostModel;
use getbatch::sim::workload::run_training;
use getbatch::testutil::fixtures;
use getbatch::util::cli::Args;
use getbatch::util::stats::Samples;
use getbatch::util::threadpool::scoped_map;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));

    println!("## Table 2 — SIM (256 loaders, batch 128, bursty synchronous steps)");
    println!("{:<18} {:>42}  {:>42}", "method", "batch latency ms (P50/P95/P99/Avg)", "per-object ms (P50/P95/P99/Avg)");
    let m = CostModel::oci_16node();
    let steps = args.usize_or("sim-steps", 10);
    let mut rows = Vec::new();
    for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
        let r = run_training(&m, mode, 256, 128, steps, 120.0, 42);
        println!(
            "{:<18} {:>9.1}/{:>9.1}/{:>9.1}/{:>9.1}  {:>9.2}/{:>9.2}/{:>9.2}/{:>9.2}",
            mode.name(),
            r.batch_ms.p50, r.batch_ms.p95, r.batch_ms.p99, r.batch_ms.avg,
            r.per_object_ms.p50, r.per_object_ms.p95, r.per_object_ms.p99, r.per_object_ms.avg,
        );
        rows.push(r);
    }
    let get = &rows[1];
    let gb = &rows[2];
    println!("\nderived (§4.2.2):");
    println!("  P95 batch reduction GetBatch vs GET : {:.2}x (paper: 2.0x)", get.batch_ms.p95 / gb.batch_ms.p95);
    println!("  P99 batch reduction                 : {:.2}x (paper: 1.75x)", get.batch_ms.p99 / gb.batch_ms.p99);
    println!("  P99 per-object reduction            : {:.2}x (paper: 3.7x)", get.per_object_ms.p99 / gb.per_object_ms.p99);
    println!(
        "  P99-P50 spread: GET {:.0} ms vs GetBatch {:.0} ms ({:.0}% reduction; paper: 40%)",
        get.batch_ms.spread(),
        gb.batch_ms.spread(),
        (1.0 - gb.batch_ms.spread() / get.batch_ms.spread()) * 100.0
    );
    println!("paper table 2 (batch ms):  Seq 243.7/431.2/638.9/261.4 | GET 934.7/3668.7/4814.3/1320.0 | GetBatch 427.5/1808.6/2744.7/624.7\n");

    // ------------------------------------------------------------- LIVE ---
    if args.bool("no-live") {
        return;
    }
    println!("## Table 2 — LIVE (in-process cluster, {} loader workers, batch {})",
             args.usize_or("live-loaders", 8), args.usize_or("live-batch", 32));
    let c = fixtures::cluster(4);
    let manifest = fixtures::stage_shards(&c, "audio", 16, 64, 8192.0, 21);
    let loaders = args.usize_or("live-loaders", 8);
    let batch = args.usize_or("live-batch", 32);
    let steps = args.usize_or("live-steps", 12);
    for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
        let per_worker: Vec<(Samples, Samples)> = scoped_map(
            &(0..loaders as u64).collect::<Vec<_>>(),
            loaders,
            |_, &w| {
                let mut dl = DataLoader::new(
                    Client::new(&c.proxy_addr()),
                    manifest.clone(),
                    mode,
                    batch,
                    w + 7,
                );
                let mut bs = Samples::new();
                let mut os = Samples::new();
                for _ in 0..steps {
                    if let Ok((_, timing)) = dl.next_batch() {
                        bs.add(timing.batch.as_secs_f64() * 1e3);
                        for d in timing.per_object {
                            os.add(d.as_secs_f64() * 1e3);
                        }
                    }
                }
                (bs, os)
            },
        );
        let mut bs = Samples::new();
        let mut os = Samples::new();
        for (b, o) in per_worker {
            bs.merge(&b);
            os.merge(&o);
        }
        println!("{:<18} batch {}  per-obj {}", mode.name(), bs.row(), os.row());
    }
}
