//! Table 1 reproduction: sustained throughput, individual GET vs
//! GetBatch(32/64/128) × {10 KiB, 100 KiB, 1 MiB}.
//!
//! Two harnesses, both printed:
//!  - SIM  — the 16-node OCI cost model at paper scale (80 workers), the
//!           apples-to-apples shape comparison with the paper's table;
//!  - LIVE — the real in-process cluster over localhost TCP (scaled down:
//!           fewer workers, shorter windows, smaller object counts).
//!
//! Usage: cargo bench --bench table1 [-- --live-ms 1500 --live-workers 8]

use std::time::Duration;

use getbatch::aisloader::{self, LoadSpec};
use getbatch::sim::model::CostModel;
use getbatch::sim::workload::run_synthetic;
use getbatch::testutil::fixtures;
use getbatch::util::bytes::fmt_size;
use getbatch::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let sizes: [u64; 3] = [10 << 10, 100 << 10, 1 << 20];
    let batches = [32usize, 64, 128];

    // ------------------------------------------------------------- SIM ----
    println!("## Table 1 — SIM (16-node OCI model, 80 workers, paper scale)");
    println!("{:<10} {:>10} {:>16} {:>16} {:>16}", "size", "GET", "Batch32", "Batch64", "Batch128");
    let m = CostModel::oci_16node();
    let secs = args.f64_or("sim-secs", 5.0);
    for (row, &size) in sizes.iter().enumerate() {
        let get = run_synthetic(&m, 80, size, None, secs, 100 + row as u64);
        let g = get.throughput.gib_per_sec();
        let mut cells = Vec::new();
        for (bi, &k) in batches.iter().enumerate() {
            let r = run_synthetic(&m, 80, size, Some(k), secs, 200 + (row * 3 + bi) as u64);
            let t = r.throughput.gib_per_sec();
            cells.push(format!("{t:>7.2} ({:>4.1}x)", t / g));
        }
        println!("{:<10} {:>7.2}    {} {} {}", fmt_size(size), g, cells[0], cells[1], cells[2]);
    }
    println!("paper:     10KiB GET 0.5 | 4.5 (9x) 6.0 (12x) 7.3 (15x)");
    println!("           100KiB GET 4.2 | 20.7 (4.9x) 24.1 (5.7x) 26.1 (6.2x)");
    println!("           1MiB GET 22.3 | 32.4 (1.5x) 35.2 (1.6x) 37.0 (1.7x)\n");

    // ------------------------------------------------------------- LIVE ---
    if args.bool("no-live") {
        return;
    }
    println!("## Table 1 — LIVE (in-process cluster, localhost TCP, scaled)");
    let workers = args.usize_or("live-workers", 8);
    let ms = args.u64_or("live-ms", 1500);
    let targets = args.usize_or("live-targets", 4);
    let live_sizes: [u64; 3] = [10 << 10, 100 << 10, 1 << 20];
    println!(
        "{} targets, {} workers, {} ms per cell",
        targets, workers, ms
    );
    println!("{:<10} {:>10} {:>16} {:>16} {:>16}", "size", "GET", "Batch32", "Batch64", "Batch128");
    for &size in &live_sizes {
        let c = fixtures::cluster(targets);
        let base = LoadSpec {
            object_size: size,
            workers,
            duration: Duration::from_millis(ms),
            num_objects: if size >= 1 << 20 { 128 } else { 512 },
            ..Default::default()
        };
        aisloader::stage_uniform(&c, "bench", &base);
        let get = aisloader::run(&c, "bench", &base);
        let g = get.throughput.gib_per_sec();
        let mut cells = Vec::new();
        for &k in &batches {
            let r = aisloader::run(&c, "bench", &LoadSpec { batch: Some(k), ..base.clone() });
            let t = r.throughput.gib_per_sec();
            cells.push(format!("{t:>7.2} ({:>4.1}x)", t / g));
        }
        println!("{:<10} {:>7.2}    {} {} {}", fmt_size(size), g, cells[0], cells[1], cells[2]);
    }
}
