//! Design-choice ablations (DESIGN.md A1-A4):
//!   A1 colocation hint on/off       — cross-node sender traffic + latency
//!   A2 streaming vs buffered DT     — time-to-first-byte + total
//!   A3 persistent pool vs cold conn — client connection reuse effect
//!   A4 batch-size sweep             — objects/s vs batch size (1..512)

use std::time::Duration;

use getbatch::aisloader::{self, LoadSpec};
use getbatch::batch::request::BatchRequest;
use getbatch::client::sdk::Client;
use getbatch::testutil::fixtures;
use getbatch::util::cli::Args;
use getbatch::util::stats::Samples;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let iters = args.usize_or("iters", 30);

    // ---- A1: colocation ----------------------------------------------------
    println!("## A1 — colocation hint (single-shard batch, 4 targets)");
    let c = fixtures::cluster(4);
    let manifest = fixtures::stage_shards(&c, "audio", 1, 128, 4096.0, 1);
    let client = Client::new(&c.proxy_addr());
    for coloc in [false, true] {
        let before: u64 = c.targets.iter().map(|t| t.metrics.sender_entries.get()).sum();
        let mut lat = Samples::new();
        for _ in 0..iters {
            let entries: Vec<_> = manifest.samples.iter().take(64).map(|s| s.to_entry()).collect();
            let req = BatchRequest::new(entries).colocation(coloc);
            let (_, stats) = client.get_batch_timed(&req).unwrap();
            lat.add(stats.total.as_secs_f64() * 1e3);
        }
        let crossed: u64 =
            c.targets.iter().map(|t| t.metrics.sender_entries.get()).sum::<u64>() - before;
        println!(
            "  coloc={coloc:<5}  cross-node entries={crossed:>5}  batch {}",
            lat.row()
        );
    }

    // ---- A2: streaming vs buffered ------------------------------------------
    println!("## A2 — streaming vs buffered DT (64 x 64KiB batch)");
    let c = fixtures::cluster(4);
    let names = fixtures::stage_objects(&c, "b", 256, 64 << 10, 2);
    let client = Client::new(&c.proxy_addr());
    for strm in [true, false] {
        let mut ttfb = Samples::new();
        let mut total = Samples::new();
        for _ in 0..iters {
            let entries: Vec<_> = names
                .iter()
                .take(64)
                .map(|n| getbatch::batch::request::BatchEntry::obj("b", n))
                .collect();
            let req = BatchRequest::new(entries).streaming(strm);
            let (_, stats) = client.get_batch_timed(&req).unwrap();
            ttfb.add(stats.ttfb.as_secs_f64() * 1e3);
            total.add(stats.total.as_secs_f64() * 1e3);
        }
        println!(
            "  strm={strm:<5}  ttfb P50 {:>7.2} ms  total P50 {:>7.2} ms",
            ttfb.percentile(50.0),
            total.percentile(50.0)
        );
    }

    // ---- A3: connection reuse ------------------------------------------------
    println!("## A3 — client connection reuse (GET path, 10KiB)");
    let c = fixtures::cluster(2);
    let spec = LoadSpec {
        object_size: 10 << 10,
        workers: 4,
        duration: Duration::from_millis(args.u64_or("ms", 1200)),
        num_objects: 256,
        ..Default::default()
    };
    aisloader::stage_uniform(&c, "bench", &spec);
    for no_reuse in [false, true] {
        let r = aisloader::run(&c, "bench", &LoadSpec { no_reuse, ..spec.clone() });
        println!(
            "  reuse={:<5}  {:>9.0} obj/s  lat {}",
            !no_reuse,
            r.throughput.ops_per_sec(),
            r.request_ms
        );
    }

    // ---- A4: batch-size sweep --------------------------------------------------
    println!("## A4 — batch-size sweep (10KiB objects)");
    let c = fixtures::cluster(4);
    let spec = LoadSpec {
        object_size: 10 << 10,
        workers: 8,
        duration: Duration::from_millis(args.u64_or("ms", 1200)),
        num_objects: 1024,
        ..Default::default()
    };
    aisloader::stage_uniform(&c, "bench", &spec);
    let base = aisloader::run(&c, "bench", &spec);
    println!("  batch=1(GET)  {:>9.0} obj/s", base.throughput.ops_per_sec());
    for k in [4usize, 16, 32, 64, 128, 256, 512] {
        let r = aisloader::run(&c, "bench", &LoadSpec { batch: Some(k), ..spec.clone() });
        println!(
            "  batch={k:<5}  {:>9.0} obj/s  ({:.1}x)",
            r.throughput.ops_per_sec(),
            r.throughput.ops_per_sec() / base.throughput.ops_per_sec()
        );
    }
}
