//! Micro-benchmarks of the L3 hot paths (feeds the §Perf iteration loop):
//! TAR assembly, frame encode/decode, reorder buffer, JSON request parse,
//! end-to-end single-batch latency on a live cluster.

use std::sync::Arc;
use std::time::{Duration, Instant};

use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::loader::{AccessMode, DataLoader, Manifest, SampleRef};
use getbatch::client::prefetch::PrefetchPlanner;
use getbatch::client::sdk::Client;
use getbatch::config::GetBatchConfig;
use getbatch::proto::http::HttpClient;
use getbatch::dt::order::OrderBuffer;
use getbatch::proto::frame::{chunk_frames, encode_into, read_frame, Frame};
use getbatch::store::{Backend, CachedBackend, ChunkCache, LocalBackend, RemoteBackend, TailConfig};
use getbatch::tar::TarWriter;
use getbatch::testutil::fixtures;
use getbatch::util::cli::Args;

fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    // warmup
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed() / iters as u32;
    println!("{name:<44} {per:>12.2?}/iter   ({iters} iters)");
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let quick = args.bool("quick");
    let scale = if quick { 1 } else { 4 };

    // TAR assembly of a 128 x 10KiB batch (the DT serialization core)
    let payload = vec![7u8; 10 << 10];
    bench("tar: assemble 128 x 10KiB", 200 * scale, || {
        let mut w = TarWriter::new(Vec::with_capacity(130 * 10 << 10));
        for i in 0..128 {
            w.append(&format!("obj-{i:06}"), &payload).unwrap();
        }
        w.finish().unwrap();
    });

    // frame encode+decode of a 10KiB entry
    let f = Frame::data(1, 0, vec![9u8; 10 << 10]);
    let mut buf = Vec::new();
    bench("frame: encode 10KiB", 20_000 * scale, || {
        encode_into(&f, &mut buf);
    });
    encode_into(&f, &mut buf);
    bench("frame: decode 10KiB (incl. crc)", 20_000 * scale, || {
        let mut cur = std::io::Cursor::new(&buf);
        read_frame(&mut cur).unwrap().unwrap();
    });

    // chunked framing of a 1MiB entry (the large-object streaming path);
    // chunks are prebuilt so the measurement is encode cost, not the
    // allocation of a fresh source buffer per iteration
    let big = vec![3u8; 1 << 20];
    let big_frames = chunk_frames(1, 0, big, 256 << 10);
    let mut scratch = Vec::new();
    bench("frame: encode 1MiB chunked (4 x 256KiB, incl. crc)", 500 * scale, || {
        for cf in &big_frames {
            encode_into(cf, &mut scratch);
        }
    });
    let mut chunk_wire = Vec::new();
    for cf in &big_frames {
        getbatch::proto::frame::write_frame(&mut chunk_wire, cf).unwrap();
    }
    bench("frame: decode 1MiB chunked (incl. crc)", 500 * scale, || {
        let mut cur = std::io::Cursor::new(&chunk_wire);
        while read_frame(&mut cur).unwrap().is_some() {}
    });

    // reorder buffer: 256 out-of-order fills + ordered drain
    bench("order: 256-slot fill+drain", 2_000 * scale, || {
        let b = OrderBuffer::new(256);
        for i in (0..256u32).rev() {
            b.fill(i, vec![0u8; 64]);
        }
        for i in 0..256u32 {
            b.wait_take(i, std::time::Duration::from_secs(1));
        }
    });

    // JSON parse of a 512-entry batch request (proxy coloc path + DT)
    let req = BatchRequest::new(
        (0..512).map(|i| BatchEntry::member("bucket", &format!("shard-{:04}.tar", i % 16), &format!("member-{i:05}"))).collect(),
    );
    let body = req.to_body();
    println!("request body: {} bytes for 512 entries", body.len());
    bench("wire: parse 512-entry batch request", 2_000 * scale, || {
        BatchRequest::from_body(&body).unwrap();
    });

    // end-to-end single batch on a live cluster
    let c = fixtures::cluster(4);
    let names = fixtures::stage_objects(&c, "b", 256, 10 << 10, 1);
    let client = Client::new(&c.proxy_addr());
    let entries: Vec<BatchEntry> =
        names.iter().take(128).map(|n| BatchEntry::obj("b", n)).collect();
    bench("e2e: GetBatch(128 x 10KiB) live", 50 * scale, || {
        client.get_batch_collect(&BatchRequest::new(entries.clone())).unwrap();
    });
    let one = vec![BatchEntry::obj("b", &names[0])];
    bench("e2e: GET-equivalent batch(1) live", 200 * scale, || {
        client.get_batch_collect(&BatchRequest::new(one.clone())).unwrap();
    });
    bench("e2e: plain GET live", 200 * scale, || {
        client.get("b", &names[0]).unwrap();
    });
    drop(client);
    drop(c);

    // Large-object, memory-capped scenario: 8 x 1MiB per batch streamed
    // through a DT whose enforced budget (256KiB) is 32x smaller than the
    // batch — exercises chunked streaming + backpressure end to end.
    let capped = fixtures::cluster_cfg(
        4,
        GetBatchConfig { chunk_bytes: 64 << 10, dt_buffer_bytes: 256 << 10, ..Default::default() },
    );
    let big_names = fixtures::stage_objects(&capped, "big", 16, 1 << 20, 2);
    let capped_client = Client::new(&capped.proxy_addr());
    let big_entries: Vec<BatchEntry> =
        big_names.iter().take(8).map(|n| BatchEntry::obj("big", n)).collect();
    bench("e2e: GetBatch(8 x 1MiB) budget=256KiB", 10 * scale, || {
        capped_client.get_batch_collect(&BatchRequest::new(big_entries.clone())).unwrap();
    });
    let peak = capped.targets.iter().map(|t| t.budget.peak()).max().unwrap();
    println!(
        "memory-capped run: max DT resident {} B (budget {} B), overruns {}",
        peak,
        capped.targets[0].budget.budget(),
        capped.targets.iter().map(|t| t.budget.overruns()).sum::<u64>()
    );
    drop(capped_client);
    drop(capped);

    // Tiered store: a 1 MiB object read through each tier — local disk,
    // read-through chunk cache cold (every chunk a read-through fill) vs
    // warm (every chunk a hit), remote HTTP Range backend, and remote
    // fronted by a warm cache (the latency the cache tier hides).
    let tier_dir = std::env::temp_dir().join(format!("gb-hotpath-tier-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tier_dir);
    std::fs::create_dir_all(&tier_dir).unwrap();
    let local = Arc::new(LocalBackend::open(&tier_dir, 2).unwrap());
    let obj = vec![5u8; 1 << 20];
    local.put("b", "o", &obj).unwrap();
    bench("store: 1MiB read, local tier", 200 * scale, || {
        assert_eq!(local.open_entry("b", "o").unwrap().read_all().unwrap().len(), 1 << 20);
    });
    bench("store: 1MiB read, cache COLD (read-through)", 100 * scale, || {
        let cache = Arc::new(ChunkCache::new(8 << 20, 256 << 10, None));
        let cached = CachedBackend::new(
            Arc::clone(&local) as Arc<dyn Backend>,
            cache,
            2,
            Duration::from_secs(3600),
        );
        assert_eq!(cached.open_entry("b", "o").unwrap().read_all().unwrap().len(), 1 << 20);
    });
    let warm_cache = Arc::new(ChunkCache::new(8 << 20, 256 << 10, None));
    let warm = CachedBackend::new(
        Arc::clone(&local) as Arc<dyn Backend>,
        Arc::clone(&warm_cache),
        2,
        Duration::from_secs(3600),
    );
    let _ = warm.open_entry("b", "o").unwrap().read_all().unwrap();
    bench("store: 1MiB read, cache WARM (all hits)", 500 * scale, || {
        assert_eq!(warm.open_entry("b", "o").unwrap().read_all().unwrap().len(), 1 << 20);
    });

    let storage = fixtures::cluster(1);
    storage.put_direct("rb", "o", &obj).unwrap();
    let remote = Arc::new(RemoteBackend::new(&storage.proxy_addr(), None));
    bench("store: 1MiB read, remote tier (HTTP range)", 50 * scale, || {
        assert_eq!(remote.open_entry("rb", "o").unwrap().read_all().unwrap().len(), 1 << 20);
    });
    let rcache = Arc::new(ChunkCache::new(8 << 20, 256 << 10, None));
    let rcached = CachedBackend::new(
        Arc::clone(&remote) as Arc<dyn Backend>,
        Arc::clone(&rcache),
        2,
        Duration::from_secs(3600),
    );
    let _ = rcached.open_entry("rb", "o").unwrap().read_all().unwrap();
    bench("store: 1MiB read, remote + WARM cache", 200 * scale, || {
        assert_eq!(rcached.open_entry("rb", "o").unwrap().read_all().unwrap().len(), 1 << 20);
    });
    println!(
        "remote scenario: {} fetch requests, cache {} hits / {} misses",
        rcache.hits.get() + rcache.misses.get(),
        rcache.hits.get(),
        rcache.misses.get()
    );

    // End-to-end: a remote-backed bucket served through the tiered stack
    // (cold includes remote fetch + cache fill; warm is cache-resident).
    let serving = fixtures::cluster_cfg(
        2,
        GetBatchConfig { cache_bytes: 32 << 20, readahead_chunks: 2, ..Default::default() },
    );
    serving.route_remote_bucket("rb", &[&storage.proxy_addr()], true);
    let sclient = Client::new(&serving.proxy_addr());
    let rb_entries = vec![BatchEntry::obj("rb", "o")];
    let warm_req = BatchRequest::new(rb_entries);
    sclient.get_batch_collect(&warm_req).unwrap(); // cold fill
    bench("e2e: GetBatch(1MiB) remote bucket, warm cache", 50 * scale, || {
        sclient.get_batch_collect(&warm_req).unwrap();
    });

    // Degraded-endpoint scenario (the tail-latency engine): one of two
    // endpoints serving the same object straggles 25 ms per read. With
    // hedging off, latency-aware selection steers reads to the healthy
    // endpoint but each periodic slow trial pays the full delay; with
    // hedging on, a straggling read is raced to the healthy endpoint after
    // the 5 ms floor, so the trials stop dominating the average.
    let degraded = fixtures::cluster(1);
    degraded.put_direct("rb", "o", &obj).unwrap();
    degraded.targets[0].store.local().set_latency(Duration::from_millis(25), 1.0);
    let slow_addr = degraded.proxy_addr();
    let fast_addr = storage.proxy_addr();
    let mk = |quantile: f64| {
        RemoteBackend::with_tail(
            &[&slow_addr, &fast_addr],
            3,
            Duration::from_millis(100),
            TailConfig {
                slow: Duration::from_millis(10),
                hedge_quantile: quantile,
                hedge_min: Duration::from_millis(5),
                hedge_max_inflight: 32,
            },
            None,
        )
    };
    let unhedged = mk(0.0);
    bench("store: 1MiB read, degraded endpoint, hedge OFF", 50 * scale, || {
        assert_eq!(unhedged.open_entry("rb", "o").unwrap().read_all().unwrap().len(), 1 << 20);
    });
    let hedged = mk(0.95);
    bench("store: 1MiB read, degraded endpoint, hedge ON", 50 * scale, || {
        assert_eq!(hedged.open_entry("rb", "o").unwrap().read_all().unwrap().len(), 1 << 20);
    });

    // Epoch pipeline (the epoch-aware loading engine): one full
    // deterministic epoch — begin_epoch + next_epoch_batch, GetBatch mode —
    // over a remote-backed bucket, three ways. OFF-cold pays every remote
    // fill inline on the demand path; OFF-warm is the cache-resident floor;
    // ON-cold overlaps batch N+1's fills with batch N's streaming. The two
    // cold scenarios invalidate the dataset through the gateway before each
    // epoch (both pay that identically, so the OFF/ON delta prices the
    // prefetch pipeline itself).
    let epoch_storage = fixtures::cluster(1);
    let mut manifest = Manifest::default();
    for i in 0..16usize {
        let name = format!("s-{i:03}");
        epoch_storage.put_direct("ds", &name, &vec![i as u8; 64 << 10]).unwrap();
        manifest.samples.push(SampleRef {
            bucket: "ds".into(),
            shard: None,
            name,
            size: 64 << 10,
        });
    }
    let epoch_serving = fixtures::cluster_cfg(
        2,
        GetBatchConfig {
            cache_bytes: 32 << 20,
            readahead_chunks: 2,
            prefetch_batches: 2,
            ..Default::default()
        },
    );
    epoch_serving.route_remote_bucket("ds", &[&epoch_storage.proxy_addr()], true);
    let http = HttpClient::new(true);
    let invalidate_all = || {
        for s in &manifest.samples {
            http.request(
                "POST",
                &epoch_serving.proxy_addr(),
                &format!("/v1/invalidate?bucket=ds&obj={}", s.name),
                &[],
            )
            .unwrap();
        }
    };
    let eclient = Client::new(&epoch_serving.proxy_addr());
    let mut edl = DataLoader::new(eclient.clone(), manifest.clone(), AccessMode::GetBatch, 4, 7);
    bench("epoch: 16-obj remote epoch, prefetch OFF cold", 10 * scale, || {
        invalidate_all();
        edl.begin_epoch(0);
        while edl.next_epoch_batch().unwrap().is_some() {}
    });
    bench("epoch: 16-obj remote epoch, prefetch OFF warm", 20 * scale, || {
        edl.begin_epoch(0);
        while edl.next_epoch_batch().unwrap().is_some() {}
    });
    let planner = PrefetchPlanner::new(eclient.clone(), 2, 4);
    let mut pdl = DataLoader::new(eclient, manifest.clone(), AccessMode::GetBatch, 4, 7);
    pdl.attach_prefetch(Arc::clone(&planner));
    bench("epoch: 16-obj remote epoch, prefetch ON cold", 10 * scale, || {
        invalidate_all();
        pdl.begin_epoch(0);
        while pdl.next_epoch_batch().unwrap().is_some() {}
        // Drain the background fills so no iteration inherits warmth the
        // previous one paid for.
        planner.wait_idle(Duration::from_secs(10));
    });
    println!(
        "epoch scenario: prefetch issued {} / failed {}",
        planner.issued.get(),
        planner.failed.get()
    );

    let _ = std::fs::remove_dir_all(&tier_dir);
}
