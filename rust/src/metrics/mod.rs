//! Per-node observability (§2.4.4): counters/gauges with Prometheus text
//! exposition. The metric names mirror the paper's: workload composition
//! (work items, delivered objects vs shard extractions), bottleneck
//! decomposition (`rxwait` vs `throttle`), and the error/recovery family.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1)
    }
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn sub(&self, v: i64) {
        self.0.fetch_sub(v, Ordering::Relaxed);
    }
    /// Ratchet the gauge up to `v` (high-water marks, e.g. peak buffers).
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The GetBatch metric family of one node (§2.4.4). Field names follow the
/// paper's terminology.
#[derive(Default)]
pub struct GetBatchMetrics {
    // -- workload composition ---------------------------------------------
    /// Total executed work items (one per request entry).
    pub work_items: Counter,
    /// Whole objects delivered / bytes.
    pub objs_delivered: Counter,
    pub obj_bytes: Counter,
    /// Shard-extracted members delivered / bytes.
    pub members_extracted: Counter,
    pub member_bytes: Counter,
    /// GetBatch requests coordinated by this node (as DT).
    pub dt_requests: Counter,
    /// Entries this node served as a sender.
    pub sender_entries: Counter,

    // -- bottleneck decomposition -----------------------------------------
    /// Cumulative ns spent waiting to receive entries from peer targets.
    pub rxwait_ns: Counter,
    /// Cumulative ns slept due to local pressure throttling.
    pub throttle_ns: Counter,
    /// Cumulative ns producers spent blocked on the DT memory budget.
    pub budget_wait_ns: Counter,
    /// Forced budget admissions after the patience timeout (liveness valve).
    pub budget_overruns: Counter,
    /// Chunk frames emitted by this node as a sender.
    pub sender_chunks: Counter,
    /// Recoveries triggered early because sender fan-in completed with the
    /// slot still unresolved (no need to burn the full sender-wait timeout).
    pub early_recoveries: Counter,

    // -- errors & recovery --------------------------------------------------
    /// Hard failures: aborted requests.
    pub hard_failures: Counter,
    /// Admission rejections (HTTP 429).
    pub admission_rejects: Counter,
    /// Soft errors tolerated under continue-on-error.
    pub soft_errors: Counter,
    /// Get-from-neighbor recovery attempts / failures.
    pub recovery_attempts: Counter,
    pub recovery_failures: Counter,

    // -- storage tiers ------------------------------------------------------
    /// Read-through chunk cache: hits / misses / LRU evictions.
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub cache_evictions: Counter,
    /// Chunk-cache fills by origin: `demand` fills happen inline on a
    /// read's miss path, `prefetch` fills are issued ahead of need by the
    /// epoch batch planner. Rendered as `cache_fills_total{kind=...}`.
    pub cache_fills_demand: Counter,
    pub cache_fills_prefetch: Counter,
    /// Epoch prefetch: objects the prefetch path was asked to warm
    /// (`issued`), demand reads that landed on a still-pinned prefetched
    /// chunk (`hits`), and prefetched chunks dropped — evicted, staled, or
    /// invalidated — before any demand read consumed them (`wasted`).
    pub prefetch_issued: Counter,
    pub prefetch_hits: Counter,
    pub prefetch_wasted: Counter,
    /// Coherence: invalidation events applied to the chunk cache (local
    /// write-through + received `/v1/invalidate` broadcasts).
    pub cache_invalidations: Counter,
    /// Coherence: chunks dropped because a newer object version was
    /// observed or the object was invalidated — staleness work, disjoint
    /// from the capacity-driven `cache_evictions`.
    pub cache_stale_evictions: Counter,
    /// Coherence: `/v1/invalidate` broadcasts initiated by this node
    /// (target fan-out after PUT/DELETE, or proxy fan-out on behalf of an
    /// external writer).
    pub invalidate_broadcasts: Counter,
    /// Remote-backend requests issued / payload bytes fetched over HTTP.
    pub remote_fetches: Counter,
    pub remote_fetch_bytes: Counter,
    /// Endpoint failovers: remote operations (including mid-stream ranged
    /// reads) that moved to another endpoint after a failure.
    pub remote_failovers: Counter,
    /// Active health probes issued against broken remote endpoints.
    pub endpoint_probes: Counter,
    /// Hedged reads launched: a ranged read outlived its endpoint's
    /// tracked latency quantile and the same range was raced on the
    /// second-best healthy endpoint.
    pub hedges: Counter,
    /// Hedges where the backup endpoint delivered first.
    pub hedge_wins: Counter,
    /// Hedge losers canceled after producing a usable response (their
    /// connection is dropped, not recycled). Losers that errored or were
    /// abandoned mid-flight count as neither win nor cancel.
    pub hedges_canceled: Counter,

    // -- connection scheduling ----------------------------------------------
    /// epoll wake-ups across the node's reactor threads (HTTP + P2P).
    pub reactor_wakeups: Counter,
    /// Accepted connections shed at the `max_connections` cap.
    pub accept_backlog_shed: Counter,

    // -- resources ----------------------------------------------------------
    /// Connections currently registered on the node's reactors (HTTP
    /// server, P2P server, peer-pool outbound).
    pub open_connections: Gauge,
    /// Bytes currently buffered by in-flight DT assemblies.
    pub dt_buffered_bytes: Gauge,
    /// In-flight GetBatch executions on this node (as DT).
    pub dt_inflight: Gauge,
    /// High-water mark of the largest single entry buffer this node
    /// materialized as a sender — with streaming reads this stays O(chunk)
    /// even for multi-GiB entries (the peak-residency guarantee made
    /// observable).
    pub sender_peak_buffer: Gauge,
    /// Bytes currently resident in the node's read-through chunk cache.
    pub cache_resident_bytes: Gauge,
    /// Remote endpoints currently marked unhealthy (circuit open) across
    /// this node's remote backends. Flips back down when a broken endpoint
    /// passes a health probe (or serves a half-open trial request).
    pub endpoints_unhealthy: Gauge,
    /// Epoch prefetch: the batch horizon the planner is currently running
    /// with (`prefetch_batches` after sanitization; 0 = prefetch off).
    pub prefetch_horizon: Gauge,
    /// Per-endpoint state, rendered as labeled gauge lines per configured
    /// endpoint: `remote_endpoint_healthy{addr="..."}` (1 = circuit
    /// closed), `remote_endpoint_latency_ewma_ms{addr="..."}` (decayed
    /// ranged-read latency, once sampled), and
    /// `remote_endpoint_inflight{addr="..."}` (requests currently
    /// outstanding). Keyed by address with a registration refcount:
    /// endpoint sets that share an address on one node share (and
    /// overwrite) its lines, and the lines disappear only when the *last*
    /// set tracking that address is dropped.
    endpoint_health: Mutex<BTreeMap<String, EndpointLine>>,
    /// Per-tenant QoS state, rendered as labeled lines per tenant seen at
    /// DT registration: `tenant_resident_bytes{tenant=...}` (bytes charged
    /// to the tenant's fair-share ledger), `tenant_admits_total` /
    /// `tenant_sheds_total` (registration outcomes), and
    /// `tenant_throttle_ns_total` (time the tenant's producers spent
    /// blocked on the fair-share gate or the budget). Lines appear on
    /// first touch and persist for the node's lifetime.
    tenant_lines: Mutex<BTreeMap<String, TenantLine>>,
}

/// One tenant's labeled QoS lines (see [`GetBatchMetrics::tenant_admit`]).
#[derive(Default)]
struct TenantLine {
    resident: i64,
    admits: u64,
    sheds: u64,
    throttle_ns: u64,
}

/// One remote endpoint's labeled-gauge state (see
/// [`GetBatchMetrics::register_endpoint`]).
struct EndpointLine {
    healthy: bool,
    /// Latency EWMA in ms; `None` until the first sample (no line rendered
    /// for an endpoint that has never served a ranged read).
    ewma_ms: Option<f64>,
    inflight: i64,
    refs: usize,
}

impl GetBatchMetrics {
    pub fn new() -> Arc<GetBatchMetrics> {
        Arc::new(GetBatchMetrics::default())
    }

    /// Register one tracker of `addr`'s health line (called per endpoint
    /// at `EndpointSet` construction). A *new* line starts healthy; an
    /// existing one keeps its current state — another live set may have
    /// that endpoint's circuit open, and registration is not a health
    /// event.
    pub fn register_endpoint(&self, addr: &str) {
        let mut m = self.endpoint_health.lock().unwrap();
        m.entry(addr.to_string())
            .or_insert(EndpointLine { healthy: true, ewma_ms: None, inflight: 0, refs: 0 })
            .refs += 1;
    }

    /// Update one endpoint's health line (circuit open/close). No-op for
    /// an unregistered address.
    pub fn set_endpoint_health(&self, addr: &str, healthy: bool) {
        if let Some(e) = self.endpoint_health.lock().unwrap().get_mut(addr) {
            e.healthy = healthy;
        }
    }

    /// Update one endpoint's latency-EWMA line (per successful ranged
    /// read). No-op for an unregistered address.
    pub fn set_endpoint_latency(&self, addr: &str, ewma_ms: f64) {
        if let Some(e) = self.endpoint_health.lock().unwrap().get_mut(addr) {
            e.ewma_ms = Some(ewma_ms);
        }
    }

    /// Adjust one endpoint's in-flight gauge line (±1 per request guard).
    /// No-op for an unregistered address.
    pub fn add_endpoint_inflight(&self, addr: &str, delta: i64) {
        if let Some(e) = self.endpoint_health.lock().unwrap().get_mut(addr) {
            e.inflight += delta;
        }
    }

    /// Drop one registration of `addr`'s lines (its set was dropped —
    /// bucket re-routed, cluster shutdown); the lines are removed only
    /// when no set tracks the address anymore.
    pub fn drop_endpoint_health(&self, addr: &str) {
        let mut m = self.endpoint_health.lock().unwrap();
        if let Some(e) = m.get_mut(addr) {
            e.refs = e.refs.saturating_sub(1);
            if e.refs == 0 {
                m.remove(addr);
            }
        }
    }

    /// Count one admitted DT registration for `tenant`.
    pub fn tenant_admit(&self, tenant: &str) {
        self.tenant_lines.lock().unwrap().entry(tenant.to_string()).or_default().admits += 1;
    }

    /// Count one shed (429-rejected) DT registration for `tenant`.
    pub fn tenant_shed(&self, tenant: &str) {
        self.tenant_lines.lock().unwrap().entry(tenant.to_string()).or_default().sheds += 1;
    }

    /// Adjust `tenant`'s resident-bytes gauge line (± ledger charges).
    pub fn tenant_resident_add(&self, tenant: &str, delta: i64) {
        let mut m = self.tenant_lines.lock().unwrap();
        let t = m.entry(tenant.to_string()).or_default();
        t.resident = t.resident.saturating_add(delta);
    }

    /// Accumulate producer-blocked time on `tenant`'s throttle line.
    pub fn tenant_throttle_add(&self, tenant: &str, ns: u64) {
        let mut m = self.tenant_lines.lock().unwrap();
        let t = m.entry(tenant.to_string()).or_default();
        t.throttle_ns = t.throttle_ns.saturating_add(ns);
    }

    /// Prometheus text exposition (§2.4.4 "lightweight, per-node Prometheus
    /// metrics").
    pub fn render(&self, node: &str) -> String {
        let mut out = String::with_capacity(1024);
        {
            let mut c = |name: &str, help: &str, v: u64| {
                out.push_str(&format!(
                    "# HELP ais_getbatch_{name} {help}\n# TYPE ais_getbatch_{name} counter\nais_getbatch_{name}{{node=\"{node}\"}} {v}\n"
                ));
            };
            c("work_items_total", "executed work items", self.work_items.get());
            c("objects_delivered_total", "whole objects delivered", self.objs_delivered.get());
            c("object_bytes_total", "bytes of whole objects delivered", self.obj_bytes.get());
            c("members_extracted_total", "archive members extracted", self.members_extracted.get());
            c("member_bytes_total", "bytes of archive members delivered", self.member_bytes.get());
            c("dt_requests_total", "requests coordinated as DT", self.dt_requests.get());
            c("sender_entries_total", "entries served as sender", self.sender_entries.get());
            c("rxwait_ns_total", "cumulative ns waiting for peer senders", self.rxwait_ns.get());
            c("throttle_ns_total", "cumulative ns slept under local pressure", self.throttle_ns.get());
            c("budget_wait_ns_total", "cumulative ns producers blocked on the DT memory budget", self.budget_wait_ns.get());
            c("budget_overruns_total", "forced budget admissions after patience timeout", self.budget_overruns.get());
            c("sender_chunks_total", "chunk frames emitted as sender", self.sender_chunks.get());
            c("early_recoveries_total", "recoveries triggered by early fan-in completion", self.early_recoveries.get());
            c("hard_failures_total", "aborted requests", self.hard_failures.get());
            c("admission_rejects_total", "HTTP 429 admission rejections", self.admission_rejects.get());
            c("soft_errors_total", "tolerated soft errors", self.soft_errors.get());
            c("recovery_attempts_total", "GFN recovery attempts", self.recovery_attempts.get());
            c("recovery_failures_total", "failed recoveries", self.recovery_failures.get());
            c("cache_hits_total", "chunk cache hits", self.cache_hits.get());
            c("cache_misses_total", "chunk cache misses", self.cache_misses.get());
            c("cache_evictions_total", "chunk cache LRU evictions", self.cache_evictions.get());
            c("prefetch_issued_total", "objects the epoch prefetch path was asked to warm", self.prefetch_issued.get());
            c("prefetch_hits_total", "demand reads served by a still-pinned prefetched chunk", self.prefetch_hits.get());
            c("prefetch_wasted_total", "prefetched chunks dropped before any demand read", self.prefetch_wasted.get());
            c("cache_invalidations_total", "cache invalidation events applied", self.cache_invalidations.get());
            c("cache_stale_evictions_total", "chunks dropped for version staleness", self.cache_stale_evictions.get());
            c("invalidate_broadcasts_total", "invalidation broadcasts initiated", self.invalidate_broadcasts.get());
            c("remote_fetches_total", "remote-backend requests issued", self.remote_fetches.get());
            c("remote_fetch_bytes_total", "payload bytes fetched from remote backends", self.remote_fetch_bytes.get());
            c("remote_failovers_total", "remote operations failed over to another endpoint", self.remote_failovers.get());
            c("endpoint_probes_total", "active health probes of broken remote endpoints", self.endpoint_probes.get());
            c("hedges_total", "hedged remote reads launched", self.hedges.get());
            c("hedge_wins_total", "hedged reads won by the backup endpoint", self.hedge_wins.get());
            c("hedges_canceled_total", "hedge losers canceled after responding", self.hedges_canceled.get());
            c("reactor_wakeups_total", "epoll wake-ups across reactor threads", self.reactor_wakeups.get());
            c("accept_backlog_shed_total", "connections shed at the max_connections cap", self.accept_backlog_shed.get());
        }
        // Fill-origin split: one labeled counter line per fill kind.
        // `parse` strips labels (the two lines would collide in its map),
        // so consumers of the split assert on the raw text lines.
        out.push_str(&format!(
            "# HELP ais_getbatch_cache_fills_total chunk-cache fills by origin\n\
             # TYPE ais_getbatch_cache_fills_total counter\n\
             ais_getbatch_cache_fills_total{{node=\"{node}\",kind=\"demand\"}} {}\n\
             ais_getbatch_cache_fills_total{{node=\"{node}\",kind=\"prefetch\"}} {}\n",
            self.cache_fills_demand.get(),
            self.cache_fills_prefetch.get()
        ));
        // Derived hit ratio: computed at render time from the counters so
        // scrapers get it without doing the division (0 with no traffic).
        let (h, m) = (self.cache_hits.get(), self.cache_misses.get());
        let ratio = if h + m == 0 { 0.0 } else { h as f64 / (h + m) as f64 };
        out.push_str(&format!(
            "# HELP ais_getbatch_cache_hit_ratio derived chunk-cache hit ratio: hits / (hits + misses)\n\
             # TYPE ais_getbatch_cache_hit_ratio gauge\n\
             ais_getbatch_cache_hit_ratio{{node=\"{node}\"}} {ratio:.4}\n"
        ));
        let mut g = |name: &str, help: &str, v: i64| {
            out.push_str(&format!(
                "# HELP ais_getbatch_{name} {help}\n# TYPE ais_getbatch_{name} gauge\nais_getbatch_{name}{{node=\"{node}\"}} {v}\n"
            ));
        };
        g("open_connections", "connections registered on the node's reactors", self.open_connections.get());
        g("dt_buffered_bytes", "bytes buffered by in-flight assemblies", self.dt_buffered_bytes.get());
        g("dt_inflight", "in-flight executions as DT", self.dt_inflight.get());
        g("sender_peak_buffer", "largest single sender-side entry buffer", self.sender_peak_buffer.get());
        g("cache_resident_bytes", "bytes resident in the chunk cache", self.cache_resident_bytes.get());
        g("endpoints_unhealthy", "remote endpoints currently marked unhealthy", self.endpoints_unhealthy.get());
        g("prefetch_horizon", "epoch prefetch horizon in batches (0 = off)", self.prefetch_horizon.get());
        // Per-endpoint circuit state: one labeled line per configured
        // remote endpoint (the ROADMAP's "surface per-endpoint health"
        // item — the aggregate gauge above says *how many* are broken,
        // these lines say *which*).
        let eps = self.endpoint_health.lock().unwrap();
        if !eps.is_empty() {
            out.push_str(
                "# HELP ais_getbatch_remote_endpoint_healthy 1 if the endpoint's circuit is closed\n\
                 # TYPE ais_getbatch_remote_endpoint_healthy gauge\n",
            );
            for (addr, line) in eps.iter() {
                out.push_str(&format!(
                    "ais_getbatch_remote_endpoint_healthy{{node=\"{node}\",addr=\"{addr}\"}} {}\n",
                    u8::from(line.healthy)
                ));
            }
            // Latency lines only for endpoints that have actually served a
            // ranged read — a cold endpoint has no latency, not latency 0.
            if eps.values().any(|l| l.ewma_ms.is_some()) {
                out.push_str(
                    "# HELP ais_getbatch_remote_endpoint_latency_ewma_ms decayed ranged-read latency per endpoint\n\
                     # TYPE ais_getbatch_remote_endpoint_latency_ewma_ms gauge\n",
                );
                for (addr, line) in eps.iter() {
                    if let Some(ms) = line.ewma_ms {
                        out.push_str(&format!(
                            "ais_getbatch_remote_endpoint_latency_ewma_ms{{node=\"{node}\",addr=\"{addr}\"}} {ms:.3}\n"
                        ));
                    }
                }
            }
            out.push_str(
                "# HELP ais_getbatch_remote_endpoint_inflight requests currently in flight per endpoint\n\
                 # TYPE ais_getbatch_remote_endpoint_inflight gauge\n",
            );
            for (addr, line) in eps.iter() {
                out.push_str(&format!(
                    "ais_getbatch_remote_endpoint_inflight{{node=\"{node}\",addr=\"{addr}\"}} {}\n",
                    line.inflight
                ));
            }
        }
        drop(eps);
        // Per-tenant QoS lines: one labeled line per tenant seen at DT
        // registration. As with the fill split, `parse` strips labels, so
        // consumers assert on the raw text lines.
        let tenants = self.tenant_lines.lock().unwrap();
        if !tenants.is_empty() {
            out.push_str(
                "# HELP ais_getbatch_tenant_resident_bytes bytes charged to the tenant's fair-share ledger\n\
                 # TYPE ais_getbatch_tenant_resident_bytes gauge\n",
            );
            for (t, line) in tenants.iter() {
                out.push_str(&format!(
                    "ais_getbatch_tenant_resident_bytes{{node=\"{node}\",tenant=\"{t}\"}} {}\n",
                    line.resident
                ));
            }
            out.push_str(
                "# HELP ais_getbatch_tenant_admits_total DT registrations admitted per tenant\n\
                 # TYPE ais_getbatch_tenant_admits_total counter\n",
            );
            for (t, line) in tenants.iter() {
                out.push_str(&format!(
                    "ais_getbatch_tenant_admits_total{{node=\"{node}\",tenant=\"{t}\"}} {}\n",
                    line.admits
                ));
            }
            out.push_str(
                "# HELP ais_getbatch_tenant_sheds_total DT registrations shed (429) per tenant\n\
                 # TYPE ais_getbatch_tenant_sheds_total counter\n",
            );
            for (t, line) in tenants.iter() {
                out.push_str(&format!(
                    "ais_getbatch_tenant_sheds_total{{node=\"{node}\",tenant=\"{t}\"}} {}\n",
                    line.sheds
                ));
            }
            out.push_str(
                "# HELP ais_getbatch_tenant_throttle_ns_total ns the tenant's producers spent blocked on fair-share or budget\n\
                 # TYPE ais_getbatch_tenant_throttle_ns_total counter\n",
            );
            for (t, line) in tenants.iter() {
                out.push_str(&format!(
                    "ais_getbatch_tenant_throttle_ns_total{{node=\"{node}\",tenant=\"{t}\"}} {}\n",
                    line.throttle_ns
                ));
            }
        }
        out
    }

    /// Parse an exposition back into name→value (used by tests and the CLI's
    /// `metrics` subcommand when scraping live nodes).
    pub fn parse(text: &str) -> BTreeMap<String, f64> {
        text.lines()
            .filter(|l| !l.starts_with('#') && !l.is_empty())
            .filter_map(|l| {
                let (name_labels, val) = l.rsplit_once(' ')?;
                let name = name_labels.split('{').next()?.to_string();
                Some((name, val.parse().ok()?))
            })
            .collect()
    }
}

/// Global registry keyed by node id — the `/metrics` handler of each node
/// renders its own entry; tests can inspect the whole cluster.
#[derive(Default)]
pub struct Registry {
    nodes: Mutex<BTreeMap<String, Arc<GetBatchMetrics>>>,
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    pub fn node(&self, id: &str) -> Arc<GetBatchMetrics> {
        let mut m = self.nodes.lock().unwrap();
        Arc::clone(m.entry(id.to_string()).or_insert_with(GetBatchMetrics::new))
    }

    pub fn render_all(&self) -> String {
        let m = self.nodes.lock().unwrap();
        m.iter().map(|(id, met)| met.render(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = GetBatchMetrics::default();
        m.work_items.add(10);
        m.work_items.inc();
        assert_eq!(m.work_items.get(), 11);
        m.dt_buffered_bytes.add(100);
        m.dt_buffered_bytes.sub(40);
        assert_eq!(m.dt_buffered_bytes.get(), 60);
    }

    #[test]
    fn render_and_parse_roundtrip() {
        let m = GetBatchMetrics::default();
        m.rxwait_ns.add(123456);
        m.throttle_ns.add(789);
        m.soft_errors.add(3);
        m.dt_inflight.set(2);
        let text = m.render("t1");
        let parsed = GetBatchMetrics::parse(&text);
        assert_eq!(parsed["ais_getbatch_rxwait_ns_total"], 123456.0);
        assert_eq!(parsed["ais_getbatch_throttle_ns_total"], 789.0);
        assert_eq!(parsed["ais_getbatch_soft_errors_total"], 3.0);
        assert_eq!(parsed["ais_getbatch_dt_inflight"], 2.0);
        assert!(text.contains("node=\"t1\""));
        assert!(text.contains("# TYPE ais_getbatch_work_items_total counter"));
    }

    #[test]
    fn endpoint_health_renders_one_labeled_line_per_endpoint() {
        let m = GetBatchMetrics::default();
        assert!(
            !m.render("t0").contains("remote_endpoint_healthy"),
            "no endpoint lines before any endpoint registers"
        );
        m.register_endpoint("10.0.0.7:8080");
        m.register_endpoint("10.0.0.8:8080");
        let text = m.render("t0");
        let lines: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("ais_getbatch_remote_endpoint_healthy{"))
            .collect();
        assert_eq!(lines.len(), 2, "one line per endpoint: {lines:?}");
        assert!(lines.iter().all(|l| l.ends_with(" 1")), "{lines:?}");
        assert!(text.contains("addr=\"10.0.0.7:8080\""));
        // Flip one unhealthy: exactly that line reads 0.
        m.set_endpoint_health("10.0.0.7:8080", false);
        let text = m.render("t0");
        assert!(text.contains("addr=\"10.0.0.7:8080\"} 0"), "{text}");
        assert!(text.contains("addr=\"10.0.0.8:8080\"} 1"), "{text}");
        // A second set tracking the same address: registration is not a
        // health event (the open circuit stays visible), and dropping ONE
        // registration must not remove the line another live set still
        // owns.
        m.register_endpoint("10.0.0.7:8080");
        assert!(
            m.render("t0").contains("addr=\"10.0.0.7:8080\"} 0"),
            "re-registration must not mask the open circuit"
        );
        m.drop_endpoint_health("10.0.0.7:8080");
        assert!(m.render("t0").contains("addr=\"10.0.0.7:8080\""), "refcounted line survives");
        // Dropping the last registrations removes the lines.
        m.drop_endpoint_health("10.0.0.7:8080");
        m.drop_endpoint_health("10.0.0.8:8080");
        assert!(!m.render("t0").contains("remote_endpoint_healthy{"));
    }

    #[test]
    fn endpoint_latency_and_inflight_lines_render() {
        let m = GetBatchMetrics::default();
        m.register_endpoint("10.0.0.7:8080");
        // Inflight renders from registration; latency only once sampled.
        let text = m.render("t0");
        assert!(
            text.contains("ais_getbatch_remote_endpoint_inflight{node=\"t0\",addr=\"10.0.0.7:8080\"} 0"),
            "{text}"
        );
        assert!(!text.contains("remote_endpoint_latency_ewma_ms"), "no latency before a sample");
        m.set_endpoint_latency("10.0.0.7:8080", 12.5);
        m.add_endpoint_inflight("10.0.0.7:8080", 1);
        let text = m.render("t0");
        assert!(
            text.contains(
                "ais_getbatch_remote_endpoint_latency_ewma_ms{node=\"t0\",addr=\"10.0.0.7:8080\"} 12.500"
            ),
            "{text}"
        );
        assert!(
            text.contains("ais_getbatch_remote_endpoint_inflight{node=\"t0\",addr=\"10.0.0.7:8080\"} 1"),
            "{text}"
        );
        m.add_endpoint_inflight("10.0.0.7:8080", -1);
        assert!(m
            .render("t0")
            .contains("ais_getbatch_remote_endpoint_inflight{node=\"t0\",addr=\"10.0.0.7:8080\"} 0"));
        // Updates on unregistered addresses are no-ops, not phantom lines.
        m.set_endpoint_latency("nobody:1", 3.0);
        m.add_endpoint_inflight("nobody:1", 1);
        assert!(!m.render("t0").contains("nobody:1"));
        m.drop_endpoint_health("10.0.0.7:8080");
        assert!(!m.render("t0").contains("remote_endpoint_inflight{"));
    }

    #[test]
    fn hedge_counters_render_and_parse() {
        let m = GetBatchMetrics::default();
        m.hedges.add(5);
        m.hedge_wins.add(3);
        m.hedges_canceled.add(2);
        let parsed = GetBatchMetrics::parse(&m.render("t0"));
        assert_eq!(parsed["ais_getbatch_hedges_total"], 5.0);
        assert_eq!(parsed["ais_getbatch_hedge_wins_total"], 3.0);
        assert_eq!(parsed["ais_getbatch_hedges_canceled_total"], 2.0);
    }

    #[test]
    fn fill_split_and_hit_ratio_render() {
        let m = GetBatchMetrics::default();
        // No traffic: ratio is defined (0), both fill kinds render at 0.
        let text = m.render("t0");
        assert!(text.contains("ais_getbatch_cache_hit_ratio{node=\"t0\"} 0.0000"), "{text}");
        assert!(text.contains("cache_fills_total{node=\"t0\",kind=\"demand\"} 0"), "{text}");
        assert!(text.contains("cache_fills_total{node=\"t0\",kind=\"prefetch\"} 0"), "{text}");
        m.cache_hits.add(3);
        m.cache_misses.add(1);
        m.cache_fills_demand.add(4);
        m.cache_fills_prefetch.add(9);
        m.prefetch_issued.add(2);
        m.prefetch_hits.inc();
        m.prefetch_wasted.inc();
        m.prefetch_horizon.set(2);
        let text = m.render("t0");
        assert!(text.contains("ais_getbatch_cache_hit_ratio{node=\"t0\"} 0.7500"), "{text}");
        assert!(text.contains("cache_fills_total{node=\"t0\",kind=\"demand\"} 4"), "{text}");
        assert!(text.contains("cache_fills_total{node=\"t0\",kind=\"prefetch\"} 9"), "{text}");
        let parsed = GetBatchMetrics::parse(&text);
        assert_eq!(parsed["ais_getbatch_prefetch_issued_total"], 2.0);
        assert_eq!(parsed["ais_getbatch_prefetch_hits_total"], 1.0);
        assert_eq!(parsed["ais_getbatch_prefetch_wasted_total"], 1.0);
        assert_eq!(parsed["ais_getbatch_prefetch_horizon"], 2.0);
    }

    #[test]
    fn tenant_lines_render_per_tenant() {
        let m = GetBatchMetrics::default();
        assert!(!m.render("t0").contains("tenant_resident_bytes"), "no lines before any tenant");
        m.tenant_admit("alpha");
        m.tenant_admit("alpha");
        m.tenant_shed("beta");
        m.tenant_resident_add("alpha", 4096);
        m.tenant_resident_add("alpha", -1024);
        m.tenant_throttle_add("beta", 500);
        let text = m.render("t0");
        assert!(
            text.contains("ais_getbatch_tenant_resident_bytes{node=\"t0\",tenant=\"alpha\"} 3072"),
            "{text}"
        );
        assert!(
            text.contains("ais_getbatch_tenant_admits_total{node=\"t0\",tenant=\"alpha\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("ais_getbatch_tenant_sheds_total{node=\"t0\",tenant=\"beta\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("ais_getbatch_tenant_throttle_ns_total{node=\"t0\",tenant=\"beta\"} 500"),
            "{text}"
        );
        // Touching one line creates the tenant's whole family (zeros).
        assert!(
            text.contains("ais_getbatch_tenant_admits_total{node=\"t0\",tenant=\"beta\"} 0"),
            "{text}"
        );
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::new();
        r.node("a").work_items.inc();
        r.node("a").work_items.inc();
        assert_eq!(r.node("a").work_items.get(), 2);
        assert_eq!(r.node("b").work_items.get(), 0);
        let all = r.render_all();
        assert!(all.contains("node=\"a\"") && all.contains("node=\"b\""));
    }
}
