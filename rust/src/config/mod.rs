//! Configuration (§2.4.3): cluster topology knobs plus the dedicated
//! GetBatch section governing execution under load — sender wait timeout,
//! GFN recovery attempts, soft-error budget, read-ahead workers, and the
//! admission-control thresholds. JSON on disk, derived defaults in code.

use std::time::Duration;

use crate::util::error as anyhow;
use crate::util::json::Value;

/// Per-bucket storage routing: which backend stack serves a bucket's
/// objects on every target. The default (no spec) is the node's local
/// mountpath backend, uncached.
#[derive(Debug, Clone, PartialEq)]
pub struct BucketSpec {
    /// Bucket name.
    pub name: String,
    /// Backend kind: `"local"` or `"remote"`.
    pub backend: String,
    /// `host:port` endpoints of the nodes (or proxies) fronting a remote
    /// bucket; unused for local. All endpoints must serve the same data
    /// (replicated front) — reads select among the healthy ones and fail
    /// over on endpoint faults (`endpoint_failure_limit`,
    /// `endpoint_probe_ms`). Buckets whose endpoints are only known at
    /// runtime (ephemeral ports) are routed via
    /// `Cluster::route_remote_bucket` instead.
    pub remote_addrs: Vec<String>,
    /// Route reads through the node's read-through chunk cache
    /// (`cache_bytes` capacity, `readahead_chunks` sequential read-ahead).
    pub cache: bool,
}

impl BucketSpec {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("name", Value::str(&self.name))
            .set("backend", Value::str(&self.backend))
            .set(
                "remote_addrs",
                Value::Arr(self.remote_addrs.iter().map(|a| Value::str(a)).collect()),
            )
            .set("cache", Value::Bool(self.cache))
    }

    pub fn from_json(v: &Value) -> Option<BucketSpec> {
        // `remote_addrs` (list) is canonical; the pre-failover scalar
        // `remote_addr` is still accepted from older config files.
        let mut addrs: Vec<String> = v
            .get("remote_addrs")
            .and_then(|a| a.as_arr())
            .map(|xs| xs.iter().filter_map(|x| x.as_str().map(|s| s.to_string())).collect())
            .unwrap_or_default();
        if addrs.is_empty() {
            if let Some(a) = v.str_field("remote_addr") {
                if !a.is_empty() {
                    addrs.push(a.to_string());
                }
            }
        }
        Some(BucketSpec {
            name: v.str_field("name")?.to_string(),
            backend: v.str_field("backend").unwrap_or("local").to_string(),
            remote_addrs: addrs,
            cache: v.bool_field("cache").unwrap_or(false),
        })
    }
}

/// One tenant's weight in the DT fair-share ledger (see
/// `dt::admission::TenantLedger`): a tenant's resident-bytes share of the
/// data-plane budget is proportional to its weight over the sum of the
/// *active* tenants' weights. Tenants not listed weigh 1.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantWeight {
    /// Tenant name as carried by the `x-getbatch-tenant` header.
    pub tenant: String,
    /// Relative weight; clamped to ≥ 1 by `GetBatchConfig::sanitized`.
    pub weight: u64,
}

impl TenantWeight {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("tenant", Value::str(&self.tenant))
            .set("weight", Value::num(self.weight as f64))
    }

    pub fn from_json(v: &Value) -> Option<TenantWeight> {
        Some(TenantWeight {
            tenant: v.str_field("tenant")?.to_string(),
            weight: v.u64_field("weight").unwrap_or(1),
        })
    }
}

/// The paper's dedicated GetBatch configuration section (§2.4.3).
#[derive(Debug, Clone, PartialEq)]
pub struct GetBatchConfig {
    /// Max time the DT waits for a remote sender before initiating recovery.
    pub sender_wait: Duration,
    /// Get-from-neighbor recovery attempts permitted per request.
    pub gfn_attempts: u32,
    /// Max tolerated soft errors per request (continue-on-error budget).
    pub max_soft_errs: u32,
    /// Background read-ahead workers warming the page cache for upcoming
    /// local reads.
    pub readahead_workers: usize,
    /// Admission control: reject new work (HTTP 429) when DT-buffered bytes
    /// exceed this (memory is a *hard* constraint).
    pub mem_critical_bytes: u64,
    /// Throttling: start inserting calibrated sleeps when in-flight DT work
    /// items exceed this watermark (CPU/disk pressure proxy).
    pub throttle_watermark: i64,
    /// Base throttle sleep; scales with overload factor.
    pub throttle_base: Duration,
    /// Streaming chunk size: senders split entries larger than this into
    /// chunk frames (`proto::frame` FIRST/LAST flags) so the DT can emit an
    /// entry before its last byte arrives and bound its memory. Smaller
    /// chunks mean tighter memory bounds and earlier time-to-first-byte;
    /// larger chunks mean fewer frames on the wire. Keep
    /// `dt_buffer_bytes ≥ 2 × chunk_bytes` (see below).
    pub chunk_bytes: usize,
    /// DT data-plane memory budget: the *enforced* cap on bytes resident in
    /// a target's reorder buffers. Producers (P2P dispatch, DT-local reads)
    /// block once the budget is exhausted, which propagates as TCP
    /// backpressure to senders; peak residency stays ≤ this value provided
    /// it is at least `2 × chunk_bytes` (see `dt::admission::MemoryBudget`
    /// for the exact bound and the head-of-line progress exemption).
    pub dt_buffer_bytes: u64,
    /// How long a producer may block on a full memory budget before being
    /// force-admitted (the liveness valve; each forced admission is counted
    /// as a budget *overrun*). Ranged GFN recovery does NOT pay this per
    /// chunk — as the head-of-line consumer it takes the progress exemption
    /// after a brief grace (see `MemoryBudget::reserve_for_recovery`).
    pub budget_patience: Duration,
    /// Admission control: reject new registrations (HTTP 429) when at least
    /// this many budget overruns happened since the previous registration —
    /// overruns mean the data plane is already past its memory cap, so new
    /// work would only deepen the hole. `0` disables the overrun gate.
    pub budget_overrun_limit: u32,
    /// Capacity of each target's read-through chunk cache, in bytes. The
    /// cache serves `chunk_bytes`-aligned object chunks with strict LRU
    /// eviction; `0` disables caching even for buckets that request it.
    pub cache_bytes: u64,
    /// Sequential read-ahead: on a cache miss, also fetch this many
    /// *following* chunks through one ranged read of the inner backend
    /// (clamped so one fill never exceeds `dt_buffer_bytes`).
    pub readahead_chunks: usize,
    /// Epoch prefetch: how many *future* batches the client-side batch
    /// planner may warm into the chunk cache while the current batch
    /// streams (`0` disables prefetch). Bounded by `cache_bytes`: the
    /// sanitizer clamps it so the worst-case prefetch footprint — one
    /// read-ahead fill span of `(readahead_chunks + 1) × chunk_bytes` per
    /// prefetched batch — always fits inside the cache alongside the
    /// demand path's own fills. Prefetch reserves against `cache_bytes`
    /// only, never against `dt_buffer_bytes`.
    pub prefetch_batches: usize,
    /// Cache coherence: how long the chunk cache trusts remembered
    /// per-object metadata (length + write generation) before an open
    /// re-probes the inner backend. Within the grace, cross-node coherence
    /// relies on the best-effort `/v1/invalidate` broadcast; past it,
    /// versioned chunk keys are the correctness backstop for a node that
    /// missed the broadcast. `0` revalidates on every open (strongest
    /// coherence, one metadata probe per open); larger values trade
    /// staleness-after-missed-broadcast for fewer probes.
    pub coherence_grace: Duration,
    /// Remote endpoint circuit breaker: this many *consecutive* failed
    /// operations mark an endpoint unhealthy (reads stop selecting it
    /// while healthy peers remain). Clamped to ≥ 1.
    pub endpoint_failure_limit: u32,
    /// How often an unhealthy remote endpoint is re-tried: the interval
    /// between active `/v1/health` probes and between half-open trial
    /// admissions of live traffic (also the interval between slow trials
    /// of a latency-deprioritized endpoint). Smaller means faster recovery
    /// after an endpoint returns, at the cost of more probe traffic.
    pub endpoint_probe: Duration,
    /// Tail latency: a healthy remote endpoint whose ranged-read latency
    /// EWMA exceeds this is deprioritized — sorted after every faster
    /// healthy peer, its circuit NOT opened — and re-tried once per
    /// `endpoint_probe_ms` (slow trial) so it recovers when it speeds up.
    /// `0` disables slow-endpoint deprioritization.
    pub endpoint_slow: Duration,
    /// Tail latency: hedge a ranged read once its first byte outlives this
    /// quantile of the serving endpoint's own latency histogram (e.g.
    /// `0.95` = hedge past the endpoint's P95). `0.0` disables hedged
    /// reads.
    pub hedge_quantile: f64,
    /// Tail latency: floor under the hedge trigger — never hedge before
    /// this much wall time, even while the latency histogram is cold or
    /// the endpoint is very fast.
    pub hedge_min: Duration,
    /// Tail latency: cap on concurrent hedge attempts per remote backend,
    /// bounding the extra load hedging can add during a brown-out. `0`
    /// disables hedged reads.
    pub hedge_max_inflight: usize,
    /// Per-bucket backend routing (see [`BucketSpec`]); buckets not listed
    /// are served by the node's local backend, uncached.
    pub buckets: Vec<BucketSpec>,
    /// Multi-tenant QoS: per-tenant weights for the DT fair-share ledger
    /// (see [`TenantWeight`]). Empty means every tenant weighs 1 (equal
    /// shares among active tenants).
    pub tenant_weights: Vec<TenantWeight>,
    /// Priority class assumed for registrations that carry none
    /// (`"interactive"`, `"batch"`, or `"bulk"`); legacy clients land
    /// here. Invalid values sanitize back to the default.
    pub default_priority: String,
}

impl Default for GetBatchConfig {
    fn default() -> Self {
        GetBatchConfig {
            sender_wait: Duration::from_secs(10),
            gfn_attempts: 2,
            max_soft_errs: 32,
            readahead_workers: 2,
            mem_critical_bytes: 512 << 20,
            throttle_watermark: 64,
            throttle_base: Duration::from_micros(200),
            chunk_bytes: 256 << 10,
            dt_buffer_bytes: 256 << 20,
            budget_patience: Duration::from_secs(10),
            budget_overrun_limit: 4,
            cache_bytes: 64 << 20,
            readahead_chunks: 2,
            prefetch_batches: 1,
            coherence_grace: Duration::from_millis(500),
            endpoint_failure_limit: 3,
            endpoint_probe: Duration::from_millis(1000),
            endpoint_slow: Duration::from_millis(500),
            hedge_quantile: 0.95,
            hedge_min: Duration::from_millis(25),
            hedge_max_inflight: 32,
            buckets: Vec::new(),
            tenant_weights: Vec::new(),
            default_priority: "batch".to_string(),
        }
    }
}

impl GetBatchConfig {
    /// Clamp dependent knobs into safe relationships: the memory-budget
    /// bound (see `dt::admission::MemoryBudget`) needs
    /// `chunk_bytes ≤ dt_buffer_bytes / 2`. Called at cluster boot so a
    /// misconfiguration degrades to smaller chunks instead of collapsing
    /// the data path into patience-timeout force admissions.
    pub fn sanitized(&self) -> GetBatchConfig {
        let mut c = self.clone();
        c.dt_buffer_bytes = c.dt_buffer_bytes.max(2);
        let max_chunk = (c.dt_buffer_bytes / 2).min(usize::MAX as u64) as usize;
        c.chunk_bytes = c.chunk_bytes.clamp(1, max_chunk);
        // One read-ahead fill spans (readahead_chunks + 1) chunks; clamp it
        // so a single fill can never out-size the node's data-plane budget.
        let max_ra = (c.dt_buffer_bytes / c.chunk_bytes as u64).saturating_sub(1) as usize;
        c.readahead_chunks = c.readahead_chunks.min(max_ra);
        // Prefetch reserves against the *cache*, never the DT budget: the
        // horizon's worst-case footprint (one read-ahead fill span per
        // prefetched batch, each span (readahead_chunks + 1) chunks) must
        // fit inside `cache_bytes`, or prefetch would evict the very
        // chunks the demand path is about to read. With caching disabled
        // there is nowhere to prefetch into.
        let span_bytes = (c.readahead_chunks as u64 + 1) * c.chunk_bytes as u64;
        let max_pf = (c.cache_bytes / span_bytes).min(usize::MAX as u64) as usize;
        c.prefetch_batches = c.prefetch_batches.min(max_pf);
        // A failure limit of 0 would open endpoint circuits spontaneously,
        // and a zero probe interval would disable trial/probe rate-limiting
        // (every operation would lead with a broken endpoint and spawn a
        // probe thread).
        c.endpoint_failure_limit = c.endpoint_failure_limit.max(1);
        c.endpoint_probe = c.endpoint_probe.max(Duration::from_millis(10));
        // A hedge quantile outside [0, 1] (or NaN from a hand-edited file)
        // would either hedge every read instantly or never; clamp it, and
        // keep a non-zero floor so a cold histogram can't trigger
        // zero-delay hedges.
        if !c.hedge_quantile.is_finite() {
            c.hedge_quantile = GetBatchConfig::default().hedge_quantile;
        }
        c.hedge_quantile = c.hedge_quantile.clamp(0.0, 1.0);
        c.hedge_min = c.hedge_min.max(Duration::from_millis(1));
        // A zero tenant weight would starve that tenant outright (its fair
        // share collapses to the chunk floor even on an idle node) — clamp
        // to the implicit default weight instead.
        for tw in &mut c.tenant_weights {
            tw.weight = tw.weight.max(1);
        }
        // An unknown default class would make every legacy registration
        // unclassifiable; fall back to the stock default.
        if crate::dt::admission::Priority::parse(&c.default_priority).is_none() {
            c.default_priority = GetBatchConfig::default().default_priority;
        }
        c
    }

    /// Tenant-weights list as the map the fair-share ledger consumes.
    pub fn tenant_weight_map(&self) -> std::collections::BTreeMap<String, u64> {
        self.tenant_weights.iter().map(|tw| (tw.tenant.clone(), tw.weight.max(1))).collect()
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("sender_wait_ms", Value::num(self.sender_wait.as_millis() as f64))
            .set("gfn_attempts", Value::num(self.gfn_attempts as f64))
            .set("max_soft_errs", Value::num(self.max_soft_errs as f64))
            .set("readahead_workers", Value::num(self.readahead_workers as f64))
            .set("mem_critical_bytes", Value::num(self.mem_critical_bytes as f64))
            .set("throttle_watermark", Value::num(self.throttle_watermark as f64))
            .set("throttle_base_us", Value::num(self.throttle_base.as_micros() as f64))
            .set("chunk_bytes", Value::num(self.chunk_bytes as f64))
            .set("dt_buffer_bytes", Value::num(self.dt_buffer_bytes as f64))
            .set("budget_patience_ms", Value::num(self.budget_patience.as_millis() as f64))
            .set("budget_overrun_limit", Value::num(self.budget_overrun_limit as f64))
            .set("cache_bytes", Value::num(self.cache_bytes as f64))
            .set("readahead_chunks", Value::num(self.readahead_chunks as f64))
            .set("prefetch_batches", Value::num(self.prefetch_batches as f64))
            .set("coherence_grace_ms", Value::num(self.coherence_grace.as_millis() as f64))
            .set("endpoint_failure_limit", Value::num(self.endpoint_failure_limit as f64))
            .set("endpoint_probe_ms", Value::num(self.endpoint_probe.as_millis() as f64))
            .set("endpoint_slow_ms", Value::num(self.endpoint_slow.as_millis() as f64))
            .set("hedge_quantile", Value::num(self.hedge_quantile))
            .set("hedge_min_ms", Value::num(self.hedge_min.as_millis() as f64))
            .set("hedge_max_inflight", Value::num(self.hedge_max_inflight as f64))
            .set("buckets", Value::Arr(self.buckets.iter().map(BucketSpec::to_json).collect()))
            .set(
                "tenant_weights",
                Value::Arr(self.tenant_weights.iter().map(TenantWeight::to_json).collect()),
            )
            .set("default_priority", Value::str(&self.default_priority))
    }

    pub fn from_json(v: &Value) -> GetBatchConfig {
        let d = GetBatchConfig::default();
        GetBatchConfig {
            sender_wait: v
                .u64_field("sender_wait_ms")
                .map(Duration::from_millis)
                .unwrap_or(d.sender_wait),
            gfn_attempts: v.u64_field("gfn_attempts").map(|x| x as u32).unwrap_or(d.gfn_attempts),
            max_soft_errs: v.u64_field("max_soft_errs").map(|x| x as u32).unwrap_or(d.max_soft_errs),
            readahead_workers: v
                .u64_field("readahead_workers")
                .map(|x| x as usize)
                .unwrap_or(d.readahead_workers),
            mem_critical_bytes: v.u64_field("mem_critical_bytes").unwrap_or(d.mem_critical_bytes),
            throttle_watermark: v
                .u64_field("throttle_watermark")
                .map(|x| x as i64)
                .unwrap_or(d.throttle_watermark),
            throttle_base: v
                .u64_field("throttle_base_us")
                .map(Duration::from_micros)
                .unwrap_or(d.throttle_base),
            chunk_bytes: v.u64_field("chunk_bytes").map(|x| x as usize).unwrap_or(d.chunk_bytes),
            dt_buffer_bytes: v.u64_field("dt_buffer_bytes").unwrap_or(d.dt_buffer_bytes),
            budget_patience: v
                .u64_field("budget_patience_ms")
                .map(Duration::from_millis)
                .unwrap_or(d.budget_patience),
            budget_overrun_limit: v
                .u64_field("budget_overrun_limit")
                .map(|x| x as u32)
                .unwrap_or(d.budget_overrun_limit),
            cache_bytes: v.u64_field("cache_bytes").unwrap_or(d.cache_bytes),
            readahead_chunks: v
                .u64_field("readahead_chunks")
                .map(|x| x as usize)
                .unwrap_or(d.readahead_chunks),
            prefetch_batches: v
                .u64_field("prefetch_batches")
                .map(|x| x as usize)
                .unwrap_or(d.prefetch_batches),
            coherence_grace: v
                .u64_field("coherence_grace_ms")
                .map(Duration::from_millis)
                .unwrap_or(d.coherence_grace),
            endpoint_failure_limit: v
                .u64_field("endpoint_failure_limit")
                .map(|x| x as u32)
                .unwrap_or(d.endpoint_failure_limit),
            endpoint_probe: v
                .u64_field("endpoint_probe_ms")
                .map(Duration::from_millis)
                .unwrap_or(d.endpoint_probe),
            endpoint_slow: v
                .u64_field("endpoint_slow_ms")
                .map(Duration::from_millis)
                .unwrap_or(d.endpoint_slow),
            hedge_quantile: v
                .get("hedge_quantile")
                .and_then(|x| x.as_f64())
                .unwrap_or(d.hedge_quantile),
            hedge_min: v.u64_field("hedge_min_ms").map(Duration::from_millis).unwrap_or(d.hedge_min),
            hedge_max_inflight: v
                .u64_field("hedge_max_inflight")
                .map(|x| x as usize)
                .unwrap_or(d.hedge_max_inflight),
            buckets: v
                .get("buckets")
                .and_then(|b| b.as_arr())
                .map(|specs| specs.iter().filter_map(BucketSpec::from_json).collect())
                .unwrap_or(d.buckets),
            tenant_weights: v
                .get("tenant_weights")
                .and_then(|b| b.as_arr())
                .map(|specs| specs.iter().filter_map(TenantWeight::from_json).collect())
                .unwrap_or(d.tenant_weights),
            default_priority: v
                .str_field("default_priority")
                .map(|s| s.to_string())
                .unwrap_or(d.default_priority),
        }
    }
}

/// Whole-cluster configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of target (storage) nodes.
    pub targets: usize,
    /// Number of proxy (gateway) nodes.
    pub proxies: usize,
    /// Simulated mountpaths (disks) per target.
    pub mountpaths: usize,
    /// Minimum request-handler worker threads per node. Handlers may block
    /// (memory budget, nested intra-cluster calls), so the pool is elastic
    /// above this floor; it no longer bounds connection concurrency.
    pub http_workers: usize,
    /// Event-loop threads per node reactor. Connections hold no thread, so
    /// a couple of loops multiplex thousands of sockets; raise only when a
    /// loop core saturates on epoll/copy work.
    pub reactor_threads: usize,
    /// Per-node registered-connection cap. Accepts beyond it are shed
    /// immediately (counted by `accept_backlog_shed_total`) instead of
    /// letting untracked sockets exhaust fds/memory.
    pub max_connections: usize,
    /// Root directory for node stores (a temp dir when empty).
    pub root_dir: String,
    /// Idle P2P connection reclaim timeout (§2.3.1 "idle connections
    /// reclaimed after a configurable timeout").
    pub p2p_idle_timeout: Duration,
    pub getbatch: GetBatchConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            targets: 4,
            proxies: 1,
            mountpaths: 2,
            http_workers: 8,
            reactor_threads: 2,
            max_connections: 4096,
            root_dir: String::new(),
            p2p_idle_timeout: Duration::from_secs(30),
            getbatch: GetBatchConfig::default(),
        }
    }
}

impl ClusterConfig {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("targets", Value::num(self.targets as f64))
            .set("proxies", Value::num(self.proxies as f64))
            .set("mountpaths", Value::num(self.mountpaths as f64))
            .set("http_workers", Value::num(self.http_workers as f64))
            .set("reactor_threads", Value::num(self.reactor_threads as f64))
            .set("max_connections", Value::num(self.max_connections as f64))
            .set("root_dir", Value::str(&self.root_dir))
            .set("p2p_idle_timeout_ms", Value::num(self.p2p_idle_timeout.as_millis() as f64))
            .set("getbatch", self.getbatch.to_json())
    }

    pub fn from_json(v: &Value) -> ClusterConfig {
        let d = ClusterConfig::default();
        ClusterConfig {
            targets: v.u64_field("targets").map(|x| x as usize).unwrap_or(d.targets),
            proxies: v.u64_field("proxies").map(|x| x as usize).unwrap_or(d.proxies),
            mountpaths: v.u64_field("mountpaths").map(|x| x as usize).unwrap_or(d.mountpaths),
            http_workers: v.u64_field("http_workers").map(|x| x as usize).unwrap_or(d.http_workers),
            reactor_threads: v
                .u64_field("reactor_threads")
                .map(|x| x as usize)
                .unwrap_or(d.reactor_threads),
            max_connections: v
                .u64_field("max_connections")
                .map(|x| x as usize)
                .unwrap_or(d.max_connections),
            root_dir: v.str_field("root_dir").unwrap_or("").to_string(),
            p2p_idle_timeout: v
                .u64_field("p2p_idle_timeout_ms")
                .map(Duration::from_millis)
                .unwrap_or(d.p2p_idle_timeout),
            getbatch: v.get("getbatch").map(GetBatchConfig::from_json).unwrap_or(d.getbatch),
        }
    }

    pub fn load(path: &str) -> anyhow::Result<ClusterConfig> {
        let text = std::fs::read_to_string(path)?;
        Ok(ClusterConfig::from_json(&Value::parse(&text)?))
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = ClusterConfig::default();
        assert!(c.targets >= 1 && c.mountpaths >= 1);
        assert!(c.getbatch.gfn_attempts > 0);
        assert!(c.getbatch.mem_critical_bytes > 0);
        // Streaming invariant: the budget must fit the head-of-line
        // exemption chunk on top of the admission cap.
        assert!(c.getbatch.dt_buffer_bytes >= 2 * c.getbatch.chunk_bytes as u64);
    }

    #[test]
    fn sanitized_clamps_chunk_to_half_budget() {
        let c = GetBatchConfig {
            chunk_bytes: 1 << 20,
            dt_buffer_bytes: 512 << 10,
            ..Default::default()
        }
        .sanitized();
        assert_eq!(c.chunk_bytes, 256 << 10, "chunk clamped to budget/2");
        let ok = GetBatchConfig::default().sanitized();
        assert_eq!(ok.chunk_bytes, GetBatchConfig::default().chunk_bytes, "defaults untouched");
        let degenerate = GetBatchConfig { chunk_bytes: 0, dt_buffer_bytes: 0, ..Default::default() }
            .sanitized();
        assert!(degenerate.chunk_bytes >= 1);
        assert!(degenerate.dt_buffer_bytes >= 2 * degenerate.chunk_bytes as u64);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ClusterConfig::default();
        c.targets = 16;
        c.reactor_threads = 3;
        c.max_connections = 777;
        c.getbatch.max_soft_errs = 5;
        c.getbatch.sender_wait = Duration::from_millis(1234);
        c.getbatch.budget_patience = Duration::from_millis(2500);
        c.getbatch.budget_overrun_limit = 9;
        c.getbatch.cache_bytes = 8 << 20;
        c.getbatch.readahead_chunks = 5;
        c.getbatch.prefetch_batches = 3;
        c.getbatch.coherence_grace = Duration::from_millis(125);
        c.getbatch.endpoint_failure_limit = 7;
        c.getbatch.endpoint_probe = Duration::from_millis(250);
        c.getbatch.endpoint_slow = Duration::from_millis(350);
        c.getbatch.hedge_quantile = 0.5; // exact in binary: roundtrips verbatim
        c.getbatch.hedge_min = Duration::from_millis(7);
        c.getbatch.hedge_max_inflight = 3;
        c.getbatch.buckets = vec![
            BucketSpec {
                name: "hot".into(),
                backend: "remote".into(),
                remote_addrs: vec!["10.0.0.7:8080".into(), "10.0.0.8:8080".into()],
                cache: true,
            },
            BucketSpec {
                name: "cold".into(),
                backend: "local".into(),
                remote_addrs: Vec::new(),
                cache: false,
            },
        ];
        c.getbatch.tenant_weights = vec![
            TenantWeight { tenant: "trainer-a".into(), weight: 3 },
            TenantWeight { tenant: "trainer-b".into(), weight: 1 },
        ];
        c.getbatch.default_priority = "bulk".into();
        let back = ClusterConfig::from_json(&c.to_json());
        assert_eq!(back, c);
    }

    #[test]
    fn legacy_scalar_remote_addr_still_parses() {
        let v = Value::parse(
            r#"{"name": "hot", "backend": "remote", "remote_addr": "10.0.0.7:8080"}"#,
        )
        .unwrap();
        let spec = BucketSpec::from_json(&v).unwrap();
        assert_eq!(spec.remote_addrs, vec!["10.0.0.7:8080".to_string()]);
    }

    #[test]
    fn sanitized_clamps_endpoint_knobs() {
        let c = GetBatchConfig {
            endpoint_failure_limit: 0,
            endpoint_probe: Duration::ZERO,
            hedge_quantile: 7.5,
            hedge_min: Duration::ZERO,
            ..Default::default()
        }
        .sanitized();
        assert_eq!(c.endpoint_failure_limit, 1);
        assert!(c.endpoint_probe >= Duration::from_millis(10));
        assert_eq!(c.hedge_quantile, 1.0, "quantile clamped into [0, 1]");
        assert!(c.hedge_min >= Duration::from_millis(1));
        let nan = GetBatchConfig { hedge_quantile: f64::NAN, ..Default::default() }.sanitized();
        assert_eq!(nan.hedge_quantile, GetBatchConfig::default().hedge_quantile);
        let off = GetBatchConfig { hedge_quantile: 0.0, ..Default::default() }.sanitized();
        assert_eq!(off.hedge_quantile, 0.0, "0 stays 0: hedging disabled is respected");
        let ok = GetBatchConfig::default().sanitized();
        assert_eq!(ok.endpoint_probe, GetBatchConfig::default().endpoint_probe);
        assert_eq!(ok.hedge_quantile, GetBatchConfig::default().hedge_quantile);
    }

    #[test]
    fn sanitized_clamps_readahead_to_budget() {
        let c = GetBatchConfig {
            chunk_bytes: 64 << 10,
            dt_buffer_bytes: 256 << 10, // 4 chunks
            readahead_chunks: 64,
            ..Default::default()
        }
        .sanitized();
        assert_eq!(c.readahead_chunks, 3, "fill of ra+1 chunks fits the budget");
        let ok = GetBatchConfig::default().sanitized();
        assert_eq!(ok.readahead_chunks, GetBatchConfig::default().readahead_chunks);
    }

    #[test]
    fn sanitized_clamps_prefetch_to_cache() {
        // Cache holds 4 chunks; read-ahead span is 2 chunks ⇒ at most two
        // prefetched-batch spans fit alongside each other.
        let c = GetBatchConfig {
            chunk_bytes: 64 << 10,
            dt_buffer_bytes: 1 << 20,
            cache_bytes: 256 << 10,
            readahead_chunks: 1,
            prefetch_batches: 16,
            ..Default::default()
        }
        .sanitized();
        assert_eq!(c.prefetch_batches, 2, "horizon clamped so spans fit cache_bytes");
        // Caching disabled ⇒ nowhere to prefetch into.
        let off = GetBatchConfig { cache_bytes: 0, prefetch_batches: 4, ..Default::default() }
            .sanitized();
        assert_eq!(off.prefetch_batches, 0);
        // The cross-clamp composes with the readahead clamp: a huge
        // readahead is first clamped to the DT budget, and the prefetch
        // bound uses the *clamped* span size.
        let cross = GetBatchConfig {
            chunk_bytes: 64 << 10,
            dt_buffer_bytes: 256 << 10, // readahead clamps to 3
            cache_bytes: 512 << 10,     // 8 chunks / 4-chunk span = 2
            readahead_chunks: 64,
            prefetch_batches: 64,
            ..Default::default()
        }
        .sanitized();
        assert_eq!(cross.readahead_chunks, 3);
        assert_eq!(cross.prefetch_batches, 2);
        // Defaults untouched.
        let ok = GetBatchConfig::default().sanitized();
        assert_eq!(ok.prefetch_batches, GetBatchConfig::default().prefetch_batches);
    }

    #[test]
    fn sanitized_clamps_tenant_qos_knobs() {
        let c = GetBatchConfig {
            tenant_weights: vec![
                TenantWeight { tenant: "a".into(), weight: 0 },
                TenantWeight { tenant: "b".into(), weight: 5 },
            ],
            default_priority: "turbo".into(),
            ..Default::default()
        }
        .sanitized();
        assert_eq!(c.tenant_weights[0].weight, 1, "zero weight clamped to 1");
        assert_eq!(c.tenant_weights[1].weight, 5);
        assert_eq!(c.default_priority, "batch", "unknown class falls back to default");
        let m = c.tenant_weight_map();
        assert_eq!(m["a"], 1);
        assert_eq!(m["b"], 5);
        let ok = GetBatchConfig { default_priority: "interactive".into(), ..Default::default() }
            .sanitized();
        assert_eq!(ok.default_priority, "interactive", "valid classes untouched");
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = Value::parse(r#"{"targets": 8}"#).unwrap();
        let c = ClusterConfig::from_json(&v);
        assert_eq!(c.targets, 8);
        assert_eq!(c.proxies, ClusterConfig::default().proxies);
        assert_eq!(c.getbatch, GetBatchConfig::default());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gbcfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        let c = ClusterConfig { targets: 3, ..Default::default() };
        c.save(p.to_str().unwrap()).unwrap();
        let back = ClusterConfig::load(p.to_str().unwrap()).unwrap();
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
