//! Proxy request handling (§2.3.1).
//!
//! Object GET/PUT: 307-redirect to the HRW owner target (the AIStore
//! pattern — the proxy never touches data).
//!
//! GetBatch: (1) select the DT — by default *opaquely*, without unmarshaling
//! the potentially large entry list (a pseudo-random pick via the request
//! sequence number); with the `coloc` query parameter, unmarshal and pick
//! the target owning the most entries (§2.4.1); (2) register the execution
//! with the DT; (3) broadcast sender activation to all other targets; then
//! redirect the client to the DT's stream endpoint.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::batch::request::BatchRequest;
use crate::cluster::placement;
use crate::cluster::smap::Smap;
use crate::metrics::GetBatchMetrics;
use crate::proto::http::{Handler, HttpClient, Request, Response};
use crate::proto::wire::{self, paths, DtRegister, SenderActivate};
use crate::transport::reactor::WorkerPool;
use crate::util::rng::mix64;

/// Late-bound cluster map: nodes boot before the full membership is known;
/// `set` is called once when the cluster finishes assembling.
#[derive(Default)]
pub struct SmapHolder(Mutex<Option<Arc<Smap>>>);

impl SmapHolder {
    pub fn new() -> Arc<SmapHolder> {
        Arc::new(SmapHolder::default())
    }
    pub fn set(&self, smap: Arc<Smap>) {
        *self.0.lock().unwrap() = Some(smap);
    }
    pub fn get(&self) -> Option<Arc<Smap>> {
        self.0.lock().unwrap().clone()
    }
}

pub struct ProxyState {
    pub id: String,
    pub smap: Arc<SmapHolder>,
    pub http: HttpClient,
    pub metrics: Arc<GetBatchMetrics>,
    /// Persistent elastic pool for broadcast legs (sender activation,
    /// invalidation): fan-out reuses pooled worker threads and the client's
    /// keep-alive connections instead of spawning a thread per leg.
    fanout: WorkerPool,
    req_seq: AtomicU64,
}

impl ProxyState {
    pub fn new(id: &str, smap: Arc<SmapHolder>, metrics: Arc<GetBatchMetrics>) -> Arc<ProxyState> {
        Arc::new(ProxyState {
            id: id.to_string(),
            smap,
            http: HttpClient::new(true),
            metrics,
            fanout: WorkerPool::new(2, &format!("{id}-fanout")),
            req_seq: AtomicU64::new(1),
        })
    }

    fn next_req_id(&self) -> u64 {
        // Mixed so consecutive requests land on "random" DTs — the paper's
        // default DT selection distributes serialization load cluster-wide.
        // Masked to 48 bits: req ids ride JSON numbers (f64), which carry
        // integers exactly only below 2^53.
        mix64(self.req_seq.fetch_add(1, Ordering::Relaxed) ^ crate::util::hrw::fnv1a(self.id.as_bytes()))
            & 0xFFFF_FFFF_FFFF
    }
}

/// Build the HTTP handler for a proxy node.
pub fn make_proxy_handler(st: Arc<ProxyState>) -> Handler {
    Arc::new(move |req: Request| route(&st, req))
}

/// Run `job(state, i)` for `0..n` on the proxy's shared fan-out worker pool
/// and sum the results. Replaces the old scoped thread-per-leg broadcast:
/// worker threads persist across requests (the pool grows under load and
/// retires back to its floor), and each leg rides the client's pooled
/// keep-alive connection to its target.
fn pooled_fanout_sum(
    st: &Arc<ProxyState>,
    n: usize,
    job: impl Fn(&ProxyState, usize) -> usize + Send + Sync + 'static,
) -> usize {
    let (tx, rx) = mpsc::channel();
    let job = Arc::new(job);
    for i in 0..n {
        let tx = tx.clone();
        let job = Arc::clone(&job);
        let stc = Arc::clone(st);
        st.fanout.execute(move || {
            let _ = tx.send(job(&stc, i));
        });
    }
    drop(tx);
    rx.iter().sum()
}

fn route(st: &Arc<ProxyState>, req: Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        (_, p) if p.starts_with(paths::OBJECTS) => route_object(st, req),
        ("GET", paths::BATCH) => route_batch(st, req),
        ("GET", paths::SMAP) => match st.smap.get() {
            Some(s) => Response::ok(s.to_json().to_string().into_bytes()),
            None => Response::text(503, "smap not ready"),
        },
        ("GET", paths::LIST) => route_list(st, req),
        ("POST", paths::INVALIDATE) => route_invalidate(st, req),
        ("POST", paths::PREFETCH) => route_prefetch(st, req),
        ("GET", paths::METRICS) => Response::ok(st.metrics.render(&st.id).into_bytes()),
        ("GET", paths::HEALTH) => Response::ok(b"ok".to_vec()),
        _ => Response::status(404),
    }
}

/// Bucket listing: fan out to every target (each holds its HRW slice of
/// the namespace) and merge — lets a remote store backend pointed at a
/// proxy list a whole cluster-backed bucket.
fn route_list(st: &ProxyState, req: Request) -> Response {
    let smap = match st.smap.get() {
        Some(s) => s,
        None => return Response::text(503, "smap not ready"),
    };
    let bucket = match req.query_param("bucket") {
        Some(b) => b,
        None => return Response::text(400, "missing bucket"),
    };
    let mut names: Vec<String> = Vec::new();
    for t in &smap.targets {
        let pq = format!("{}?bucket={bucket}", paths::LIST);
        match st.http.get(&t.http_addr, &pq) {
            Ok(resp) if resp.status == 200 => match resp.into_bytes() {
                Ok(body) => names.extend(
                    String::from_utf8_lossy(&body)
                        .lines()
                        .filter(|l| !l.is_empty())
                        .map(|l| l.to_string()),
                ),
                Err(e) => return Response::text(502, &format!("list {}: {e}", t.id)),
            },
            Ok(resp) => return Response::text(502, &format!("list {}: http {}", t.id, resp.status)),
            Err(e) => return Response::text(502, &format!("list {}: {e}", t.id)),
        }
    }
    names.sort();
    names.dedup();
    Response::ok(names.join("\n").into_bytes())
}

/// Cache-coherence invalidation, gateway side: fan
/// `POST /v1/invalidate?bucket=..&obj=..` out to every target in the smap —
/// how an external writer (one that mutated the underlying storage without
/// going through this cluster) tells a whole serving cluster to drop an
/// object's cached chunks with a single call. Best-effort like the
/// target-initiated broadcast: a target that misses it is corrected by
/// versioned-key revalidation after `coherence_grace_ms`, so delivery
/// failures degrade the window, never correctness — the response reports
/// the delivered/total count instead of failing the call.
fn route_invalidate(st: &Arc<ProxyState>, req: Request) -> Response {
    let smap = match st.smap.get() {
        Some(s) => s,
        None => return Response::text(503, "smap not ready"),
    };
    let (bucket, obj) = match (req.query_param("bucket"), req.query_param("obj")) {
        (Some(b), Some(o)) => (b.to_string(), o.to_string()),
        _ => return Response::text(400, "missing bucket/obj"),
    };
    st.metrics.invalidate_broadcasts.inc();
    let pq = format!("{}?bucket={bucket}&obj={obj}", paths::INVALIDATE);
    let n = smap.targets.len();
    let delivered = pooled_fanout_sum(st, n, move |st, i| {
        match st.http.request("POST", &smap.targets[i].http_addr, &pq, &[]) {
            Ok(resp) if resp.status == 200 => {
                let _ = resp.into_bytes();
                1usize
            }
            _ => 0usize,
        }
    });
    Response::ok(format!("invalidated on {delivered}/{n} targets").into_bytes())
}

/// Epoch prefetch → redirect to the object's HRW owner: the target that
/// will serve the predicted demand read (as sender or DT-local), so the
/// warmth lands in the one cache that matters. Same per-request hop shape
/// as `route_object`; the client follows the 307 with method+body intact.
fn route_prefetch(st: &ProxyState, req: Request) -> Response {
    let smap = match st.smap.get() {
        Some(s) => s,
        None => return Response::text(503, "smap not ready"),
    };
    let (bucket, obj) = match (req.query_param("bucket"), req.query_param("obj")) {
        (Some(b), Some(o)) => (b, o),
        _ => return Response::text(400, "missing bucket/obj"),
    };
    let owner = placement::owner(&smap, &format!("{bucket}/{obj}"));
    let target = &smap.targets[owner];
    let qs: Vec<String> = req.query.iter().map(|(k, v)| format!("{k}={v}")).collect();
    Response::redirect(&format!(
        "http://{}{}?{}",
        target.http_addr,
        paths::PREFETCH,
        qs.join("&")
    ))
}

/// Object GET/PUT → redirect to the HRW owner target (per-request hop that
/// the paper's baseline pays on every sample).
fn route_object(st: &ProxyState, req: Request) -> Response {
    let smap = match st.smap.get() {
        Some(s) => s,
        None => return Response::text(503, "smap not ready"),
    };
    let (bucket, obj) = match wire::parse_object_path(&req.path) {
        Some(x) => x,
        None => return Response::text(400, "bad object path"),
    };
    let owner = placement::owner(&smap, &format!("{bucket}/{obj}"));
    let target = &smap.targets[owner];
    let mut loc = format!("http://{}{}", target.http_addr, req.path);
    // Preserve the query string (archpath etc.).
    if !req.query.is_empty() {
        let qs: Vec<String> = req.query.iter().map(|(k, v)| format!("{k}={v}")).collect();
        loc.push('?');
        loc.push_str(&qs.join("&"));
    }
    Response::redirect(&loc)
}

/// The three-phase GetBatch flow.
fn route_batch(st: &Arc<ProxyState>, req: Request) -> Response {
    let smap = match st.smap.get() {
        Some(s) => s,
        None => return Response::text(503, "smap not ready"),
    };
    if smap.targets.is_empty() {
        return Response::text(503, "no targets");
    }
    let req_id = st.next_req_id();

    // --- DT selection -----------------------------------------------------
    // Opaque default: no unmarshal. Colocation hint: parse body, argmax of
    // per-target placement weights.
    let coloc = req.query_param(wire::QPARAM_COLOC).is_some();
    let dt_idx = if coloc {
        match BatchRequest::from_body(&req.body) {
            Some(parsed) => placement::colocated_dt(&smap, &parsed),
            None => return Response::text(400, "malformed batch request"),
        }
    } else {
        (req_id % smap.targets.len() as u64) as usize
    };
    let dt = &smap.targets[dt_idx];

    // Validate lazily only for the opaque path's registration forward: the
    // DT unmarshals anyway and replies 400 if the body is bad.
    let num_senders = (smap.targets.len() - 1) as u32;

    // --- Phase 1: DT registration ------------------------------------------
    let request = match BatchRequest::from_body(&req.body) {
        Some(r) => r,
        None => return Response::text(400, "malformed batch request"),
    };
    if request.entries.is_empty() {
        return Response::text(400, "empty batch");
    }
    // Splice the client's body verbatim into the control messages instead
    // of re-serializing the parsed entry list — saves two full JSON
    // serializations per request on the proxy hot path (§Perf).
    // Multi-tenant QoS identity rides on headers: tenant defaults for
    // legacy clients, priority is resolved (and defaulted) at the DT.
    let raw = std::str::from_utf8(&req.body).unwrap_or("{}");
    let tenant = req.header(wire::HDR_TENANT).unwrap_or(wire::DEFAULT_TENANT);
    let priority = req.header(wire::HDR_PRIORITY).unwrap_or("");
    let reg_body = DtRegister::body_with_raw_qos(req_id, num_senders, tenant, priority, raw);
    match st.http.request("POST", &dt.http_addr, paths::DT_REGISTER, &reg_body) {
        Ok(resp) if resp.status == 200 => {
            let _ = resp.into_bytes();
        }
        Ok(resp) if resp.status == 429 => {
            // Admission rejection at the DT propagates to the client
            // unchanged — including the DT's Retry-After hint, derived from
            // its budget patience — so it can back off and retry (§2.4.3).
            let mut out = Response::text(429, "DT admission: memory pressure");
            if let Some(ra) = resp.header("retry-after") {
                out.headers.push(("retry-after".to_string(), ra.to_string()));
            }
            return out;
        }
        Ok(resp) => return Response::text(500, &format!("dt-register failed: {}", resp.status)),
        Err(e) => return Response::text(500, &format!("dt-register io: {e}")),
    }

    // --- Phase 2: sender activation broadcast ------------------------------
    let _ = request; // validated above; broadcast reuses the raw body
    let body = SenderActivate::body_with_raw(req_id, &dt.p2p_addr, raw);
    let others: Vec<usize> = (0..smap.targets.len()).filter(|&i| i != dt_idx).collect();
    let failures = {
        let smap = Arc::clone(&smap);
        let others = others.clone();
        pooled_fanout_sum(st, others.len(), move |st, k| {
            let t = &smap.targets[others[k]];
            match st.http.request("POST", &t.http_addr, paths::SENDER_ACTIVATE, &body) {
                Ok(resp) if resp.status == 200 => {
                    let _ = resp.into_bytes();
                    0usize
                }
                _ => 1usize,
            }
        })
    };
    if failures > 0 {
        // Activation failures degrade to DT sender-wait timeouts + GFN;
        // surface in metrics but do not abort (§2.4.2).
        st.metrics.soft_errors.add(failures as u64);
    }

    // --- Phase 3: redirect client to the DT stream -------------------------
    Response::redirect(&format!(
        "http://{}{}?{}={}",
        dt.http_addr,
        paths::DT_STREAM,
        wire::QPARAM_REQ_ID,
        req_id
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchEntry;
    use crate::cluster::smap::NodeInfo;

    fn holder(n: usize) -> Arc<SmapHolder> {
        let h = SmapHolder::new();
        h.set(Arc::new(Smap::new(
            1,
            vec![],
            (0..n)
                .map(|i| NodeInfo {
                    id: format!("t{i}"),
                    http_addr: "127.0.0.1:1".into(),
                    p2p_addr: "127.0.0.1:2".into(),
                })
                .collect(),
        )));
        h
    }

    fn get(path: &str, body: &[u8]) -> Request {
        let (p, q) = match path.split_once('?') {
            Some((p, q)) => (p.to_string(), q),
            None => (path.to_string(), ""),
        };
        Request {
            method: "GET".into(),
            path: p,
            query: q
                .split('&')
                .filter(|s| !s.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => (kv.to_string(), "true".to_string()),
                })
                .collect(),
            headers: Default::default(),
            body: body.to_vec(),
            peer: None,
        }
    }

    #[test]
    fn object_get_redirects_to_owner() {
        let st = ProxyState::new("p0", holder(4), GetBatchMetrics::new());
        let resp = route(&st, get("/v1/objects/b/o1", &[]));
        assert_eq!(resp.status, 307);
        let loc = resp.headers.iter().find(|(k, _)| k == "location").unwrap().1.clone();
        assert!(loc.contains("/v1/objects/b/o1"), "{loc}");
    }

    #[test]
    fn malformed_batch_rejected() {
        let st = ProxyState::new("p0", holder(2), GetBatchMetrics::new());
        let resp = route(&st, get("/v1/batch", b"not json"));
        assert_eq!(resp.status, 400);
        let empty = BatchRequest::new(vec![]).to_body();
        let resp = route(&st, get("/v1/batch", &empty));
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn smap_endpoint() {
        let st = ProxyState::new("p0", holder(3), GetBatchMetrics::new());
        let resp = route(&st, get("/v1/cluster/smap", &[]));
        assert_eq!(resp.status, 200);
        match resp.body {
            crate::proto::http::Body::Bytes(b) => {
                let s = Smap::from_body(&b).unwrap();
                assert_eq!(s.targets.len(), 3);
            }
            _ => panic!("expected bytes"),
        }
    }

    #[test]
    fn smap_not_ready_is_503() {
        let st = ProxyState::new("p0", SmapHolder::new(), GetBatchMetrics::new());
        let body = BatchRequest::new(vec![BatchEntry::obj("b", "o")]).to_body();
        assert_eq!(route(&st, get("/v1/batch", &body)).status, 503);
        assert_eq!(route(&st, get("/v1/objects/b/o", &[])).status, 503);
    }

    #[test]
    fn list_fanout_5xx_surfaces_partial_failure() {
        use crate::proto::http::{Handler, HttpServer};
        use std::sync::atomic::{AtomicBool, Ordering};

        // Two live targets behind the proxy's /v1/list fan-out: one serves
        // its slice, the other can be flipped into a 5xx failure mode. A
        // failing target must surface as a partial-failure error (502) —
        // never as a silently truncated merged listing.
        let ok_handler: Handler = Arc::new(|req: Request| {
            assert_eq!(req.path, paths::LIST);
            Response::ok(b"obj-a\nobj-b".to_vec())
        });
        let broken = Arc::new(AtomicBool::new(false));
        let broken2 = Arc::clone(&broken);
        let flaky_handler: Handler = Arc::new(move |_req: Request| {
            if broken2.load(Ordering::Relaxed) {
                Response::text(500, "disk gone")
            } else {
                Response::ok(b"obj-c".to_vec())
            }
        });
        let t0 = HttpServer::serve(ok_handler, 2, "list-ok").unwrap();
        let t1 = HttpServer::serve(flaky_handler, 2, "list-flaky").unwrap();

        let h = SmapHolder::new();
        h.set(Arc::new(Smap::new(
            1,
            vec![],
            vec![
                NodeInfo {
                    id: "t0".into(),
                    http_addr: t0.addr.to_string(),
                    p2p_addr: String::new(),
                },
                NodeInfo {
                    id: "t1".into(),
                    http_addr: t1.addr.to_string(),
                    p2p_addr: String::new(),
                },
            ],
        )));
        let st = ProxyState::new("p0", h, GetBatchMetrics::new());

        // Healthy fan-out merges both slices.
        let resp = route(&st, get("/v1/list?bucket=b", &[]));
        assert_eq!(resp.status, 200);
        match resp.body {
            crate::proto::http::Body::Bytes(b) => {
                assert_eq!(String::from_utf8_lossy(&b), "obj-a\nobj-b\nobj-c");
            }
            _ => panic!("expected bytes"),
        }

        // One target 5xx: the whole listing fails loudly, naming the target.
        broken.store(true, Ordering::Relaxed);
        let resp = route(&st, get("/v1/list?bucket=b", &[]));
        assert_eq!(resp.status, 502, "partial failure must not truncate the merge");
        match resp.body {
            crate::proto::http::Body::Bytes(b) => {
                let msg = String::from_utf8_lossy(&b).into_owned();
                assert!(msg.contains("t1") && msg.contains("500"), "{msg}");
            }
            _ => panic!("expected bytes"),
        }
    }

    #[test]
    fn batch_429_propagates_retry_after() {
        use crate::proto::http::HttpServer;

        // A DT stub that rejects registration under memory pressure with a
        // Retry-After hint: the proxy must hand that hint to the client.
        let dt: Handler = Arc::new(|req: Request| {
            assert_eq!(req.path, paths::DT_REGISTER);
            let mut r = Response::text(429, "memory pressure");
            r.headers.push(("retry-after".into(), "3".into()));
            r
        });
        let dt_srv = HttpServer::serve(dt, 2, "dt-stub").unwrap();
        let h = SmapHolder::new();
        h.set(Arc::new(Smap::new(
            1,
            vec![],
            vec![NodeInfo {
                id: "t0".into(),
                http_addr: dt_srv.addr.to_string(),
                p2p_addr: String::new(),
            }],
        )));
        let st = ProxyState::new("p0", h, GetBatchMetrics::new());
        let body = BatchRequest::new(vec![BatchEntry::obj("b", "o")]).to_body();
        let resp = route(&st, get("/v1/batch", &body));
        assert_eq!(resp.status, 429);
        let ra = resp.headers.iter().find(|(k, _)| k == "retry-after");
        assert_eq!(ra.map(|(_, v)| v.as_str()), Some("3"), "Retry-After propagated");
    }

    #[test]
    fn batch_registration_carries_tenant_and_priority() {
        use crate::proto::http::HttpServer;

        // DT stub capturing each parsed registration: the proxy must splice
        // the client's QoS headers into the register body, and default the
        // tenant for legacy clients that send none.
        let seen: Arc<Mutex<Vec<(String, String)>>> = Arc::new(Mutex::new(Vec::new()));
        let seen2 = Arc::clone(&seen);
        let dt: Handler = Arc::new(move |req: Request| {
            let reg = DtRegister::from_body(&req.body).expect("parseable register body");
            seen2.lock().unwrap().push((reg.tenant, reg.priority));
            // 500 stops route_batch before activation/redirect.
            Response::text(500, "stub")
        });
        let dt_srv = HttpServer::serve(dt, 2, "dt-qos-stub").unwrap();
        let h = SmapHolder::new();
        h.set(Arc::new(Smap::new(
            1,
            vec![],
            vec![NodeInfo {
                id: "t0".into(),
                http_addr: dt_srv.addr.to_string(),
                p2p_addr: String::new(),
            }],
        )));
        let st = ProxyState::new("p0", h, GetBatchMetrics::new());
        let body = BatchRequest::new(vec![BatchEntry::obj("b", "o")]).to_body();

        let mut tagged = get("/v1/batch", &body);
        tagged.headers.insert(wire::HDR_TENANT.to_string(), "trainer-a".into());
        tagged.headers.insert(wire::HDR_PRIORITY.to_string(), "interactive".into());
        let _ = route(&st, tagged);
        let _ = route(&st, get("/v1/batch", &body)); // legacy client, no headers

        let seen = seen.lock().unwrap();
        assert_eq!(seen[0], ("trainer-a".to_string(), "interactive".to_string()));
        assert_eq!(seen[1], (wire::DEFAULT_TENANT.to_string(), String::new()));
    }

    #[test]
    fn req_ids_unique_and_spread() {
        let st = ProxyState::new("p0", holder(4), GetBatchMetrics::new());
        let mut ids: Vec<u64> = (0..100).map(|_| st.next_req_id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 100);
        // DT spread: at least 3 of 4 targets hit across 100 ids
        let mut dts = std::collections::HashSet::new();
        for id in ids {
            dts.insert((id % 4) as usize);
        }
        assert!(dts.len() >= 3);
    }
}
