//! Stateless gateway (proxy) role: routes object I/O to owner targets and
//! orchestrates the three-phase GetBatch execution flow (§2.3.1).

pub mod proxy;

pub use proxy::{make_proxy_handler, ProxyState};
