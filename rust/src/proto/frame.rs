//! Target-to-target frame protocol: senders push locally-resolved entries to
//! the Designated Target over persistent peer connections (§2.3.1 phase 2).
//!
//! Binary layout (little-endian), one frame per record:
//!
//! ```text
//! magic  u16   0xA15B
//! type   u8    1=DATA 2=SOFT_ERR 3=SENDER_DONE
//! flags  u8    reserved
//! req    u64   GetBatch execution id
//! index  u32   request-entry index (DATA/SOFT_ERR) | #satisfied (DONE)
//! len    u32   payload length
//! crc    u32   CRC-32 of payload
//! payload [len]
//! ```
//!
//! CRC protects against silent corruption on the intra-cluster path; a bad
//! CRC is classified as a *soft* error (transient stream failure, §2.4.2)
//! so continue-on-error requests survive it.

use std::io::{self, Read, Write};

pub const MAGIC: u16 = 0xA15B;
pub const HEADER_LEN: usize = 2 + 1 + 1 + 8 + 4 + 4 + 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameType {
    /// Entry payload (whole entry — entries are bounded by object size).
    Data = 1,
    /// Sender could not resolve this entry (missing object/member, read
    /// failure); payload is a UTF-8 reason.
    SoftErr = 2,
    /// Sender finished all entries it owns for this request; `index` holds
    /// the count it satisfied (lets the DT cross-check completion).
    SenderDone = 3,
}

impl FrameType {
    fn from_u8(b: u8) -> Option<FrameType> {
        match b {
            1 => Some(FrameType::Data),
            2 => Some(FrameType::SoftErr),
            3 => Some(FrameType::SenderDone),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub ftype: FrameType,
    pub req_id: u64,
    pub index: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn data(req_id: u64, index: u32, payload: Vec<u8>) -> Frame {
        Frame { ftype: FrameType::Data, req_id, index, payload }
    }
    pub fn soft_err(req_id: u64, index: u32, reason: &str) -> Frame {
        Frame { ftype: FrameType::SoftErr, req_id, index, payload: reason.as_bytes().to_vec() }
    }
    pub fn sender_done(req_id: u64, satisfied: u32) -> Frame {
        Frame { ftype: FrameType::SenderDone, req_id, index: satisfied, payload: Vec::new() }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum FrameError {
    #[error("io: {0}")]
    Io(#[from] io::Error),
    #[error("bad magic {0:#06x}")]
    BadMagic(u16),
    #[error("unknown frame type {0}")]
    BadType(u8),
    #[error("crc mismatch on req {req_id} entry {index}")]
    BadCrc { req_id: u64, index: u32 },
}

/// Serialize a frame into `out` (clears it first). Separate from the socket
/// write so the hot path can reuse one scratch buffer per connection.
pub fn encode_into(f: &Frame, out: &mut Vec<u8>) {
    out.clear();
    out.reserve(HEADER_LEN + f.payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(f.ftype as u8);
    out.push(0);
    out.extend_from_slice(&f.req_id.to_le_bytes());
    out.extend_from_slice(&f.index.to_le_bytes());
    out.extend_from_slice(&(f.payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32fast::hash(&f.payload).to_le_bytes());
    out.extend_from_slice(&f.payload);
}

pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> Result<(), FrameError> {
    let mut buf = Vec::with_capacity(HEADER_LEN + f.payload.len());
    encode_into(f, &mut buf);
    w.write_all(&buf)?;
    Ok(())
}

/// Read one frame. Returns `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, FrameError> {
    let mut hdr = [0u8; HEADER_LEN];
    // First byte decides EOF-vs-truncation.
    match r.read(&mut hdr[..1])? {
        0 => return Ok(None),
        _ => {}
    }
    r.read_exact(&mut hdr[1..])?;
    let magic = u16::from_le_bytes([hdr[0], hdr[1]]);
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let ftype = FrameType::from_u8(hdr[2]).ok_or(FrameError::BadType(hdr[2]))?;
    let req_id = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
    let index = u32::from_le_bytes(hdr[12..16].try_into().unwrap());
    let len = u32::from_le_bytes(hdr[16..20].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(hdr[20..24].try_into().unwrap());
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    if crc32fast::hash(&payload) != crc {
        return Err(FrameError::BadCrc { req_id, index });
    }
    Ok(Some(Frame { ftype, req_id, index, payload }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_all_types() {
        let frames = vec![
            Frame::data(7, 3, vec![1, 2, 3, 4]),
            Frame::soft_err(7, 9, "missing object"),
            Frame::sender_done(7, 42),
            Frame::data(u64::MAX, u32::MAX, vec![]),
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut cur = Cursor::new(&buf);
        for f in &frames {
            assert_eq!(&read_frame(&mut cur).unwrap().unwrap(), f);
        }
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn crc_detects_corruption() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(1, 0, vec![9; 100])).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x1;
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(FrameError::BadCrc { req_id: 1, index: 0 })
        ));
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(1, 0, vec![])).unwrap();
        buf[0] = 0;
        assert!(matches!(read_frame(&mut Cursor::new(&buf)), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn truncated_frame_is_error_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(1, 0, vec![5; 50])).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(matches!(read_frame(&mut Cursor::new(&buf)), Err(FrameError::Io(_))));
    }

    #[test]
    fn large_payload() {
        let payload = vec![0xAB; 2 << 20];
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(2, 1, payload.clone())).unwrap();
        let f = read_frame(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(f.payload, payload);
    }
}
