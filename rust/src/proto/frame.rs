//! Target-to-target frame protocol: senders push locally-resolved entries to
//! the Designated Target over persistent peer connections (§2.3.1 phase 2).
//!
//! Binary layout (little-endian), one frame per record:
//!
//! ```text
//! magic  u16   0xA15B
//! type   u8    1=DATA 2=SOFT_ERR 3=SENDER_DONE
//! flags  u8    DATA chunking flags, see below
//! req    u64   GetBatch execution id
//! index  u32   request-entry index (DATA/SOFT_ERR) | #satisfied (DONE)
//! len    u32   payload length
//! crc    u32   CRC-32 of payload
//! payload [len]
//! ```
//!
//! ## `flags` semantics (DATA frames only)
//!
//! Large entries are streamed as a *sequence of chunk frames* so the DT can
//! start emitting an entry before its last byte arrives (§2.3.1 "streaming
//! execution") and so DT memory stays bounded by the backpressure budget:
//!
//! * bit 0 — `FLAG_FIRST`: first chunk of the entry. When LAST is *not*
//!   also set, the payload begins with an 8-byte LE prefix carrying the
//!   entry's **total** byte length (the DT needs it up-front to emit the
//!   TAR header), followed by the first chunk bytes. A retransmitted entry
//!   (stale-connection retry) starts again with a FIRST chunk, which
//!   resets any partially received unconsumed state for that slot.
//! * bit 1 — `FLAG_LAST`: last chunk of the entry.
//! * `FIRST|LAST`: the payload is the whole entry, no size prefix — the
//!   frame length *is* the entry length. Small entries (≤ chunk size) take
//!   this single-frame path.
//! * neither bit: a middle chunk (pure payload bytes).
//!
//! Non-DATA frames carry `flags = 0`.
//!
//! Each chunk frame carries its own CRC (per-chunk CRC), so corruption is
//! detected before a chunk is appended to the reorder buffer; a bad CRC is
//! classified as a *soft* error (transient stream failure, §2.4.2) so
//! continue-on-error requests survive it.

use std::io::{self, Read, Write};

pub const MAGIC: u16 = 0xA15B;
pub const HEADER_LEN: usize = 2 + 1 + 1 + 8 + 4 + 4 + 4;

/// First chunk of a multi-chunk entry (payload starts with the u64 total).
pub const FLAG_FIRST: u8 = 0b01;
/// Last chunk of a multi-chunk entry.
pub const FLAG_LAST: u8 = 0b10;
/// Whole entry in one frame.
pub const FLAG_WHOLE: u8 = FLAG_FIRST | FLAG_LAST;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameType {
    /// Entry payload: a whole entry or one chunk of it (see `flags`).
    Data = 1,
    /// Sender could not resolve this entry (missing object/member, read
    /// failure); payload is a UTF-8 reason.
    SoftErr = 2,
    /// Sender finished all entries it owns for this request; `index` holds
    /// the count it satisfied (lets the DT cross-check completion).
    SenderDone = 3,
}

impl FrameType {
    fn from_u8(b: u8) -> Option<FrameType> {
        match b {
            1 => Some(FrameType::Data),
            2 => Some(FrameType::SoftErr),
            3 => Some(FrameType::SenderDone),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub ftype: FrameType,
    pub flags: u8,
    pub req_id: u64,
    pub index: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Whole-entry DATA frame (single-frame path).
    pub fn data(req_id: u64, index: u32, payload: Vec<u8>) -> Frame {
        Frame { ftype: FrameType::Data, flags: FLAG_WHOLE, req_id, index, payload }
    }

    /// First chunk of a multi-chunk entry: prefixes the chunk bytes with the
    /// entry's total length so the receiver can pre-size its slot (and the
    /// DT can emit the TAR header before the rest arrives).
    pub fn data_first_chunk(req_id: u64, index: u32, total: u64, chunk: &[u8], last: bool) -> Frame {
        if last {
            // Degenerate single-chunk case: the whole-frame encoding already
            // carries its length — no prefix needed.
            return Frame::data(req_id, index, chunk.to_vec());
        }
        let mut payload = Vec::with_capacity(8 + chunk.len());
        payload.extend_from_slice(&total.to_le_bytes());
        payload.extend_from_slice(chunk);
        Frame { ftype: FrameType::Data, flags: FLAG_FIRST, req_id, index, payload }
    }

    /// Middle/last chunk of a multi-chunk entry.
    pub fn data_chunk(req_id: u64, index: u32, chunk: Vec<u8>, last: bool) -> Frame {
        let flags = if last { FLAG_LAST } else { 0 };
        Frame { ftype: FrameType::Data, flags, req_id, index, payload: chunk }
    }

    pub fn soft_err(req_id: u64, index: u32, reason: &str) -> Frame {
        Frame {
            ftype: FrameType::SoftErr,
            flags: 0,
            req_id,
            index,
            payload: reason.as_bytes().to_vec(),
        }
    }

    pub fn sender_done(req_id: u64, satisfied: u32) -> Frame {
        Frame { ftype: FrameType::SenderDone, flags: 0, req_id, index: satisfied, payload: Vec::new() }
    }

    pub fn is_first(&self) -> bool {
        self.flags & FLAG_FIRST != 0
    }

    pub fn is_last(&self) -> bool {
        self.flags & FLAG_LAST != 0
    }

    /// For a DATA frame, split into (declared total length, chunk bytes).
    /// Whole-entry frames declare their own payload length; a FIRST chunk of
    /// a multi-chunk entry decodes the 8-byte total prefix; middle/LAST
    /// chunks declare 0. Returns `None` for a malformed first chunk.
    pub fn chunk_parts(&self) -> Option<(u64, &[u8])> {
        debug_assert_eq!(self.ftype, FrameType::Data);
        if self.flags == FLAG_FIRST {
            // FIRST of a multi-chunk entry: total-length prefix + bytes.
            if self.payload.len() < 8 {
                return None;
            }
            let total = u64::from_le_bytes(self.payload[..8].try_into().unwrap());
            Some((total, &self.payload[8..]))
        } else if self.is_first() {
            // Whole entry (FIRST|LAST).
            Some((self.payload.len() as u64, &self.payload))
        } else {
            // Middle/last chunk: pure payload, no declared total.
            Some((0, &self.payload))
        }
    }
}

#[derive(Debug)]
pub enum FrameError {
    Io(io::Error),
    BadMagic(u16),
    BadType(u8),
    BadCrc { req_id: u64, index: u32 },
}

crate::impl_error! {
    FrameError {
        display {
            FrameError::Io(e) => "io: {e}",
            FrameError::BadMagic(m) => "bad magic {m:#06x}",
            FrameError::BadType(t) => "unknown frame type {t}",
            FrameError::BadCrc { req_id, index } => "crc mismatch on req {req_id} entry {index}",
        }
        source {
            FrameError::Io(e) => e,
        }
        from {
            io::Error => Io,
        }
    }
}

/// Frame identity without an owned payload — the borrowed-payload encode
/// path ([`encode_head_into`]) the sender hot loop uses to cut chunk
/// frames out of one reusable buffer instead of materializing a `Frame`
/// (and a fresh payload `Vec`) per chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHead {
    pub ftype: FrameType,
    pub flags: u8,
    pub req_id: u64,
    pub index: u32,
}

/// Serialize a frame from its head and a borrowed payload into `out`
/// (clears it first). Wire-identical to [`encode_into`].
pub fn encode_head_into(head: FrameHead, payload: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(head.ftype as u8);
    out.push(head.flags);
    out.extend_from_slice(&head.req_id.to_le_bytes());
    out.extend_from_slice(&head.index.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crate::util::crc32::hash(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Serialize a frame into `out` (clears it first). Separate from the socket
/// write so the hot path can reuse one scratch buffer per connection.
pub fn encode_into(f: &Frame, out: &mut Vec<u8>) {
    encode_head_into(
        FrameHead { ftype: f.ftype, flags: f.flags, req_id: f.req_id, index: f.index },
        &f.payload,
        out,
    );
}

pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> Result<(), FrameError> {
    let mut buf = Vec::with_capacity(HEADER_LEN + f.payload.len());
    encode_into(f, &mut buf);
    w.write_all(&buf)?;
    Ok(())
}

/// Read one frame. Returns `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, FrameError> {
    let mut hdr = [0u8; HEADER_LEN];
    // First byte decides EOF-vs-truncation.
    match r.read(&mut hdr[..1])? {
        0 => return Ok(None),
        _ => {}
    }
    r.read_exact(&mut hdr[1..])?;
    let magic = u16::from_le_bytes([hdr[0], hdr[1]]);
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let ftype = FrameType::from_u8(hdr[2]).ok_or(FrameError::BadType(hdr[2]))?;
    let flags = hdr[3];
    let req_id = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
    let index = u32::from_le_bytes(hdr[12..16].try_into().unwrap());
    let len = u32::from_le_bytes(hdr[16..20].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(hdr[20..24].try_into().unwrap());
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    if crate::util::crc32::hash(&payload) != crc {
        return Err(FrameError::BadCrc { req_id, index });
    }
    Ok(Some(Frame { ftype, flags, req_id, index, payload }))
}

/// Decode one frame from the front of `buf` without consuming input.
/// Returns `Ok(None)` when `buf` holds only a partial frame (read more and
/// retry) and `Ok(Some((frame, consumed)))` when a full frame is present —
/// the reactor's incremental-parse path (`read_frame` is its blocking
/// counterpart and stays the wire authority for stream readers).
pub fn decode_slice(buf: &[u8]) -> Result<Option<(Frame, usize)>, FrameError> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let magic = u16::from_le_bytes([buf[0], buf[1]]);
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let ftype = FrameType::from_u8(buf[2]).ok_or(FrameError::BadType(buf[2]))?;
    let flags = buf[3];
    let req_id = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let index = u32::from_le_bytes(buf[12..16].try_into().unwrap());
    let len = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(buf[20..24].try_into().unwrap());
    if buf.len() < HEADER_LEN + len {
        return Ok(None);
    }
    let payload = buf[HEADER_LEN..HEADER_LEN + len].to_vec();
    if crate::util::crc32::hash(&payload) != crc {
        return Err(FrameError::BadCrc { req_id, index });
    }
    Ok(Some((Frame { ftype, flags, req_id, index, payload }, HEADER_LEN + len)))
}

/// Number of chunk frames an entry of `len` bytes splits into.
pub fn chunk_count(len: usize, chunk_bytes: usize) -> usize {
    let chunk_bytes = chunk_bytes.max(1);
    if len <= chunk_bytes {
        1
    } else {
        len.div_ceil(chunk_bytes)
    }
}

/// Split an in-memory payload into its chunk-frame sequence: one whole
/// frame when it fits in `chunk_bytes`, otherwise FIRST (with the
/// total-length prefix) + middle + LAST chunks of at most `chunk_bytes`.
/// Test/bench utility — the production sender cuts frames straight off a
/// streaming `store::EntryReader` (`sender::run_sender`) and never holds a
/// whole entry.
pub fn chunk_frames(req_id: u64, index: u32, data: Vec<u8>, chunk_bytes: usize) -> Vec<Frame> {
    let chunk_bytes = chunk_bytes.max(1);
    if data.len() <= chunk_bytes {
        return vec![Frame::data(req_id, index, data)];
    }
    let total = data.len() as u64;
    let mut frames = Vec::with_capacity(chunk_count(data.len(), chunk_bytes));
    let mut off = 0usize;
    while off < data.len() {
        let end = (off + chunk_bytes).min(data.len());
        let last = end == data.len();
        frames.push(if off == 0 {
            Frame::data_first_chunk(req_id, index, total, &data[..end], last)
        } else {
            Frame::data_chunk(req_id, index, data[off..end].to_vec(), last)
        });
        off = end;
    }
    frames
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_all_types() {
        let frames = vec![
            Frame::data(7, 3, vec![1, 2, 3, 4]),
            Frame::soft_err(7, 9, "missing object"),
            Frame::sender_done(7, 42),
            Frame::data(u64::MAX, u32::MAX, vec![]),
            Frame::data_first_chunk(8, 0, 10, &[1, 2, 3], false),
            Frame::data_chunk(8, 0, vec![4, 5, 6], false),
            Frame::data_chunk(8, 0, vec![7, 8, 9, 10], true),
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut cur = Cursor::new(&buf);
        for f in &frames {
            assert_eq!(&read_frame(&mut cur).unwrap().unwrap(), f);
        }
        assert!(read_frame(&mut cur).unwrap().is_none());
    }

    #[test]
    fn borrowed_encode_is_wire_identical() {
        let frames = vec![
            Frame::data(7, 3, vec![1, 2, 3, 4]),
            Frame::data_first_chunk(8, 0, 10, &[1, 2, 3], false),
            Frame::data_chunk(8, 0, vec![7, 8, 9, 10], true),
            Frame::soft_err(7, 9, "missing object"),
            Frame::sender_done(7, 42),
        ];
        let (mut owned, mut borrowed) = (Vec::new(), Vec::new());
        for f in &frames {
            encode_into(f, &mut owned);
            encode_head_into(
                FrameHead { ftype: f.ftype, flags: f.flags, req_id: f.req_id, index: f.index },
                &f.payload,
                &mut borrowed,
            );
            assert_eq!(owned, borrowed);
            assert_eq!(&read_frame(&mut Cursor::new(&borrowed)).unwrap().unwrap(), f);
        }
    }

    #[test]
    fn crc_detects_corruption() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(1, 0, vec![9; 100])).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x1;
        assert!(matches!(
            read_frame(&mut Cursor::new(&buf)),
            Err(FrameError::BadCrc { req_id: 1, index: 0 })
        ));
    }

    #[test]
    fn per_chunk_crc_detects_corruption_in_any_chunk() {
        // Encode a 3-chunk entry; flip one byte in the middle chunk's
        // payload; the middle frame (and only it) must fail CRC.
        let data: Vec<u8> = (0..=255u8).cycle().take(3000).collect();
        let frames = chunk_frames(5, 2, data, 1024);
        assert_eq!(frames.len(), 3);
        let mut buf = Vec::new();
        let mut offsets = Vec::new();
        for f in &frames {
            offsets.push(buf.len());
            write_frame(&mut buf, f).unwrap();
        }
        // corrupt a payload byte of the middle frame
        buf[offsets[1] + HEADER_LEN + 10] ^= 0xFF;
        let mut cur = Cursor::new(&buf);
        assert!(read_frame(&mut cur).unwrap().is_some(), "chunk 0 intact");
        assert!(matches!(
            read_frame(&mut cur),
            Err(FrameError::BadCrc { req_id: 5, index: 2 })
        ));
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(1, 0, vec![])).unwrap();
        buf[0] = 0;
        assert!(matches!(read_frame(&mut Cursor::new(&buf)), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn truncated_frame_is_error_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(1, 0, vec![5; 50])).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(matches!(read_frame(&mut Cursor::new(&buf)), Err(FrameError::Io(_))));
    }

    #[test]
    fn large_payload() {
        let payload = vec![0xAB; 2 << 20];
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::data(2, 1, payload.clone())).unwrap();
        let f = read_frame(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(f.payload, payload);
    }

    #[test]
    fn chunking_roundtrips_byte_identical() {
        for (len, chunk) in [(0usize, 64usize), (63, 64), (64, 64), (65, 64), (1000, 64), (4096, 1024)] {
            let data: Vec<u8> = (0..len).map(|i| (i * 7 % 251) as u8).collect();
            let frames = chunk_frames(9, 4, data.clone(), chunk);
            assert_eq!(frames.len(), chunk_count(len, chunk), "len={len} chunk={chunk}");
            // encode/decode every frame over the wire
            let mut buf = Vec::new();
            for f in &frames {
                write_frame(&mut buf, f).unwrap();
            }
            let mut cur = Cursor::new(&buf);
            let mut rebuilt = Vec::new();
            let mut declared_total = None;
            let mut saw_last = false;
            while let Some(f) = read_frame(&mut cur).unwrap() {
                assert!(!saw_last, "no frames after LAST");
                let (total, bytes) = f.chunk_parts().unwrap();
                if f.is_first() {
                    declared_total = Some(total);
                }
                rebuilt.extend_from_slice(bytes);
                saw_last = f.is_last();
            }
            assert!(saw_last, "len={len}");
            assert_eq!(declared_total, Some(data.len() as u64), "len={len}");
            assert_eq!(rebuilt, data, "len={len} chunk={chunk}");
        }
    }

    #[test]
    fn decode_slice_matches_read_frame() {
        let frames = vec![
            Frame::data(7, 3, vec![1, 2, 3, 4]),
            Frame::soft_err(7, 9, "missing object"),
            Frame::sender_done(7, 42),
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        // Incremental: every prefix either yields the next frame or None.
        let mut off = 0usize;
        let mut got = Vec::new();
        for end in 0..=buf.len() {
            if let Some((f, used)) = decode_slice(&buf[off..end]).unwrap() {
                got.push(f);
                off += used;
            }
        }
        assert_eq!(got, frames);
        assert_eq!(off, buf.len());
        // Corruption is detected at the slice layer too.
        let mut bad = Vec::new();
        write_frame(&mut bad, &Frame::data(1, 0, vec![9; 10])).unwrap();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        assert!(matches!(decode_slice(&bad), Err(FrameError::BadCrc { .. })));
    }

    #[test]
    fn whole_frame_flags_and_parts() {
        let f = Frame::data(1, 0, vec![1, 2, 3]);
        assert!(f.is_first() && f.is_last());
        assert_eq!(f.chunk_parts().unwrap(), (3, &[1u8, 2, 3][..]));
        // middle chunks carry neither flag and no declared total
        let mid = Frame::data_chunk(1, 0, vec![7, 8], false);
        assert!(!mid.is_first() && !mid.is_last());
        assert_eq!(mid.chunk_parts().unwrap(), (0, &[7u8, 8][..]));
        // last chunks carry only LAST
        let last = Frame::data_chunk(1, 0, vec![9], true);
        assert!(!last.is_first() && last.is_last());
    }

    #[test]
    fn malformed_first_chunk_rejected() {
        // FIRST (not LAST) with < 8 payload bytes cannot carry the prefix.
        let f = Frame { ftype: FrameType::Data, flags: FLAG_FIRST, req_id: 1, index: 0, payload: vec![1, 2] };
        assert!(f.chunk_parts().is_none());
    }
}
