//! Minimal HTTP/1.1 implementation (no hyper/tokio in the offline
//! sandbox). Covers exactly what the GetBatch API needs:
//!
//! - request bodies on GET (§2.2 — the JSON entry list rides a GET body);
//! - 307 redirects (proxy → Designated Target, §2.3.1 phase 3);
//! - chunked transfer encoding for the DT's streaming TAR response;
//! - 429 Too Many Requests for admission control (§2.4.3);
//! - keep-alive with a client-side connection cache (per-request TCP setup
//!   is precisely the overhead the paper measures — the *baseline* GET path
//!   can disable reuse to model cold connections).
//!
//! The **server** side is readiness-driven: each connection is a
//! [`ConnProto`] state machine on the shared [`Reactor`] (incremental head
//! parse off the connection's input buffer, one in-flight request per
//! connection, responses written through the reactor's bounded
//! per-connection output buffer). Handlers run on the reactor's elastic
//! worker pool and are free to block — on the `MemoryBudget`, on nested
//! intra-cluster calls — because they hold no socket; a streaming body
//! that outruns a slow client blocks on the output buffer's high-water
//! mark while the reactor keeps only write-*interest* armed. The client
//! side stays a plain blocking caller (it lives on worker/test threads
//! that have nothing else to do while waiting).

use std::collections::BTreeMap;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::transport::reactor::{
    ConnIo, ConnProto, ProtoFactory, Reactor, ReactorConfig, ReactorStats, WorkerPool,
};


// ---------------------------------------------------------------- types --

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    pub peer: Option<SocketAddr>,
}

impl Request {
    pub fn header(&self, k: &str) -> Option<&str> {
        self.headers.get(&k.to_ascii_lowercase()).map(|s| s.as_str())
    }
    pub fn query_param(&self, k: &str) -> Option<&str> {
        self.query.get(k).map(|s| s.as_str())
    }
}

/// Response body: fully buffered, or a producer that streams via chunked
/// transfer encoding (the DT's streaming mode).
pub enum Body {
    Bytes(Vec<u8>),
    /// Producer writes the payload to the supplied sink; transfer is chunked.
    Stream(Box<dyn FnOnce(&mut dyn Write) -> io::Result<()> + Send>),
}

impl std::fmt::Debug for Body {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Body::Bytes(b) => write!(f, "Bytes({})", b.len()),
            Body::Stream(_) => write!(f, "Stream"),
        }
    }
}

#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Body,
}

impl Response {
    pub fn ok(body: Vec<u8>) -> Response {
        Response { status: 200, headers: Vec::new(), body: Body::Bytes(body) }
    }
    pub fn text(status: u16, msg: &str) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), "text/plain".into())],
            body: Body::Bytes(msg.as_bytes().to_vec()),
        }
    }
    pub fn status(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Body::Bytes(Vec::new()) }
    }
    /// 307 Temporary Redirect preserving method+body — proxy → DT handoff.
    pub fn redirect(location: &str) -> Response {
        Response {
            status: 307,
            headers: vec![("location".into(), location.into())],
            body: Body::Bytes(Vec::new()),
        }
    }
    pub fn stream(f: impl FnOnce(&mut dyn Write) -> io::Result<()> + Send + 'static) -> Response {
        Response { status: 200, headers: Vec::new(), body: Body::Stream(Box::new(f)) }
    }
    pub fn with_header(mut self, k: &str, v: &str) -> Response {
        self.headers.push((k.to_string(), v.to_string()));
        self
    }
    /// Mark a response as 206 Partial Content for the half-open slice
    /// `[start, end)` of a `len`-byte resource (internal Range contract —
    /// see [`resolve_range`]).
    pub fn into_partial(mut self, start: u64, end: u64, len: u64) -> Response {
        self.status = 206;
        self.with_header("content-range", &content_range_value(start, end, len))
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        206 => "Partial Content",
        307 => "Temporary Redirect",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        416 => "Range Not Satisfiable",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

// ---------------------------------------------------------------- ranges --

/// Outcome of resolving a `Range` request header against a resource length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RangeSpec {
    /// No (or unsupported) range — serve the whole resource with 200.
    Whole,
    /// Serve the half-open byte slice `[start, end)` with 206 and a
    /// `content-range: bytes start-(end-1)/len` header.
    Slice { start: u64, end: u64 },
    /// Start lies beyond the resource — serve 416 with
    /// `content-range: bytes */len`.
    Unsatisfiable,
}

/// Server side of the crate's internal Range support: resolve a
/// `Range: bytes=S-E` header against a `len`-byte resource. `E` is
/// inclusive per RFC 9110; an open-ended `bytes=S-` runs to the end. The
/// forms the cluster never sends (multi-range, suffix `bytes=-N`, other
/// units) degrade to [`RangeSpec::Whole`]. Internal departure from the RFC:
/// `start == len` yields an *empty* 206 slice rather than 416, so a ranged
/// probe of a zero-length object still learns its total from
/// `content-range`.
pub fn resolve_range(header: Option<&str>, len: u64) -> RangeSpec {
    let spec = match header.and_then(|h| h.trim().strip_prefix("bytes=")) {
        Some(s) => s,
        None => return RangeSpec::Whole,
    };
    if spec.contains(',') {
        return RangeSpec::Whole;
    }
    let (s, e) = match spec.split_once('-') {
        Some(x) => x,
        None => return RangeSpec::Whole,
    };
    let start: u64 = match s.trim().parse() {
        Ok(v) => v,
        Err(_) => return RangeSpec::Whole, // includes the suffix form "-N"
    };
    if start > len {
        return RangeSpec::Unsatisfiable;
    }
    let end = match e.trim() {
        "" => len,
        t => match t.parse::<u64>() {
            Ok(v) => v.saturating_add(1).min(len),
            Err(_) => return RangeSpec::Whole,
        },
    };
    if end < start {
        return RangeSpec::Unsatisfiable;
    }
    RangeSpec::Slice { start, end }
}

/// Format the `content-range` value for a [`RangeSpec::Slice`]. The empty
/// slice renders a last-byte position one below `start` (internal contract;
/// only the `/{len}` total is parsed back).
pub fn content_range_value(start: u64, end: u64, len: u64) -> String {
    format!("bytes {}-{}/{}", start, end as i64 - 1, len)
}

/// Parse the total length out of a `content-range: bytes S-E/total` value —
/// how a ranged client (GFN recovery) learns an object's full size from its
/// first chunk response.
pub fn content_range_total(v: &str) -> Option<u64> {
    v.rsplit_once('/')?.1.trim().parse().ok()
}

/// The 416 response advertising the resource's total length (internal
/// Range contract).
pub fn range_unsatisfiable(len: u64) -> Response {
    Response::text(416, &format!("range unsatisfiable for {len}-byte resource"))
        .with_header("content-range", &format!("bytes */{len}"))
}

/// Serve an in-memory payload honoring an optional `Range` header per the
/// internal contract — the single definition test stubs and simple handlers
/// share (the production object endpoint streams the same contract from an
/// `EntryReader` instead of a buffer).
pub fn serve_ranged_bytes(req: &Request, payload: &[u8]) -> Response {
    let len = payload.len() as u64;
    match resolve_range(req.header("range"), len) {
        RangeSpec::Whole => Response::ok(payload.to_vec()),
        RangeSpec::Slice { start, end } => {
            Response::ok(payload[start as usize..end as usize].to_vec())
                .into_partial(start, end, len)
        }
        RangeSpec::Unsatisfiable => range_unsatisfiable(len),
    }
}

/// [`serve_ranged_bytes`] with an injected service delay — the test-stub
/// hook for "slow-not-dead endpoint" scenarios (tail-latency suites). The
/// sleep happens in the stub server's handler thread before any byte of
/// the response is written, so the client observes it as time to first
/// byte.
pub fn serve_ranged_bytes_after(
    delay: std::time::Duration,
    req: &Request,
    payload: &[u8],
) -> Response {
    if !delay.is_zero() {
        std::thread::sleep(delay);
    }
    serve_ranged_bytes(req, payload)
}

// --------------------------------------------------------------- parsing --

fn parse_query(q: &str) -> BTreeMap<String, String> {
    q.split('&')
        .filter(|s| !s.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), "true".to_string()),
        })
        .collect()
}

/// Try to parse one complete request from the front of `buf`. Returns the
/// request plus the bytes it consumed, or `None` when more input is needed.
/// `scan_from` caches how much of `buf` was already searched for the head
/// terminator so a large body arriving in pieces is not re-scanned.
fn parse_request(
    buf: &[u8],
    peer: SocketAddr,
    scan_from: &mut usize,
) -> io::Result<Option<(Request, usize)>> {
    const MAX_HEAD: usize = 64 * 1024;
    let from = scan_from.saturating_sub(3);
    let head_end = match buf[from..].windows(4).position(|w| w == b"\r\n\r\n") {
        Some(i) => from + i + 4,
        None => {
            if buf.len() > MAX_HEAD {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "request head too large"));
            }
            *scan_from = buf.len();
            return Ok(None);
        }
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-utf8 request head"))?;
    let mut lines = head.split("\r\n");
    let mut parts = lines.next().unwrap_or("").splitn(3, ' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad request line"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), parse_query(q)),
        None => (target, BTreeMap::new()),
    };
    let mut headers = BTreeMap::new();
    for hl in lines {
        if let Some((k, v)) = hl.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers.get("content-length").and_then(|v| v.parse().ok()).unwrap_or(0);
    let total = head_end + len;
    if buf.len() < total {
        return Ok(None);
    }
    let body = buf[head_end..total].to_vec();
    Ok(Some((Request { method, path, query, headers, body, peer: Some(peer) }, total)))
}

// ---------------------------------------------------------------- server --

pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

/// Serialize a response head for the reactor write path.
fn response_head(status: u16, headers: &[(String, String)], keep_alive: bool) -> String {
    let mut head = format!("HTTP/1.1 {} {}\r\n", status, status_text(status));
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(if keep_alive { "connection: keep-alive\r\n" } else { "connection: close\r\n" });
    head
}

/// Write a full response through the connection's reactor buffer. Runs on a
/// worker thread; blocks (on the buffer high-water mark, never a socket)
/// when the client reads slower than a streaming body produces.
fn write_conn_response(io: &Arc<ConnIo>, resp: Response, keep_alive: bool) -> io::Result<()> {
    let mut head = response_head(resp.status, &resp.headers, keep_alive);
    match resp.body {
        Body::Bytes(b) => {
            head.push_str(&format!("content-length: {}\r\n\r\n", b.len()));
            let mut buf = head.into_bytes();
            buf.extend_from_slice(&b);
            io.send_vec(buf).map(|_| ())
        }
        Body::Stream(f) => {
            head.push_str("transfer-encoding: chunked\r\n\r\n");
            io.send(head.as_bytes())?;
            let mut cw = ChunkedWriter { io, chunk_buf: Vec::with_capacity(64 * 1024) };
            f(&mut cw)?;
            cw.finish()
        }
    }
}

/// Chunked-transfer encoder over a reactor connection. Buffers small writes
/// into ~64 KiB chunks so the TAR writer's 512-byte blocks don't become
/// 512-byte chunks on the wire.
struct ChunkedWriter<'a> {
    io: &'a Arc<ConnIo>,
    chunk_buf: Vec<u8>,
}

impl ChunkedWriter<'_> {
    const FLUSH_AT: usize = 64 * 1024;

    fn emit(&mut self) -> io::Result<()> {
        if !self.chunk_buf.is_empty() {
            let mut wire = Vec::with_capacity(self.chunk_buf.len() + 16);
            wire.extend_from_slice(format!("{:x}\r\n", self.chunk_buf.len()).as_bytes());
            wire.extend_from_slice(&self.chunk_buf);
            wire.extend_from_slice(b"\r\n");
            self.chunk_buf.clear();
            self.io.send_vec(wire)?;
        }
        Ok(())
    }

    fn finish(mut self) -> io::Result<()> {
        self.emit()?;
        self.io.send(b"0\r\n\r\n")
    }
}

impl Write for ChunkedWriter<'_> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.chunk_buf.extend_from_slice(buf);
        if self.chunk_buf.len() >= Self::FLUSH_AT {
            self.emit()?;
        }
        Ok(buf.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        // Hand the pending chunk to the reactor — gives streaming mode real
        // time-to-first-byte semantics.
        self.emit()
    }
}

/// Per-connection HTTP/1.1 server state machine on the reactor. One
/// in-flight request at a time (HTTP/1.1 response ordering); while a
/// request is being handled, read interest is dropped so a pipelining
/// client gets TCP backpressure instead of growing the input buffer.
struct HttpConn {
    handler: Handler,
    pool: WorkerPool,
    peer: SocketAddr,
    /// Set while a worker owns the current request/response.
    busy: Arc<AtomicBool>,
    /// Peer half-closed; close once the in-flight response flushes.
    eof: bool,
    /// Incremental-parse resume point (see [`parse_request`]).
    scan_from: usize,
}

impl HttpConn {
    fn dispatch(&self, req: Request, io: &Arc<ConnIo>) {
        let keep_alive = !req
            .header("connection")
            .map(|c| c.eq_ignore_ascii_case("close"))
            .unwrap_or(false);
        self.busy.store(true, Ordering::Release);
        io.pause_reads();
        let handler = Arc::clone(&self.handler);
        let busy = Arc::clone(&self.busy);
        let io = Arc::clone(io);
        self.pool.execute(move || {
            let resp =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler(req)))
                    .unwrap_or_else(|_| Response::text(500, "handler panicked"));
            let ok = write_conn_response(&io, resp, keep_alive).is_ok();
            busy.store(false, Ordering::Release);
            if !ok {
                io.close();
            } else if !keep_alive {
                io.close_after_flush();
            } else {
                // Resuming read interest also re-runs `on_data`, which picks
                // up a pipelined request already sitting in the input buffer.
                io.resume_reads();
            }
        });
    }
}

impl ConnProto for HttpConn {
    fn on_data(&mut self, inbuf: &mut Vec<u8>, io: &Arc<ConnIo>) -> io::Result<()> {
        while !self.busy.load(Ordering::Acquire) {
            match parse_request(inbuf, self.peer, &mut self.scan_from)? {
                Some((req, used)) => {
                    inbuf.drain(..used);
                    self.scan_from = 0;
                    self.dispatch(req, io);
                }
                None => {
                    if self.eof {
                        io.close_after_flush();
                    }
                    break;
                }
            }
        }
        Ok(())
    }

    fn on_eof(&mut self, io: &Arc<ConnIo>) {
        self.eof = true;
        if !self.busy.load(Ordering::Acquire) {
            io.close_after_flush();
        }
    }
}

/// A running HTTP server: a listener on a dedicated [`Reactor`] whose few
/// event-loop threads multiplex every connection. Dropping it stops the
/// loops and joins reactor + worker threads.
pub struct HttpServer {
    pub addr: SocketAddr,
    reactor: Arc<Reactor>,
}

impl HttpServer {
    /// Bind to `127.0.0.1:0` (ephemeral port) and serve requests with
    /// default reactor settings; `workers` seeds the minimum worker count
    /// (the pool grows on demand — see [`WorkerPool`]).
    pub fn serve(handler: Handler, workers: usize, name: &str) -> io::Result<HttpServer> {
        let cfg = ReactorConfig { min_workers: workers.max(1), ..Default::default() };
        HttpServer::serve_opts(handler, name, cfg)
    }

    /// [`HttpServer::serve`] with explicit reactor tuning
    /// (`reactor_threads`, `max_connections`, buffer limit, metrics).
    pub fn serve_opts(handler: Handler, name: &str, cfg: ReactorConfig) -> io::Result<HttpServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let reactor = Reactor::new(cfg, name)?;
        let pool = reactor.worker_pool();
        let factory: ProtoFactory = Arc::new(move |peer| {
            Box::new(HttpConn {
                handler: Arc::clone(&handler),
                pool: pool.clone(),
                peer,
                busy: Arc::new(AtomicBool::new(false)),
                eof: false,
                scan_from: 0,
            })
        });
        reactor.listen(listener, factory)?;
        Ok(HttpServer { addr, reactor })
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Reactor counters (connection gauge, wake-ups, shed accepts, peak
    /// buffering) — mirrored into node metrics and asserted by scale tests.
    pub fn stats(&self) -> Arc<ReactorStats> {
        Arc::clone(self.reactor.stats())
    }
}

// ---------------------------------------------------------------- client --

/// Response body reader: content-length-bounded or chunked-decoding stream
/// over the pooled connection.
pub struct BodyReader {
    conn: Option<PooledConn>,
    mode: BodyMode,
    pool: Option<Arc<ConnPoolInner>>,
}

enum BodyMode {
    Length { remaining: u64 },
    Chunked { in_chunk: u64, done: bool },
}

impl Read for BodyReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let conn = match &mut self.conn {
            Some(c) => c,
            None => return Ok(0),
        };
        match &mut self.mode {
            BodyMode::Length { remaining } => {
                if *remaining == 0 {
                    self.recycle();
                    return Ok(0);
                }
                let want = buf.len().min(*remaining as usize);
                let n = conn.reader.read(&mut buf[..want])?;
                if n == 0 && *remaining > 0 {
                    return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "body truncated"));
                }
                *remaining -= n as u64;
                if *remaining == 0 {
                    self.recycle();
                }
                Ok(n)
            }
            BodyMode::Chunked { in_chunk, done } => {
                if *done {
                    self.recycle();
                    return Ok(0);
                }
                if *in_chunk == 0 {
                    // read chunk-size line
                    let mut line = String::new();
                    conn.reader.read_line(&mut line)?;
                    let size = u64::from_str_radix(line.trim(), 16)
                        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad chunk size"))?;
                    if size == 0 {
                        // trailing CRLF after last chunk
                        let mut crlf = String::new();
                        conn.reader.read_line(&mut crlf)?;
                        *done = true;
                        self.recycle();
                        return Ok(0);
                    }
                    *in_chunk = size;
                }
                let want = buf.len().min(*in_chunk as usize);
                let n = conn.reader.read(&mut buf[..want])?;
                if n == 0 {
                    return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "chunk truncated"));
                }
                *in_chunk -= n as u64;
                if *in_chunk == 0 {
                    let mut crlf = [0u8; 2];
                    conn.reader.read_exact(&mut crlf)?;
                }
                Ok(n)
            }
        }
    }
}

impl BodyReader {
    fn fully_consumed(&self) -> bool {
        match &self.mode {
            BodyMode::Length { remaining } => *remaining == 0,
            BodyMode::Chunked { done, .. } => *done,
        }
    }

    /// Return the connection to the pool once the body is fully read.
    fn recycle(&mut self) {
        if let (Some(pool), true) = (&self.pool, self.fully_consumed()) {
            if let Some(conn) = self.conn.take() {
                pool.put(conn);
            }
        }
    }

    pub fn read_all(mut self) -> io::Result<Vec<u8>> {
        let mut out = Vec::new();
        self.read_to_end(&mut out)?;
        Ok(out)
    }
}

impl Drop for BodyReader {
    fn drop(&mut self) {
        // Unconsumed body ⇒ connection state is mid-stream; drop the socket
        // rather than poisoning the pool.
        self.recycle();
    }
}

pub struct ClientResponse {
    pub status: u16,
    pub headers: BTreeMap<String, String>,
    pub body: BodyReader,
}

impl ClientResponse {
    pub fn header(&self, k: &str) -> Option<&str> {
        self.headers.get(&k.to_ascii_lowercase()).map(|s| s.as_str())
    }
    pub fn into_bytes(self) -> io::Result<Vec<u8>> {
        self.body.read_all()
    }
}

struct PooledConn {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

struct ConnPoolInner {
    conns: Mutex<BTreeMap<String, Vec<PooledConn>>>,
    max_per_host: usize,
}

impl ConnPoolInner {
    fn get(&self, addr: &str) -> Option<PooledConn> {
        self.conns.lock().unwrap().get_mut(addr).and_then(|v| v.pop())
    }
    fn put(&self, conn: PooledConn) {
        let addr = match conn.stream.peer_addr() {
            Ok(a) => a.to_string(),
            Err(_) => return,
        };
        let mut m = self.conns.lock().unwrap();
        let v = m.entry(addr).or_default();
        if v.len() < self.max_per_host {
            v.push(conn);
        }
    }
}

/// HTTP client with keep-alive connection reuse. `reuse=false` reproduces
/// the paper's per-request connection overhead (baseline GET).
#[derive(Clone)]
pub struct HttpClient {
    pool: Arc<ConnPoolInner>,
    pub reuse: bool,
    /// Artificial per-request RTT injected before each request — models
    /// datacenter network round trips on localhost. Zero by default.
    pub inject_rtt: Duration,
}

impl Default for HttpClient {
    fn default() -> Self {
        HttpClient::new(true)
    }
}

impl HttpClient {
    pub fn new(reuse: bool) -> HttpClient {
        HttpClient {
            pool: Arc::new(ConnPoolInner { conns: Mutex::new(BTreeMap::new()), max_per_host: 32 }),
            reuse,
            inject_rtt: Duration::ZERO,
        }
    }

    pub fn with_rtt(mut self, rtt: Duration) -> HttpClient {
        self.inject_rtt = rtt;
        self
    }

    fn connect(&self, addr: &str) -> io::Result<(PooledConn, bool)> {
        if self.reuse {
            if let Some(c) = self.pool.get(addr) {
                return Ok((c, true));
            }
        }
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        let reader = BufReader::with_capacity(256 * 1024, stream.try_clone()?);
        Ok((PooledConn { reader, stream }, false))
    }

    /// Issue a request; follows up to 4 temporary redirects (preserving
    /// method + body, per RFC 9110 §15.4.8 — the proxy→DT handoff).
    pub fn request(
        &self,
        method: &str,
        addr: &str,
        path_and_query: &str,
        body: &[u8],
    ) -> io::Result<ClientResponse> {
        self.request_with_headers(method, addr, path_and_query, &[], body)
    }

    /// [`HttpClient::request`] with extra request headers (e.g. `range`).
    /// Headers are preserved across redirects.
    pub fn request_with_headers(
        &self,
        method: &str,
        addr: &str,
        path_and_query: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> io::Result<ClientResponse> {
        let mut addr = addr.to_string();
        let mut pq = path_and_query.to_string();
        for _ in 0..5 {
            let resp = self.request_once(method, &addr, &pq, headers, body)?;
            if resp.status == 307 {
                let loc = resp
                    .header("location")
                    .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "redirect w/o location"))?
                    .to_string();
                // location format: http://host:port/path?query or /path
                if let Some(rest) = loc.strip_prefix("http://") {
                    match rest.split_once('/') {
                        Some((host, tail)) => {
                            addr = host.to_string();
                            pq = format!("/{tail}");
                        }
                        None => {
                            addr = rest.to_string();
                            pq = "/".to_string();
                        }
                    }
                } else {
                    pq = loc;
                }
                continue;
            }
            return Ok(resp);
        }
        Err(io::Error::new(io::ErrorKind::Other, "too many redirects"))
    }

    fn request_once(
        &self,
        method: &str,
        addr: &str,
        path_and_query: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> io::Result<ClientResponse> {
        // A pooled connection may have been closed server-side since its
        // last use; retry exactly once on a fresh connection in that case.
        match self.request_on_conn(method, addr, path_and_query, headers, body) {
            Ok(r) => Ok(r),
            Err((retryable, _)) if retryable => self
                .request_on_conn(method, addr, path_and_query, headers, body)
                .map_err(|(_, e)| e),
            Err((_, e)) => Err(e),
        }
    }

    /// Returns Err((retryable, error)): retryable = pooled conn died before
    /// any response byte arrived.
    fn request_on_conn(
        &self,
        method: &str,
        addr: &str,
        path_and_query: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<ClientResponse, (bool, io::Error)> {
        if !self.inject_rtt.is_zero() {
            std::thread::sleep(self.inject_rtt);
        }
        let (mut conn, from_pool) = self.connect(addr).map_err(|e| (false, e))?;
        let mut head = format!(
            "{method} {path_and_query} HTTP/1.1\r\nhost: {addr}\r\ncontent-length: {}\r\n",
            body.len()
        );
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        if !self.reuse {
            head.push_str("connection: close\r\n");
        }
        head.push_str("\r\n");
        // Failures up to the first response byte on a pooled conn are the
        // stale-keep-alive race — retryable on a fresh connection.
        let stale = |e: io::Error| (from_pool, e);
        conn.stream.write_all(head.as_bytes()).map_err(stale)?;
        conn.stream.write_all(body).map_err(stale)?;
        conn.stream.flush().map_err(stale)?;

        // status line
        let mut line = String::new();
        match conn.reader.read_line(&mut line) {
            Ok(0) => {
                return Err(stale(io::Error::new(io::ErrorKind::UnexpectedEof, "no response")))
            }
            Ok(_) => {}
            Err(e) => return Err(stale(e)),
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                (false, io::Error::new(io::ErrorKind::InvalidData, "bad status line"))
            })?;
        let mut headers = BTreeMap::new();
        loop {
            let mut hl = String::new();
            conn.reader.read_line(&mut hl).map_err(|e| (false, e))?;
            let hl = hl.trim_end();
            if hl.is_empty() {
                break;
            }
            if let Some((k, v)) = hl.split_once(':') {
                headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
            }
        }
        let chunked = headers
            .get("transfer-encoding")
            .map(|v| v.eq_ignore_ascii_case("chunked"))
            .unwrap_or(false);
        let mode = if chunked {
            BodyMode::Chunked { in_chunk: 0, done: false }
        } else {
            let len = headers.get("content-length").and_then(|v| v.parse().ok()).unwrap_or(0);
            BodyMode::Length { remaining: len }
        };
        let keep = headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("keep-alive"))
            .unwrap_or(true)
            && self.reuse;
        Ok(ClientResponse {
            status,
            headers,
            body: BodyReader {
                conn: Some(conn),
                mode,
                pool: if keep { Some(Arc::clone(&self.pool)) } else { None },
            },
        })
    }

    pub fn get(&self, addr: &str, pq: &str) -> io::Result<ClientResponse> {
        self.request("GET", addr, pq, &[])
    }

    /// Ranged GET: ask for `len` bytes starting at `offset` via a `Range`
    /// header. Cluster-internal servers answer 206 with a
    /// `content-range: bytes S-E/total` header (see [`content_range_total`])
    /// — this is how GFN recovery pulls a large entry in `chunk_bytes`
    /// pieces instead of materializing it.
    pub fn get_range(&self, addr: &str, pq: &str, offset: u64, len: u64) -> io::Result<ClientResponse> {
        let range = format!("bytes={}-{}", offset, offset + len.max(1) - 1);
        self.request_with_headers("GET", addr, pq, &[("range", &range)], &[])
    }

    pub fn put(&self, addr: &str, pq: &str, body: &[u8]) -> io::Result<ClientResponse> {
        self.request("PUT", addr, pq, body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        let handler: Handler = Arc::new(|req: Request| match req.path.as_str() {
            "/echo" => Response::ok(req.body),
            "/q" => Response::ok(
                req.query_param("k").unwrap_or("none").as_bytes().to_vec(),
            ),
            "/redir" => Response::redirect("/echo"),
            "/busy" => Response::text(429, "back off"),
            "/stream" => Response::stream(|w| {
                for i in 0..10u32 {
                    w.write_all(&i.to_le_bytes())?;
                    w.flush()?;
                }
                Ok(())
            }),
            "/ranged" => {
                // Canonical internal Range contract over a fixed resource.
                let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
                serve_ranged_bytes(&req, &data)
            }
            _ => Response::status(404),
        });
        HttpServer::serve(handler, 4, "test").unwrap()
    }

    #[test]
    fn roundtrip_body() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let addr = srv.addr.to_string();
        let resp = cl.request("GET", &addr, "/echo", b"hello body on GET").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.into_bytes().unwrap(), b"hello body on GET");
    }

    #[test]
    fn query_params() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let resp = cl.get(&srv.addr.to_string(), "/q?k=v1&x=2").unwrap();
        assert_eq!(resp.into_bytes().unwrap(), b"v1");
    }

    #[test]
    fn redirect_preserves_method_and_body() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let resp = cl.request("GET", &srv.addr.to_string(), "/redir", b"payload").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.into_bytes().unwrap(), b"payload");
    }

    #[test]
    fn status_429_passthrough() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let resp = cl.get(&srv.addr.to_string(), "/busy").unwrap();
        assert_eq!(resp.status, 429);
    }

    #[test]
    fn chunked_streaming_body() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let resp = cl.get(&srv.addr.to_string(), "/stream").unwrap();
        let bytes = resp.into_bytes().unwrap();
        assert_eq!(bytes.len(), 40);
        let v: Vec<u32> = bytes.chunks(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn keep_alive_reuses_connection() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let addr = srv.addr.to_string();
        for i in 0..20 {
            let resp = cl.request("GET", &addr, "/echo", format!("r{i}").as_bytes()).unwrap();
            assert_eq!(resp.into_bytes().unwrap(), format!("r{i}").as_bytes());
        }
        // pool should hold exactly one idle connection for this host
        assert_eq!(cl.pool.conns.lock().unwrap().get(&addr).map(|v| v.len()), Some(1));
    }

    #[test]
    fn no_reuse_mode() {
        let srv = echo_server();
        let cl = HttpClient::new(false);
        let addr = srv.addr.to_string();
        for _ in 0..3 {
            let resp = cl.get(&addr, "/q?k=z").unwrap();
            assert_eq!(resp.into_bytes().unwrap(), b"z");
        }
        assert!(cl.pool.conns.lock().unwrap().is_empty());
    }

    #[test]
    fn resolve_range_contract() {
        assert_eq!(resolve_range(None, 100), RangeSpec::Whole);
        assert_eq!(resolve_range(Some("bytes=0-9"), 100), RangeSpec::Slice { start: 0, end: 10 });
        assert_eq!(resolve_range(Some("bytes=90-"), 100), RangeSpec::Slice { start: 90, end: 100 });
        // end clamped to the resource
        assert_eq!(resolve_range(Some("bytes=90-500"), 100), RangeSpec::Slice { start: 90, end: 100 });
        // empty slice at EOF is allowed (zero-length probe learns the total)
        assert_eq!(resolve_range(Some("bytes=0-9"), 0), RangeSpec::Slice { start: 0, end: 0 });
        assert_eq!(resolve_range(Some("bytes=100-"), 100), RangeSpec::Slice { start: 100, end: 100 });
        assert_eq!(resolve_range(Some("bytes=101-"), 100), RangeSpec::Unsatisfiable);
        // unsupported forms degrade to Whole
        assert_eq!(resolve_range(Some("bytes=-5"), 100), RangeSpec::Whole);
        assert_eq!(resolve_range(Some("bytes=0-1,5-9"), 100), RangeSpec::Whole);
        assert_eq!(resolve_range(Some("items=0-1"), 100), RangeSpec::Whole);
    }

    #[test]
    fn content_range_helpers_roundtrip() {
        assert_eq!(content_range_value(0, 10, 100), "bytes 0-9/100");
        assert_eq!(content_range_value(0, 0, 0), "bytes 0--1/0");
        assert_eq!(content_range_total("bytes 0-9/100"), Some(100));
        assert_eq!(content_range_total("bytes 0--1/0"), Some(0));
        assert_eq!(content_range_total("garbage"), None);
    }

    #[test]
    fn range_request_roundtrip() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let addr = srv.addr.to_string();
        let want: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();

        // whole resource without a Range header
        let resp = cl.get(&addr, "/ranged").unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.into_bytes().unwrap(), want);

        // chunked ranged reads rebuild the resource byte-identically and
        // learn the total from the first content-range
        let mut rebuilt = Vec::new();
        let mut total = None;
        let mut off = 0u64;
        loop {
            let resp = cl.get_range(&addr, "/ranged", off, 64).unwrap();
            assert_eq!(resp.status, 206);
            let t = content_range_total(resp.header("content-range").unwrap()).unwrap();
            total.get_or_insert(t);
            assert_eq!(total, Some(t));
            let bytes = resp.into_bytes().unwrap();
            assert!(bytes.len() <= 64);
            off += bytes.len() as u64;
            rebuilt.extend_from_slice(&bytes);
            if off >= t {
                break;
            }
        }
        assert_eq!(total, Some(1000));
        assert_eq!(rebuilt, want);

        // past-EOF start → 416 with the total still advertised
        let resp = cl.get_range(&addr, "/ranged", 5000, 64).unwrap();
        assert_eq!(resp.status, 416);
        assert_eq!(content_range_total(resp.header("content-range").unwrap()), Some(1000));
    }

    #[test]
    fn not_found() {
        let srv = echo_server();
        let cl = HttpClient::new(true);
        let resp = cl.get(&srv.addr.to_string(), "/nope").unwrap();
        assert_eq!(resp.status, 404);
    }

    #[test]
    fn concurrent_requests() {
        let srv = echo_server();
        let addr = srv.addr.to_string();
        let results = crate::util::threadpool::scoped_map(
            &(0..32).collect::<Vec<u32>>(),
            8,
            |_, &i| {
                let cl = HttpClient::new(true);
                let resp = cl.request("GET", &addr, "/echo", format!("c{i}").as_bytes()).unwrap();
                resp.into_bytes().unwrap()
            },
        );
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r, format!("c{i}").as_bytes());
        }
    }
}
