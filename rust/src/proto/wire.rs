//! Control-plane messages and URL layout for the GetBatch execution flow
//! (§2.3.1): DT registration, sender activation, and the public API paths.

use crate::batch::request::BatchRequest;
use crate::util::json::Value;

/// Public API paths (client ⇄ proxy/target).
pub mod paths {
    /// GET/PUT a single object: `/v1/objects/{bucket}/{obj...}`.
    pub const OBJECTS: &str = "/v1/objects/";
    /// GetBatch: GET with JSON body: `/v1/batch`.
    pub const BATCH: &str = "/v1/batch";
    /// Intra-cluster: DT registration (proxy → target).
    pub const DT_REGISTER: &str = "/v1/xact/dt-register";
    /// Intra-cluster: sender activation broadcast (proxy → targets).
    pub const SENDER_ACTIVATE: &str = "/v1/xact/sender-activate";
    /// DT serves the assembled stream here after redirect (client → DT).
    pub const DT_STREAM: &str = "/v1/xact/stream";
    /// Prometheus exposition.
    pub const METRICS: &str = "/metrics";
    /// Cluster map for SDK bootstrap.
    pub const SMAP: &str = "/v1/cluster/smap";
    /// Health check.
    pub const HEALTH: &str = "/v1/health";
    /// List a bucket's objects: `/v1/list?bucket={bucket}`. Targets serve
    /// their local subset; proxies fan out and merge. The remote store
    /// backend's `list` rides this.
    pub const LIST: &str = "/v1/list";
    /// Cache-coherence invalidation:
    /// `POST /v1/invalidate?bucket={bucket}&obj={obj}`. On a **target** it
    /// drops the object's cached chunks and shard index; on a **proxy** it
    /// fans the same call out to every target in the smap (how an external
    /// writer notifies a whole serving cluster). Best-effort: a missed
    /// delivery degrades to versioned-key revalidation after
    /// `coherence_grace_ms`, never to a stale read forever.
    pub const INVALIDATE: &str = "/v1/invalidate";
    /// Epoch prefetch: `POST /v1/prefetch?bucket={bucket}&obj={obj}`
    /// (optional `&horizon={batches}` — observability only, surfaces the
    /// planner's current horizon on the serving node's gauge). On a
    /// **proxy** it 307-redirects to the object's HRW owner target — the
    /// node whose chunk cache will serve the upcoming demand read; on a
    /// **target** it warms the object's chunks through the bucket's
    /// caching tier (a no-op for uncached buckets) and returns the number
    /// of chunks admitted. Best-effort: a failed prefetch costs the warm
    /// hit, never correctness.
    pub const PREFETCH: &str = "/v1/prefetch";
}

/// Response header carrying an object's PUT-time CRC-32 sidecar (8 hex
/// chars) on object GETs — how the remote backend and GFN splice recovery
/// learn a stored content hash without an extra round trip.
pub const HDR_OBJ_CRC: &str = "x-getbatch-crc32";

/// Response header carrying an object's monotonic write generation
/// (decimal) on object GETs — how a remote caching tier pins the version
/// its chunk keys are derived from. Absent when the serving tier has no
/// version for the object (pre-versioning sidecar).
pub const HDR_OBJ_VERSION: &str = "x-getbatch-version";

/// Request header identifying the tenant a GetBatch call belongs to
/// (multi-tenant QoS). Absent or invalid ⇒ [`DEFAULT_TENANT`], so legacy
/// clients keep working and share one fair-share bucket.
pub const HDR_TENANT: &str = "x-getbatch-tenant";

/// Request header carrying the priority class (`interactive` / `batch` /
/// `bulk`) for class-aware admission shedding. Absent or unknown ⇒ the
/// node's `default_priority` config.
pub const HDR_PRIORITY: &str = "x-getbatch-priority";

/// Tenant name assigned to requests that carry no (valid) tenant header.
pub const DEFAULT_TENANT: &str = "default";

/// Restrict a tenant name to a JSON- and label-safe charset (alphanumeric
/// plus `-`, `_`, `.`, max 64 chars): tenant strings arrive in headers and
/// are raw-spliced into registration JSON and metric labels, so anything
/// else is dropped. Empty (or fully-invalid) names become
/// [`DEFAULT_TENANT`].
pub fn sanitize_tenant(s: &str) -> String {
    let t: String = s
        .chars()
        .filter(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        .take(64)
        .collect();
    if t.is_empty() {
        DEFAULT_TENANT.to_string()
    } else {
        t
    }
}

/// Query parameter carrying the colocation hint (§2.4.1: "clients provide a
/// colocation hint via a query parameter" so the proxy knows to unmarshal).
pub const QPARAM_COLOC: &str = "coloc";
/// Query parameter carrying the execution id on intra-cluster calls.
pub const QPARAM_REQ_ID: &str = "req";

/// DT registration payload: the full batch request, forwarded verbatim by
/// the proxy (phase 1 — the proxy does *not* unmarshal it in the default
/// opaque-routing mode; it re-serializes only when colocation was applied).
#[derive(Debug, Clone, PartialEq)]
pub struct DtRegister {
    pub req_id: u64,
    pub request: BatchRequest,
    /// How many senders will be activated (so the DT knows when fan-in is
    /// complete even if it owns zero entries).
    pub num_senders: u32,
    /// Tenant the execution is charged to in the DT fair-share ledger;
    /// [`DEFAULT_TENANT`] for legacy bodies without the field.
    pub tenant: String,
    /// Requested priority class (`interactive` / `batch` / `bulk`); empty
    /// means "use the node's `default_priority`". Legacy bodies parse as
    /// empty.
    pub priority: String,
}

impl DtRegister {
    /// Build the wire body splicing an already-serialized request verbatim
    /// (proxy hot path: no re-serialization of the entry list). Legacy
    /// QoS-less form: default tenant, node-default priority.
    pub fn body_with_raw(req_id: u64, num_senders: u32, raw_request: &str) -> Vec<u8> {
        DtRegister::body_with_raw_qos(req_id, num_senders, DEFAULT_TENANT, "", raw_request)
    }

    /// Raw-splice variant carrying QoS identity. `tenant` and `priority`
    /// come from client headers, so both are re-sanitized here — a header
    /// must not be able to inject JSON into the registration body.
    pub fn body_with_raw_qos(
        req_id: u64,
        num_senders: u32,
        tenant: &str,
        priority: &str,
        raw_request: &str,
    ) -> Vec<u8> {
        let t = sanitize_tenant(tenant);
        let p: String =
            priority.chars().filter(|c| c.is_ascii_alphanumeric()).take(16).collect();
        format!(
            "{{\"num_senders\":{num_senders},\"priority\":\"{p}\",\"req_id\":{req_id},\"request\":{raw_request},\"tenant\":\"{t}\"}}"
        )
        .into_bytes()
    }

    pub fn to_body(&self) -> Vec<u8> {
        Value::obj()
            .set("req_id", Value::num(self.req_id as f64))
            .set("num_senders", Value::num(self.num_senders as f64))
            .set("request", self.request.to_json())
            .set("tenant", Value::str(&self.tenant))
            .set("priority", Value::str(&self.priority))
            .to_string()
            .into_bytes()
    }

    pub fn from_body(b: &[u8]) -> Option<DtRegister> {
        let v = Value::parse(std::str::from_utf8(b).ok()?).ok()?;
        Some(DtRegister {
            req_id: v.u64_field("req_id")?,
            num_senders: v.u64_field("num_senders")? as u32,
            request: BatchRequest::from_json(v.get("request")?)?,
            tenant: sanitize_tenant(v.str_field("tenant").unwrap_or("")),
            priority: v.str_field("priority").unwrap_or("").to_string(),
        })
    }
}

/// Sender activation payload (phase 2): tells a target which execution to
/// join and where the DT's peer endpoint is. Each sender re-derives its own
/// slice of the entry list from placement — senders are autonomous (§2.3.1).
#[derive(Debug, Clone, PartialEq)]
pub struct SenderActivate {
    pub req_id: u64,
    /// P2P address (host:port) of the DT's transport endpoint.
    pub dt_peer: String,
    pub request: BatchRequest,
}

impl SenderActivate {
    /// Raw-splice variant (see `DtRegister::body_with_raw`).
    pub fn body_with_raw(req_id: u64, dt_peer: &str, raw_request: &str) -> Vec<u8> {
        format!(
            "{{\"dt_peer\":\"{dt_peer}\",\"req_id\":{req_id},\"request\":{raw_request}}}"
        )
        .into_bytes()
    }

    pub fn to_body(&self) -> Vec<u8> {
        Value::obj()
            .set("req_id", Value::num(self.req_id as f64))
            .set("dt_peer", Value::str(&self.dt_peer))
            .set("request", self.request.to_json())
            .to_string()
            .into_bytes()
    }

    pub fn from_body(b: &[u8]) -> Option<SenderActivate> {
        let v = Value::parse(std::str::from_utf8(b).ok()?).ok()?;
        Some(SenderActivate {
            req_id: v.u64_field("req_id")?,
            dt_peer: v.str_field("dt_peer")?.to_string(),
            request: BatchRequest::from_json(v.get("request")?)?,
        })
    }
}

/// Split an object-API path: `/v1/objects/{bucket}/{obj...}` → (bucket, obj).
pub fn parse_object_path(path: &str) -> Option<(String, String)> {
    let rest = path.strip_prefix(paths::OBJECTS)?;
    let (bucket, obj) = rest.split_once('/')?;
    if bucket.is_empty() || obj.is_empty() {
        return None;
    }
    Some((bucket.to_string(), obj.to_string()))
}

pub fn object_path(bucket: &str, obj: &str) -> String {
    format!("{}{}/{}", paths::OBJECTS, bucket, obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchEntry;

    fn req() -> BatchRequest {
        BatchRequest::new(vec![
            BatchEntry::obj("b", "o1"),
            BatchEntry::member("b", "s.tar", "m1"),
        ])
        .continue_on_err(true)
    }

    #[test]
    fn dt_register_roundtrip() {
        let m = DtRegister {
            req_id: 99,
            request: req(),
            num_senders: 15,
            tenant: "trainer-a".into(),
            priority: "bulk".into(),
        };
        let back = DtRegister::from_body(&m.to_body()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn legacy_register_body_defaults_tenant() {
        // A pre-QoS body (no tenant/priority fields at all) must keep
        // parsing: default tenant, empty priority (resolved to the node
        // default at admission).
        let legacy = b"{\"num_senders\":3,\"req_id\":7,\"request\":{\"in\":[]}}";
        let reg = DtRegister::from_body(legacy).unwrap();
        assert_eq!(reg.tenant, DEFAULT_TENANT);
        assert_eq!(reg.priority, "");
        assert_eq!(reg.req_id, 7);
        assert_eq!(reg.num_senders, 3);
        // ...and the QoS-less splice helper lands in the default bucket too.
        let reg =
            DtRegister::from_body(&DtRegister::body_with_raw(8, 1, "{\"in\":[]}")).unwrap();
        assert_eq!(reg.tenant, DEFAULT_TENANT);
        assert_eq!(reg.priority, "");
    }

    #[test]
    fn qos_register_body_roundtrips_and_sanitizes() {
        let raw = String::from_utf8(req().to_body()).unwrap();
        let b = DtRegister::body_with_raw_qos(42, 2, "team.a-1", "interactive", &raw);
        let reg = DtRegister::from_body(&b).unwrap();
        assert_eq!(reg.tenant, "team.a-1");
        assert_eq!(reg.priority, "interactive");
        assert_eq!(reg.request, req());
        // Header-borne injection attempts are stripped, not spliced: the
        // body still parses and the tenant keeps only the safe charset.
        let evil = DtRegister::body_with_raw_qos(1, 0, "x\",\"priority\":\"interactive", "b{lk", &raw);
        let reg = DtRegister::from_body(&evil).unwrap();
        assert_eq!(reg.tenant, "xpriorityinteractive");
        assert_eq!(reg.priority, "blk");
        // An all-invalid tenant collapses to the default bucket.
        assert_eq!(sanitize_tenant("{\"}"), DEFAULT_TENANT);
        assert_eq!(sanitize_tenant(""), DEFAULT_TENANT);
        let long = "a".repeat(100);
        assert_eq!(sanitize_tenant(&long).len(), 64, "names capped at 64 chars");
    }

    #[test]
    fn sender_activate_roundtrip() {
        let m = SenderActivate { req_id: 7, dt_peer: "127.0.0.1:9999".into(), request: req() };
        let back = SenderActivate::from_body(&m.to_body()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn object_path_parse() {
        assert_eq!(
            parse_object_path("/v1/objects/audio/shards/s-001.tar"),
            Some(("audio".into(), "shards/s-001.tar".into()))
        );
        assert_eq!(parse_object_path("/v1/objects/audio"), None);
        assert_eq!(parse_object_path("/v1/other/x/y"), None);
        assert_eq!(object_path("b", "o/p"), "/v1/objects/b/o/p");
    }

    #[test]
    fn malformed_control_bodies() {
        assert!(DtRegister::from_body(b"{}").is_none());
        assert!(SenderActivate::from_body(b"junk").is_none());
    }
}
