//! Control-plane messages and URL layout for the GetBatch execution flow
//! (§2.3.1): DT registration, sender activation, and the public API paths.

use crate::batch::request::BatchRequest;
use crate::util::json::Value;

/// Public API paths (client ⇄ proxy/target).
pub mod paths {
    /// GET/PUT a single object: `/v1/objects/{bucket}/{obj...}`.
    pub const OBJECTS: &str = "/v1/objects/";
    /// GetBatch: GET with JSON body: `/v1/batch`.
    pub const BATCH: &str = "/v1/batch";
    /// Intra-cluster: DT registration (proxy → target).
    pub const DT_REGISTER: &str = "/v1/xact/dt-register";
    /// Intra-cluster: sender activation broadcast (proxy → targets).
    pub const SENDER_ACTIVATE: &str = "/v1/xact/sender-activate";
    /// DT serves the assembled stream here after redirect (client → DT).
    pub const DT_STREAM: &str = "/v1/xact/stream";
    /// Prometheus exposition.
    pub const METRICS: &str = "/metrics";
    /// Cluster map for SDK bootstrap.
    pub const SMAP: &str = "/v1/cluster/smap";
    /// Health check.
    pub const HEALTH: &str = "/v1/health";
    /// List a bucket's objects: `/v1/list?bucket={bucket}`. Targets serve
    /// their local subset; proxies fan out and merge. The remote store
    /// backend's `list` rides this.
    pub const LIST: &str = "/v1/list";
    /// Cache-coherence invalidation:
    /// `POST /v1/invalidate?bucket={bucket}&obj={obj}`. On a **target** it
    /// drops the object's cached chunks and shard index; on a **proxy** it
    /// fans the same call out to every target in the smap (how an external
    /// writer notifies a whole serving cluster). Best-effort: a missed
    /// delivery degrades to versioned-key revalidation after
    /// `coherence_grace_ms`, never to a stale read forever.
    pub const INVALIDATE: &str = "/v1/invalidate";
    /// Epoch prefetch: `POST /v1/prefetch?bucket={bucket}&obj={obj}`
    /// (optional `&horizon={batches}` — observability only, surfaces the
    /// planner's current horizon on the serving node's gauge). On a
    /// **proxy** it 307-redirects to the object's HRW owner target — the
    /// node whose chunk cache will serve the upcoming demand read; on a
    /// **target** it warms the object's chunks through the bucket's
    /// caching tier (a no-op for uncached buckets) and returns the number
    /// of chunks admitted. Best-effort: a failed prefetch costs the warm
    /// hit, never correctness.
    pub const PREFETCH: &str = "/v1/prefetch";
}

/// Response header carrying an object's PUT-time CRC-32 sidecar (8 hex
/// chars) on object GETs — how the remote backend and GFN splice recovery
/// learn a stored content hash without an extra round trip.
pub const HDR_OBJ_CRC: &str = "x-getbatch-crc32";

/// Response header carrying an object's monotonic write generation
/// (decimal) on object GETs — how a remote caching tier pins the version
/// its chunk keys are derived from. Absent when the serving tier has no
/// version for the object (pre-versioning sidecar).
pub const HDR_OBJ_VERSION: &str = "x-getbatch-version";

/// Query parameter carrying the colocation hint (§2.4.1: "clients provide a
/// colocation hint via a query parameter" so the proxy knows to unmarshal).
pub const QPARAM_COLOC: &str = "coloc";
/// Query parameter carrying the execution id on intra-cluster calls.
pub const QPARAM_REQ_ID: &str = "req";

/// DT registration payload: the full batch request, forwarded verbatim by
/// the proxy (phase 1 — the proxy does *not* unmarshal it in the default
/// opaque-routing mode; it re-serializes only when colocation was applied).
#[derive(Debug, Clone, PartialEq)]
pub struct DtRegister {
    pub req_id: u64,
    pub request: BatchRequest,
    /// How many senders will be activated (so the DT knows when fan-in is
    /// complete even if it owns zero entries).
    pub num_senders: u32,
}

impl DtRegister {
    /// Build the wire body splicing an already-serialized request verbatim
    /// (proxy hot path: no re-serialization of the entry list).
    pub fn body_with_raw(req_id: u64, num_senders: u32, raw_request: &str) -> Vec<u8> {
        format!(
            "{{\"num_senders\":{num_senders},\"req_id\":{req_id},\"request\":{raw_request}}}"
        )
        .into_bytes()
    }

    pub fn to_body(&self) -> Vec<u8> {
        Value::obj()
            .set("req_id", Value::num(self.req_id as f64))
            .set("num_senders", Value::num(self.num_senders as f64))
            .set("request", self.request.to_json())
            .to_string()
            .into_bytes()
    }

    pub fn from_body(b: &[u8]) -> Option<DtRegister> {
        let v = Value::parse(std::str::from_utf8(b).ok()?).ok()?;
        Some(DtRegister {
            req_id: v.u64_field("req_id")?,
            num_senders: v.u64_field("num_senders")? as u32,
            request: BatchRequest::from_json(v.get("request")?)?,
        })
    }
}

/// Sender activation payload (phase 2): tells a target which execution to
/// join and where the DT's peer endpoint is. Each sender re-derives its own
/// slice of the entry list from placement — senders are autonomous (§2.3.1).
#[derive(Debug, Clone, PartialEq)]
pub struct SenderActivate {
    pub req_id: u64,
    /// P2P address (host:port) of the DT's transport endpoint.
    pub dt_peer: String,
    pub request: BatchRequest,
}

impl SenderActivate {
    /// Raw-splice variant (see `DtRegister::body_with_raw`).
    pub fn body_with_raw(req_id: u64, dt_peer: &str, raw_request: &str) -> Vec<u8> {
        format!(
            "{{\"dt_peer\":\"{dt_peer}\",\"req_id\":{req_id},\"request\":{raw_request}}}"
        )
        .into_bytes()
    }

    pub fn to_body(&self) -> Vec<u8> {
        Value::obj()
            .set("req_id", Value::num(self.req_id as f64))
            .set("dt_peer", Value::str(&self.dt_peer))
            .set("request", self.request.to_json())
            .to_string()
            .into_bytes()
    }

    pub fn from_body(b: &[u8]) -> Option<SenderActivate> {
        let v = Value::parse(std::str::from_utf8(b).ok()?).ok()?;
        Some(SenderActivate {
            req_id: v.u64_field("req_id")?,
            dt_peer: v.str_field("dt_peer")?.to_string(),
            request: BatchRequest::from_json(v.get("request")?)?,
        })
    }
}

/// Split an object-API path: `/v1/objects/{bucket}/{obj...}` → (bucket, obj).
pub fn parse_object_path(path: &str) -> Option<(String, String)> {
    let rest = path.strip_prefix(paths::OBJECTS)?;
    let (bucket, obj) = rest.split_once('/')?;
    if bucket.is_empty() || obj.is_empty() {
        return None;
    }
    Some((bucket.to_string(), obj.to_string()))
}

pub fn object_path(bucket: &str, obj: &str) -> String {
    format!("{}{}/{}", paths::OBJECTS, bucket, obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchEntry;

    fn req() -> BatchRequest {
        BatchRequest::new(vec![
            BatchEntry::obj("b", "o1"),
            BatchEntry::member("b", "s.tar", "m1"),
        ])
        .continue_on_err(true)
    }

    #[test]
    fn dt_register_roundtrip() {
        let m = DtRegister { req_id: 99, request: req(), num_senders: 15 };
        let back = DtRegister::from_body(&m.to_body()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn sender_activate_roundtrip() {
        let m = SenderActivate { req_id: 7, dt_peer: "127.0.0.1:9999".into(), request: req() };
        let back = SenderActivate::from_body(&m.to_body()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn object_path_parse() {
        assert_eq!(
            parse_object_path("/v1/objects/audio/shards/s-001.tar"),
            Some(("audio".into(), "shards/s-001.tar".into()))
        );
        assert_eq!(parse_object_path("/v1/objects/audio"), None);
        assert_eq!(parse_object_path("/v1/other/x/y"), None);
        assert_eq!(object_path("b", "o/p"), "/v1/objects/b/o/p");
    }

    #[test]
    fn malformed_control_bodies() {
        assert!(DtRegister::from_body(b"{}").is_none());
        assert!(SenderActivate::from_body(b"junk").is_none());
    }
}
