//! Wire protocols: minimal HTTP/1.1 (client ⇄ proxy/target), the P2P frame
//! protocol used between targets (sender → DT fan-in), and the GetBatch
//! JSON request/response schema.

pub mod http;
pub mod frame;
pub mod wire;
