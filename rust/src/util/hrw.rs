//! Highest-random-weight (rendezvous) hashing — AIStore's placement scheme.
//!
//! Every (key, node) pair gets a pseudo-random weight; the key is owned by
//! the node with the highest weight. Properties the cluster relies on:
//! deterministic, uniform, and *minimally disruptive* — removing a node only
//! remaps the keys that node owned. The proxy also uses HRW to pick the
//! default Designated Target per request (§2.3.1 "consistent hashing").

use super::rng::mix64;

/// 64-bit FNV-1a — stable string hash (std's SipHash is seed-randomized per
/// process, which would break cross-node placement agreement).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Weight of `key` on a node identified by `node_id_hash`.
#[inline]
pub fn weight(key_hash: u64, node_id_hash: u64) -> u64 {
    mix64(key_hash ^ node_id_hash)
}

/// Pick the index of the highest-weight node for `key`.
/// `node_hashes` are precomputed per-node id hashes.
pub fn pick(key: &str, node_hashes: &[u64]) -> usize {
    assert!(!node_hashes.is_empty(), "hrw over empty node set");
    let kh = fnv1a(key.as_bytes());
    let mut best = 0usize;
    let mut best_w = 0u64;
    for (i, &nh) in node_hashes.iter().enumerate() {
        let w = weight(kh, nh);
        if w > best_w {
            best_w = w;
            best = i;
        }
    }
    best
}

/// Rank all nodes for `key`, best first — used by get-from-neighbor (GFN)
/// recovery to find the next-best replica location.
pub fn rank(key: &str, node_hashes: &[u64]) -> Vec<usize> {
    let kh = fnv1a(key.as_bytes());
    let mut idx: Vec<usize> = (0..node_hashes.len()).collect();
    idx.sort_by_key(|&i| std::cmp::Reverse(weight(kh, node_hashes[i])));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hashes(n: usize) -> Vec<u64> {
        (0..n).map(|i| fnv1a(format!("t{}", i).as_bytes())).collect()
    }

    #[test]
    fn deterministic() {
        let h = hashes(8);
        for k in 0..100 {
            let key = format!("obj-{k}");
            assert_eq!(pick(&key, &h), pick(&key, &h));
        }
    }

    #[test]
    fn roughly_uniform() {
        let h = hashes(8);
        let mut counts = vec![0usize; 8];
        let n = 16_000;
        for k in 0..n {
            counts[pick(&format!("obj-{k}"), &h)] += 1;
        }
        let expect = n / 8;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < expect as f64 * 0.15,
                "node {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn minimal_disruption_on_node_removal() {
        let h8 = hashes(8);
        let h7 = h8[..7].to_vec(); // remove last node
        let n = 8000;
        let mut moved = 0;
        for k in 0..n {
            let key = format!("obj-{k}");
            let before = pick(&key, &h8);
            let after = pick(&key, &h7);
            if before < 7 {
                // keys not owned by the removed node must not move
                assert_eq!(before, after, "key {key} moved unnecessarily");
            } else {
                moved += 1;
            }
        }
        // ~1/8 of keys lived on the removed node
        assert!((moved as f64 - n as f64 / 8.0).abs() < n as f64 * 0.03);
    }

    #[test]
    fn rank_starts_with_pick() {
        let h = hashes(5);
        for k in 0..50 {
            let key = format!("obj-{k}");
            let r = rank(&key, &h);
            assert_eq!(r[0], pick(&key, &h));
            let mut sorted = r.clone();
            sorted.sort();
            assert_eq!(sorted, vec![0, 1, 2, 3, 4]); // a permutation
        }
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("") = offset basis
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }
}
