//! Minimal, dependency-free JSON codec.
//!
//! The sandbox has no `serde`, so GetBatch wire bodies (§2.2: "a GetBatch
//! request is issued as an HTTP GET with a JSON body") and the config system
//! use this hand-rolled `Value` tree. The parser is a straightforward
//! recursive-descent over bytes; the serializer emits compact JSON with
//! escaped strings. Numbers are kept as `f64` (adequate: the wire format
//! carries sizes/counts well below 2^53) with an integer fast path on output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic —
/// handy for golden tests and request hashing.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- constructors -----------------------------------------------------
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Insert into an object value (panics if not an object — builder use).
    pub fn set(mut self, k: &str, v: Value) -> Value {
        match &mut self {
            Value::Obj(m) => {
                m.insert(k.to_string(), v);
            }
            _ => panic!("Value::set on non-object"),
        }
        self
    }

    // -- accessors --------------------------------------------------------
    pub fn get(&self, k: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(k),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    // typed helpers used all over the wire layer
    pub fn str_field(&self, k: &str) -> Option<&str> {
        self.get(k).and_then(|v| v.as_str())
    }
    pub fn u64_field(&self, k: &str) -> Option<u64> {
        self.get(k).and_then(|v| v.as_u64())
    }
    pub fn bool_field(&self, k: &str) -> Option<bool> {
        self.get(k).and_then(|v| v.as_bool())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::with_capacity(64);
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                // Integer fast path keeps sizes/counts round-trippable.
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected byte")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: peek for a following low surrogate.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i + 1..].starts_with(b"\\u") && self.i + 6 < self.b.len() {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 3..self.i + 7])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                cp
                            };
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(b) if b < 0x80 => {
                    // ASCII fast path — and crucially O(1): re-validating the
                    // whole remaining buffer per char made parsing O(n²),
                    // which dominated the DT's request-unmarshal hot path
                    // (see EXPERIMENTS.md §Perf).
                    out.push(b as char);
                    self.i += 1;
                }
                Some(b) => {
                    // Multi-byte UTF-8 scalar: decode just its 2-4 bytes.
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    let end = (self.i + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[self.i..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().ok_or_else(|| self.err("invalid utf-8"))?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

/// Convenience: build a JSON array of strings.
pub fn str_arr<'a>(items: impl IntoIterator<Item = &'a str>) -> Value {
    Value::Arr(items.into_iter().map(Value::str).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = Value::parse(s).unwrap();
            assert_eq!(Value::parse(&v.to_string()).unwrap(), v, "{}", s);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_u64().unwrap(), 2);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"abc").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("tab\t quote\" slash\\ nl\n ctrl\u{1}".to_string());
        let parsed = Value::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn unicode_escape() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn builder_and_accessors() {
        let v = Value::obj()
            .set("name", Value::str("shard-001.tar"))
            .set("size", Value::num(1024u32))
            .set("ok", Value::Bool(true));
        assert_eq!(v.str_field("name").unwrap(), "shard-001.tar");
        assert_eq!(v.u64_field("size").unwrap(), 1024);
        assert!(v.bool_field("ok").unwrap());
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn deterministic_object_order() {
        let a = Value::obj().set("z", Value::num(1u32)).set("a", Value::num(2u32));
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn big_ints_roundtrip() {
        let v = Value::parse("1099511627776").unwrap(); // 1 TiB
        assert_eq!(v.as_u64().unwrap(), 1 << 40);
        assert_eq!(v.to_string(), "1099511627776");
    }
}
