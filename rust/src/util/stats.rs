//! Latency / throughput statistics: exact percentile sampling and a
//! log-bucketed histogram for high-volume paths. Powers the Table 1/2
//! reproductions (P50/P95/P99/Avg) and the aisloader reports.

use std::time::Duration;

/// Reservoir of raw samples with exact percentiles. For the scales in this
/// repo (≤ a few million samples) exact is affordable and avoids P²-style
/// estimation error in the tails the paper cares about.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Samples {
        Samples::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn add_duration(&mut self, d: Duration) {
        self.add(d.as_secs_f64() * 1e3); // milliseconds, like the paper
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn merge(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile in [0,100], linear interpolation between closest ranks.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let n = self.xs.len();
        if n == 1 {
            return self.xs[0];
        }
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(f64::NAN)
    }

    /// The paper's standard row: P50 / P95 / P99 / Avg.
    pub fn row(&mut self) -> LatencyRow {
        LatencyRow {
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            avg: self.mean(),
            n: self.len(),
        }
    }
}

/// One row of a Table-2-style latency report (values in the unit recorded,
/// conventionally milliseconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyRow {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub avg: f64,
    pub n: usize,
}

impl LatencyRow {
    /// §4.2.2: the P99–P50 absolute spread that governs step-time jitter.
    pub fn spread(&self) -> f64 {
        self.p99 - self.p50
    }
}

impl std::fmt::Display for LatencyRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "P50={:9.1}  P95={:9.1}  P99={:9.1}  Avg={:9.1}  (n={})",
            self.p50, self.p95, self.p99, self.avg, self.n
        )
    }
}

/// Log2-bucketed histogram: O(1) record, coarse percentiles; used on hot
/// per-object paths where storing raw f64s per op would distort timing.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// bucket i counts values in [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { buckets: vec![0; 64], count: 0, sum: 0.0 }
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    pub fn record_us(&mut self, us: f64) {
        let b = if us < 1.0 { 0 } else { (us.log2() as usize).min(63) };
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += us;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile estimate: geometric midpoint of the containing bucket.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                let lo = (1u64 << i) as f64;
                return lo * std::f64::consts::SQRT_2;
            }
        }
        f64::NAN
    }
}

/// Throughput accounting over a measurement window.
#[derive(Debug, Clone, Copy, Default)]
pub struct Throughput {
    pub bytes: u64,
    pub ops: u64,
    pub secs: f64,
}

impl Throughput {
    pub fn gib_per_sec(&self) -> f64 {
        if self.secs == 0.0 {
            return 0.0;
        }
        self.bytes as f64 / self.secs / (1u64 << 30) as f64
    }
    pub fn ops_per_sec(&self) -> f64 {
        if self.secs == 0.0 {
            return 0.0;
        }
        self.ops as f64 / self.secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn single_sample() {
        let mut s = Samples::new();
        s.add(7.0);
        assert_eq!(s.percentile(50.0), 7.0);
        assert_eq!(s.percentile(99.0), 7.0);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Samples::new();
        assert!(s.percentile(50.0).is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn merge_combines() {
        let mut a = Samples::new();
        let mut b = Samples::new();
        for i in 0..50 {
            a.add(i as f64);
        }
        for i in 50..100 {
            b.add(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.len(), 100);
        assert!((a.percentile(50.0) - 49.5).abs() < 1e-9);
    }

    #[test]
    fn row_and_spread() {
        let mut s = Samples::new();
        for i in 0..1000 {
            s.add(i as f64);
        }
        let r = s.row();
        assert!(r.p99 > r.p95 && r.p95 > r.p50);
        assert!((r.spread() - (r.p99 - r.p50)).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_coarse() {
        let mut h = LogHistogram::new();
        for _ in 0..900 {
            h.record_us(100.0); // bucket [64,128)
        }
        for _ in 0..100 {
            h.record_us(10_000.0);
        }
        let p50 = h.percentile_us(50.0);
        assert!(p50 > 32.0 && p50 < 256.0, "p50={p50}");
        let p99 = h.percentile_us(99.0);
        assert!(p99 > 4096.0, "p99={p99}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn throughput_math() {
        let t = Throughput { bytes: 3 << 30, ops: 1500, secs: 2.0 };
        assert!((t.gib_per_sec() - 1.5).abs() < 1e-9);
        assert!((t.ops_per_sec() - 750.0).abs() < 1e-9);
    }
}
