//! Foundation utilities built from scratch for the offline sandbox: JSON
//! codec, PRNG, streaming statistics, rendezvous hashing, thread pool,
//! virtual clock, byte-size helpers, a minimal CLI parser, CRC-32, and the
//! `anyhow`-style error type (the build has no external crates).

pub mod crc32;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod hrw;
pub mod threadpool;
pub mod clock;
pub mod bytes;
pub mod cli;
