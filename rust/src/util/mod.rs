//! Foundation utilities built from scratch for the offline sandbox: JSON
//! codec, PRNG, streaming statistics, rendezvous hashing, thread pool,
//! virtual clock, byte-size helpers and a minimal CLI parser.

pub mod json;
pub mod rng;
pub mod stats;
pub mod hrw;
pub mod threadpool;
pub mod clock;
pub mod bytes;
pub mod cli;
