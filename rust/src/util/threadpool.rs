//! Fixed-size worker pool (no tokio in the offline sandbox). Each cluster
//! node runs one pool for request handling; aisloader and the benches use
//! `scoped_map` for fork-join fan-out.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A classic channel-fed thread pool. Jobs are `FnOnce` closures; `drop`
/// joins all workers after draining the queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize, name: &str) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                queued.fetch_sub(1, Ordering::Relaxed);
                                job();
                            }
                            Err(_) => break, // sender dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, queued }
    }

    /// Enqueue a job. Never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx.as_ref().expect("pool shut down").send(Box::new(f)).expect("workers alive");
    }

    /// Jobs submitted but not yet started (approximate).
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join: run `f(i, &items[i])` on up to `par` OS threads and collect
/// results in input order. Panics in workers propagate.
pub fn scoped_map<T: Sync, R: Send>(
    items: &[T],
    par: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let par = par.clamp(1, n);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..par {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                // Each index is written exactly once; the mutex only guards
                // the &mut aliasing, contention is one lock per item.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_parallelism() {
        // 4 workers × 50ms sleeps for 8 jobs should take ~100ms, not 400ms.
        let pool = ThreadPool::new(4, "par");
        let t0 = std::time::Instant::now();
        let done = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                thread::sleep(Duration::from_millis(50));
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        let el = t0.elapsed();
        assert_eq!(done.load(Ordering::Relaxed), 8);
        assert!(el < Duration::from_millis(350), "elapsed {el:?}");
    }

    #[test]
    fn scoped_map_preserves_order() {
        let items: Vec<u64> = (0..200).collect();
        let out = scoped_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty_and_single() {
        let out: Vec<u32> = scoped_map(&[] as &[u32], 4, |_, &x| x);
        assert!(out.is_empty());
        let out = scoped_map(&[5u32], 4, |i, &x| x + i as u32);
        assert_eq!(out, vec![5]);
    }
}
