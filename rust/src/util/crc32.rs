//! CRC-32 (IEEE 802.3, the zlib/`crc32fast` polynomial) — dependency-free
//! replacement for the `crc32fast` crate in the offline build. Slice-by-8
//! table lookup: ~1 byte/cycle, plenty for the frame-protocol checksum on
//! the intra-cluster path (the socket, not the CRC, is the bottleneck).

const POLY: u32 = 0xEDB8_8320; // reflected 0x04C11DB7

/// 8 tables x 256 entries, built at first use.
fn tables() -> &'static [[u32; 256]; 8] {
    use std::sync::OnceLock;
    static TABLES: OnceLock<Box<[[u32; 256]; 8]>> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = Box::new([[0u32; 256]; 8]);
        for i in 0..256u32 {
            let mut c = i;
            for _ in 0..8 {
                c = if c & 1 != 0 { (c >> 1) ^ POLY } else { c >> 1 };
            }
            t[0][i as usize] = c;
        }
        for i in 0..256 {
            let mut c = t[0][i];
            for k in 1..8 {
                c = t[0][(c & 0xFF) as usize] ^ (c >> 8);
                t[k][i] = c;
            }
        }
        t
    })
}

/// Streaming CRC-32 state (matches `crc32fast::Hasher` usage).
#[derive(Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Hasher::new()
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: !0 }
    }

    pub fn update(&mut self, mut buf: &[u8]) {
        let t = tables();
        let mut crc = self.state;
        while buf.len() >= 8 {
            let lo = crc ^ u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
            crc = t[7][(lo & 0xFF) as usize]
                ^ t[6][((lo >> 8) & 0xFF) as usize]
                ^ t[5][((lo >> 16) & 0xFF) as usize]
                ^ t[4][(lo >> 24) as usize]
                ^ t[3][buf[4] as usize]
                ^ t[2][buf[5] as usize]
                ^ t[1][buf[6] as usize]
                ^ t[0][buf[7] as usize];
            buf = &buf[8..];
        }
        for &b in buf {
            crc = t[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
        }
        self.state = crc;
    }

    pub fn finalize(self) -> u32 {
        !self.state
    }

    /// Rebuild a streaming state from a previously `finalize`d CRC, so a
    /// checksum can be extended across a splice boundary (the DT's ranged
    /// GFN recovery resumes the emitted-prefix CRC this way).
    /// `Hasher::resume(h.finalize())` continues exactly where `h` left off.
    pub fn resume(crc: u32) -> Hasher {
        Hasher { state: !crc }
    }
}

/// One-shot hash of a buffer (drop-in for `crc32fast::hash`).
pub fn hash(buf: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(buf);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard CRC-32 test vectors (zlib-compatible).
        assert_eq!(hash(b""), 0x0000_0000);
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 31 % 251) as u8).collect();
        let whole = hash(&data);
        for split in [0, 1, 7, 8, 9, 500, 1023, 1024] {
            let mut h = Hasher::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), whole, "split={split}");
        }
    }

    #[test]
    fn resume_continues_finalized_state() {
        let data: Vec<u8> = (0..777u32).map(|i| (i * 13 % 251) as u8).collect();
        for split in [0, 1, 100, 776, 777] {
            let mut a = Hasher::new();
            a.update(&data[..split]);
            let mut b = Hasher::resume(a.finalize());
            b.update(&data[split..]);
            assert_eq!(b.finalize(), hash(&data), "split={split}");
        }
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = vec![0u8; 100];
        let mut b = a.clone();
        b[50] ^= 1;
        assert_ne!(hash(&a), hash(&b));
    }
}
