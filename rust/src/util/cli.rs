//! Minimal CLI argument parser (no `clap` offline): subcommand + `--flag
//! value` / `--flag=value` / boolean `--flag` options + positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argv strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    pub fn str_or(&self, k: &str, default: &str) -> String {
        self.str(k).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, k: &str, default: u64) -> u64 {
        self.str(k).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, k: &str, default: usize) -> usize {
        self.u64_or(k, default as u64) as usize
    }

    pub fn f64_or(&self, k: &str, default: f64) -> f64 {
        self.str(k).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, k: &str) -> bool {
        matches!(self.str(k), Some("true") | Some("1") | Some("yes"))
    }

    /// Size flag: accepts "10KiB" etc.
    pub fn size_or(&self, k: &str, default: u64) -> u64 {
        self.str(k).and_then(super::bytes::parse_size_or_num).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("bench pos1 --workers 8 --size=10KiB --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.u64_or("workers", 1), 8);
        assert_eq!(a.size_or("size", 0), 10 << 10);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.u64_or("n", 42), 42);
        assert_eq!(a.str_or("mode", "live"), "live");
        assert!(!a.bool("flag"));
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("x --coer");
        assert!(a.bool("coer"));
    }
}
