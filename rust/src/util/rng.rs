//! Deterministic PRNG (no `rand` crate offline): SplitMix64 for seeding and
//! xoshiro256** as the workhorse generator. Used by samplers, workload
//! generators, the simulator and the property-testing framework — everything
//! that must be reproducible from a seed.

/// SplitMix64 step — also used standalone for hashing/mixing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Mix a 64-bit value (stateless finalizer).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)` via Lemire's unbiased multiply-shift.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given median and sigma (of the underlying normal) —
    /// used for "audio-like" variable sample sizes in the training workload.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Exponential with mean `mean` — inter-arrival times.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Pareto (heavy tail) with scale `xm` and shape `alpha` — models
    /// straggler service times.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm / (1.0 - self.f64()).powf(1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), order random.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index map keeps this O(k) in memory
        // when k << n would matter; n here is modest so use the simple form.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fill a byte slice with pseudorandom data (synthetic object payloads).
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(5);
        let mut buf = vec![0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }
}
