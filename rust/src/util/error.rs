//! Minimal `anyhow`-compatible error type for the offline build (no crates).
//!
//! Implements the slice of the `anyhow` API this repo uses: `Error` (boxed
//! dynamic error with a context chain), `Result<T>`, the `anyhow!`/`bail!`/
//! `ensure!` macros and the `Context` extension trait on `Result`/`Option`.
//! Files that used the real crate just alias it:
//!
//! ```ignore
//! use crate::util::error as anyhow;   // or `use getbatch::util::error as anyhow;`
//! ```

use std::fmt;

/// Boxed error with optional layered context messages (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// A free-standing message error (what `anyhow!` produces).
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap an underlying error without extra context.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }

    fn wrap(msg: String, source: Box<dyn std::error::Error + Send + Sync + 'static>) -> Error {
        Error { msg, source: Some(source) }
    }

    /// Add a context layer (mirrors `anyhow::Error::context`).
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error { msg: format!("{msg}: {}", self.msg), source: self.source }
    }

    pub fn source_ref(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow-style Debug: message, then the cause chain.
        write!(f, "{}", self.msg)?;
        let mut cur = self.source_ref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

// NOTE: like real `anyhow`, `Error` does NOT implement std::error::Error —
// that's what makes the blanket From<E> below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.map_err(|e| Error::wrap(format!("{msg}: {e}"), Box::new(e)))
    }
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::wrap(format!("{}: {e}", f()), Box::new(e)))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...{}...", args)` → `Error`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` → early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` → bail unless `cond`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

// Re-export the macros as module items so `use ... as anyhow;` callers can
// write `anyhow::anyhow!`, `anyhow::bail!`, `anyhow::ensure!` path-style.
pub use crate::{anyhow, bail, ensure};

/// Declarative replacement for the hand-rolled `Display`/`Error::source`/
/// `From` impl blocks that every error enum in this crate used to carry
/// (the offline stand-in for `thiserror`). The enum itself stays a plain
/// `enum` with its own docs; this macro generates the three impls from a
/// compact spec:
///
/// ```ignore
/// impl_error! {
///     StoreError {
///         display {
///             StoreError::NotFound(k) => "object not found: {k}",
///             StoreError::Io(e) => "io: {e}",
///         }
///         source {
///             StoreError::Io(e) => e,
///         }
///         from {
///             std::io::Error => Io,
///         }
///     }
/// }
/// ```
///
/// * `display` — one arm per variant; the format literal captures the arm's
///   pattern bindings (`{k}`-style inline captures).
/// * `source` (optional) — arms whose bound value is the underlying error;
///   unlisted variants yield `None`.
/// * `from` (optional) — `SourceType => Variant` pairs generating
///   single-field `From` conversions.
#[macro_export]
macro_rules! impl_error {
    (
        $name:ident {
            display { $( $dpat:pat => $dfmt:literal ),+ $(,)? }
            $( source { $( $spat:pat => $sexpr:expr ),* $(,)? } )?
            $( from { $( $fty:ty => $fvar:ident ),* $(,)? } )?
        }
    ) => {
        impl ::std::fmt::Display for $name {
            fn fmt(&self, f: &mut ::std::fmt::Formatter<'_>) -> ::std::fmt::Result {
                match self {
                    $( $dpat => write!(f, $dfmt), )+
                }
            }
        }

        impl ::std::error::Error for $name {
            #[allow(unused_variables, unreachable_patterns, clippy::match_single_binding)]
            fn source(&self) -> Option<&(dyn ::std::error::Error + 'static)> {
                match self {
                    $( $( $spat => Some($sexpr), )* )?
                    _ => None,
                }
            }
        }

        $( $(
            impl ::std::convert::From<$fty> for $name {
                fn from(e: $fty) -> $name {
                    $name::$fvar(e)
                }
            }
        )* )?
    };
}

pub use crate::impl_error;

#[cfg(test)]
mod tests {
    use super::*;
    use std::io;

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io::Error::new(io::ErrorKind::Other, "boom"))?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("boom"));
        assert!(e.source_ref().is_some());
    }

    #[test]
    fn macros_and_context() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert!(inner(20).unwrap_err().to_string().contains("too big"));
        assert!(inner(5).unwrap_err().to_string().contains("right out"));

        let r: Result<u32, io::Error> = Err(io::Error::new(io::ErrorKind::NotFound, "nf"));
        let e = Context::context(r, "reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config:"), "{e}");

        let o: Option<u32> = None;
        assert!(Context::context(o, "missing field").is_err());
    }

    #[test]
    fn impl_error_macro_generates_all_three_impls() {
        #[derive(Debug)]
        enum DemoError {
            Missing(String),
            Io(io::Error),
            Span { from: u64, to: u64 },
        }
        crate::impl_error! {
            DemoError {
                display {
                    DemoError::Missing(k) => "missing: {k}",
                    DemoError::Io(e) => "io: {e}",
                    DemoError::Span { from, to } => "bad span {from}..{to}",
                }
                source {
                    DemoError::Io(e) => e,
                }
                from {
                    io::Error => Io,
                }
            }
        }
        let m = DemoError::Missing("x".into());
        assert_eq!(m.to_string(), "missing: x");
        assert_eq!(DemoError::Span { from: 3, to: 9 }.to_string(), "bad span 3..9");
        let io_err: DemoError = io::Error::new(io::ErrorKind::Other, "boom").into();
        assert!(io_err.to_string().contains("boom"));
        assert!(std::error::Error::source(&io_err).is_some());
        assert!(std::error::Error::source(&m).is_none());
    }

    #[test]
    fn debug_prints_chain() {
        let r: Result<(), io::Error> = Err(io::Error::new(io::ErrorKind::Other, "root"));
        let e = Context::context(r, "outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"), "{dbg}");
    }
}
