//! Time abstraction shared by the live cluster and the discrete-event
//! simulator: real code paths take a `Clock` so latency-model tests can run
//! on virtual time while production uses the monotonic clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Monotonic nanosecond clock.
pub trait Clock: Send + Sync {
    fn now_ns(&self) -> u64;
    fn sleep(&self, d: Duration);
    /// `true` when time only moves because somebody advances it. Blocking
    /// primitives (condvar waits) must not park on a virtual clock — time
    /// would never pass for them; they advance the clock instead.
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Wall (monotonic) clock.
pub struct RealClock {
    origin: Instant,
}

impl Default for RealClock {
    fn default() -> Self {
        RealClock { origin: Instant::now() }
    }
}

impl RealClock {
    pub fn new() -> Arc<dyn Clock> {
        Arc::new(RealClock::default())
    }
}

impl Clock for RealClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Virtual clock: `sleep` advances time atomically, no real waiting. Used in
/// throttling/admission unit tests and the simulator's cost models.
#[derive(Default)]
pub struct VirtualClock {
    ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Arc<VirtualClock> {
        Arc::new(VirtualClock::default())
    }
    pub fn advance(&self, d: Duration) {
        self.ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
    /// Jump forward to an absolute instant (no-op if `ns` is in the past) —
    /// the discrete-event simulator sets the clock to each event's
    /// timestamp before dispatching it.
    pub fn advance_to(&self, ns: u64) {
        self.ns.fetch_max(ns, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
    fn is_virtual(&self) -> bool {
        true
    }
}

/// Stopwatch over any `Clock`.
pub struct Stopwatch<'a> {
    clock: &'a dyn Clock,
    start: u64,
}

impl<'a> Stopwatch<'a> {
    pub fn start(clock: &'a dyn Clock) -> Stopwatch<'a> {
        Stopwatch { clock, start: clock.now_ns() }
    }
    pub fn elapsed(&self) -> Duration {
        Duration::from_nanos(self.clock.now_ns().saturating_sub(self.start))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::default();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_sleep_advances() {
        let c = VirtualClock::default();
        assert_eq!(c.now_ns(), 0);
        c.sleep(Duration::from_millis(5));
        assert_eq!(c.now_ns(), 5_000_000);
        c.advance(Duration::from_micros(1));
        assert_eq!(c.now_ns(), 5_001_000);
    }

    #[test]
    fn advance_to_is_monotonic() {
        let c = VirtualClock::default();
        c.advance_to(7_000);
        assert_eq!(c.now_ns(), 7_000);
        c.advance_to(3_000); // the past: no-op
        assert_eq!(c.now_ns(), 7_000);
        assert!(c.is_virtual());
        assert!(!RealClock::default().is_virtual());
    }

    #[test]
    fn stopwatch_on_virtual() {
        let c = VirtualClock::default();
        let sw = Stopwatch::start(&c);
        c.advance(Duration::from_millis(3));
        assert_eq!(sw.elapsed(), Duration::from_millis(3));
    }
}
