//! Byte-size parsing/formatting ("10KiB", "1MiB") and a small buffer pool
//! used on the DT assembly hot path to avoid per-item allocations.

use std::sync::Mutex;

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

/// Parse "10KiB" / "1MiB" / "4k" / "123" into bytes.
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !c.is_ascii_digit() && c != '.')?;
    let (num, unit) = if split == 0 { return None } else { s.split_at(split) };
    let n: f64 = num.parse().ok()?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "b" | "" => 1,
        "k" | "kb" | "kib" => KIB,
        "m" | "mb" | "mib" => MIB,
        "g" | "gb" | "gib" => GIB,
        "t" | "tb" | "tib" => 1 << 40,
        _ => return None,
    };
    Some((n * mult as f64) as u64)
}

/// Parse with a pure-number fallback.
pub fn parse_size_or_num(s: &str) -> Option<u64> {
    s.trim().parse::<u64>().ok().or_else(|| parse_size(s))
}

/// Human formatting: 1536 → "1.5KiB".
pub fn fmt_size(b: u64) -> String {
    const UNITS: [(&str, u64); 4] = [("GiB", GIB), ("MiB", MIB), ("KiB", KIB), ("B", 1)];
    for (name, m) in UNITS {
        if b >= m {
            let v = b as f64 / m as f64;
            return if v.fract() < 0.05 || m == 1 {
                format!("{:.0}{}", v, name)
            } else {
                format!("{:.1}{}", v, name)
            };
        }
    }
    "0B".to_string()
}

/// A trivial free-list of byte buffers. `get` returns a cleared buffer with
/// at least the requested capacity; `put` recycles it. Bounded so a burst
/// can't pin unbounded memory.
pub struct BufPool {
    pool: Mutex<Vec<Vec<u8>>>,
    max_pooled: usize,
}

impl BufPool {
    pub fn new(max_pooled: usize) -> BufPool {
        BufPool { pool: Mutex::new(Vec::new()), max_pooled }
    }

    pub fn get(&self, cap: usize) -> Vec<u8> {
        let mut pool = self.pool.lock().unwrap();
        if let Some(mut b) = pool.pop() {
            b.clear();
            b.reserve(cap);
            return b;
        }
        drop(pool);
        Vec::with_capacity(cap)
    }

    pub fn put(&self, b: Vec<u8>) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < self.max_pooled {
            pool.push(b);
        }
    }

    pub fn pooled(&self) -> usize {
        self.pool.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("10KiB"), Some(10 * KIB));
        assert_eq!(parse_size("1MiB"), Some(MIB));
        assert_eq!(parse_size("4k"), Some(4 * KIB));
        assert_eq!(parse_size("1.5m"), Some(MIB + MIB / 2));
        assert_eq!(parse_size_or_num("123"), Some(123));
        assert_eq!(parse_size("zz"), None);
        assert_eq!(parse_size(""), None);
    }

    #[test]
    fn fmt_sizes() {
        assert_eq!(fmt_size(10 * KIB), "10KiB");
        assert_eq!(fmt_size(MIB), "1MiB");
        assert_eq!(fmt_size(1536), "1.5KiB");
        assert_eq!(fmt_size(7), "7B");
    }

    #[test]
    fn pool_recycles() {
        let p = BufPool::new(4);
        let mut b = p.get(100);
        b.extend_from_slice(&[1, 2, 3]);
        p.put(b);
        assert_eq!(p.pooled(), 1);
        let b2 = p.get(10);
        assert!(b2.is_empty()); // cleared
        assert!(b2.capacity() >= 10);
        assert_eq!(p.pooled(), 0);
    }

    #[test]
    fn pool_bounded() {
        let p = BufPool::new(2);
        for _ in 0..5 {
            p.put(Vec::with_capacity(8));
        }
        assert_eq!(p.pooled(), 2);
    }
}
