//! Designated Target (DT) machinery — the coordination heart of GetBatch
//! (§2.3): per-request execution state, the strict-order reassembly buffer,
//! TAR assembly (streaming or buffered), soft-error recovery (GFN), and
//! admission control.

pub mod order;
pub mod admission;
pub mod exec;

pub use exec::{DtExec, DtRegistry, StreamOutcome};
pub use order::{OrderBuffer, SlotWait};
