//! Strict-order reassembly buffer.
//!
//! Senders deliver entries out of order from many nodes; the DT must emit
//! them in exact request order (§2.2). The buffer holds one slot per request
//! entry; producers fill arbitrary slots, the single consumer (the assembly
//! loop) blocks on the *next* index it needs — "decoupling heterogeneous
//! read and transfer latencies from output determinism" (§2.3.1 phase 3).

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::batch::error::EntryError;

#[derive(Debug)]
enum Slot {
    Pending,
    Ready(Vec<u8>),
    Failed(EntryError),
    /// Consumed by the assembler (payload moved out).
    Taken,
}

/// Outcome of waiting for one slot.
#[derive(Debug, PartialEq)]
pub enum SlotWait {
    Ready(Vec<u8>),
    Failed(EntryError),
    TimedOut,
}

pub struct OrderBuffer {
    slots: Mutex<Vec<Slot>>,
    cv: Condvar,
    /// Bytes currently resident in Ready slots (DT memory accounting).
    buffered: std::sync::atomic::AtomicI64,
}

impl OrderBuffer {
    pub fn new(n: usize) -> OrderBuffer {
        OrderBuffer {
            slots: Mutex::new((0..n).map(|_| Slot::Pending).collect()),
            cv: Condvar::new(),
            buffered: std::sync::atomic::AtomicI64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn buffered_bytes(&self) -> i64 {
        self.buffered.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Producer: deliver entry payload. First write wins (recovery may race
    /// a late sender); duplicates are dropped.
    pub fn fill(&self, idx: u32, data: Vec<u8>) {
        let mut slots = self.slots.lock().unwrap();
        if let Some(s @ (Slot::Pending | Slot::Failed(_))) = slots.get_mut(idx as usize) {
            self.buffered
                .fetch_add(data.len() as i64, std::sync::atomic::Ordering::Relaxed);
            *s = Slot::Ready(data);
            self.cv.notify_all();
        }
    }

    /// Producer: report a per-entry failure. Never overwrites Ready/Taken.
    pub fn fail(&self, idx: u32, err: EntryError) {
        let mut slots = self.slots.lock().unwrap();
        if let Some(s @ Slot::Pending) = slots.get_mut(idx as usize) {
            *s = Slot::Failed(err);
            self.cv.notify_all();
        }
    }

    /// Consumer: wait until slot `idx` resolves (or `timeout`). Moves the
    /// payload out, releasing DT memory.
    pub fn wait_take(&self, idx: u32, timeout: Duration) -> SlotWait {
        let deadline = Instant::now() + timeout;
        let mut slots = self.slots.lock().unwrap();
        loop {
            match &slots[idx as usize] {
                Slot::Pending => {
                    let now = Instant::now();
                    if now >= deadline {
                        return SlotWait::TimedOut;
                    }
                    let (guard, _t) = self.cv.wait_timeout(slots, deadline - now).unwrap();
                    slots = guard;
                }
                Slot::Ready(_) => {
                    let taken = std::mem::replace(&mut slots[idx as usize], Slot::Taken);
                    if let Slot::Ready(data) = taken {
                        self.buffered
                            .fetch_sub(data.len() as i64, std::sync::atomic::Ordering::Relaxed);
                        return SlotWait::Ready(data);
                    }
                    unreachable!()
                }
                Slot::Failed(e) => {
                    let e = e.clone();
                    slots[idx as usize] = Slot::Taken;
                    return SlotWait::Failed(e);
                }
                Slot::Taken => panic!("slot {idx} consumed twice"),
            }
        }
    }

    /// Non-blocking probe (tests / diagnostics).
    pub fn is_resolved(&self, idx: u32) -> bool {
        !matches!(self.slots.lock().unwrap()[idx as usize], Slot::Pending)
    }

    /// How many slots are resolved (ready, failed, or consumed).
    pub fn resolved_count(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .iter()
            .filter(|s| !matches!(s, Slot::Pending))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn in_order_consumption_of_out_of_order_fills() {
        let buf = Arc::new(OrderBuffer::new(4));
        let b2 = Arc::clone(&buf);
        thread::spawn(move || {
            b2.fill(3, vec![3]);
            b2.fill(1, vec![1]);
            b2.fill(0, vec![0]);
            b2.fill(2, vec![2]);
        });
        for i in 0..4u32 {
            match buf.wait_take(i, Duration::from_secs(2)) {
                SlotWait::Ready(d) => assert_eq!(d, vec![i as u8]),
                other => panic!("slot {i}: {other:?}"),
            }
        }
    }

    #[test]
    fn timeout_on_never_filled() {
        let buf = OrderBuffer::new(1);
        let t0 = Instant::now();
        assert_eq!(buf.wait_take(0, Duration::from_millis(50)), SlotWait::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(50));
    }

    #[test]
    fn failure_propagates() {
        let buf = OrderBuffer::new(2);
        buf.fill(0, vec![9]);
        buf.fail(1, EntryError::NotFound("b/x".into()));
        assert!(matches!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(_)));
        assert!(matches!(
            buf.wait_take(1, Duration::from_secs(1)),
            SlotWait::Failed(EntryError::NotFound(_))
        ));
    }

    #[test]
    fn recovery_can_overwrite_failure() {
        let buf = OrderBuffer::new(1);
        buf.fail(0, EntryError::StreamFailure("rst".into()));
        // GFN recovery delivers the payload after the failure was recorded
        // but before the consumer took it:
        buf.fill(0, vec![7; 3]);
        assert_eq!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(vec![7; 3]));
    }

    #[test]
    fn duplicate_fill_dropped() {
        let buf = OrderBuffer::new(1);
        buf.fill(0, vec![1]);
        buf.fill(0, vec![2]); // late duplicate (e.g. recovery raced sender)
        assert_eq!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(vec![1]));
        assert_eq!(buf.buffered_bytes(), 0, "accounting balanced");
    }

    #[test]
    fn fail_does_not_clobber_ready() {
        let buf = OrderBuffer::new(1);
        buf.fill(0, vec![5]);
        buf.fail(0, EntryError::SenderTimeout(0));
        assert_eq!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(vec![5]));
    }

    #[test]
    fn memory_accounting() {
        let buf = OrderBuffer::new(3);
        buf.fill(0, vec![0; 100]);
        buf.fill(2, vec![0; 50]);
        assert_eq!(buf.buffered_bytes(), 150);
        buf.wait_take(0, Duration::from_secs(1));
        assert_eq!(buf.buffered_bytes(), 50);
        buf.fill(1, vec![0; 10]);
        buf.wait_take(1, Duration::from_secs(1));
        buf.wait_take(2, Duration::from_secs(1));
        assert_eq!(buf.buffered_bytes(), 0);
    }

    #[test]
    fn many_producers_one_consumer() {
        let n = 256u32;
        let buf = Arc::new(OrderBuffer::new(n as usize));
        for chunk in 0..8u32 {
            let b = Arc::clone(&buf);
            thread::spawn(move || {
                for i in (chunk..n).step_by(8) {
                    b.fill(i, i.to_le_bytes().to_vec());
                }
            });
        }
        for i in 0..n {
            match buf.wait_take(i, Duration::from_secs(5)) {
                SlotWait::Ready(d) => assert_eq!(d, i.to_le_bytes().to_vec()),
                other => panic!("slot {i}: {other:?}"),
            }
        }
        assert_eq!(buf.resolved_count(), n as usize);
    }
}
