//! Strict-order reassembly buffer.
//!
//! Senders deliver entries out of order from many nodes; the DT must emit
//! them in exact request order (§2.2). The buffer holds one slot per request
//! entry; producers fill arbitrary slots, the single consumer (the assembly
//! loop) blocks on the *next* index it needs — "decoupling heterogeneous
//! read and transfer latencies from output determinism" (§2.3.1 phase 3).
//!
//! Two producer paths exist:
//!
//! * whole-entry `fill` — single-frame deliveries and GFN recovery;
//! * incremental `append_chunk` — multi-chunk streaming (see
//!   `proto::frame`). The consumer can start draining the head-of-line slot
//!   via `wait_chunk` *before* its last chunk arrives, which is what makes
//!   the data path genuinely streaming for entries larger than one chunk.
//!
//! When constructed `with_budget`, every producer byte is reserved against
//! the node-wide [`super::admission::MemoryBudget`] before it becomes
//! resident, and released as the consumer drains it. Producers block when
//! the budget is exhausted — over the P2P path this propagates as TCP
//! backpressure to the sending target (the §2.4.3 memory constraint made
//! real, not just a metric). The head-of-line slot is exempt while it holds
//! no resident bytes, which guarantees the consumer can always make
//! progress (no reorder-buffer deadlock) while keeping peak residency ≤ the
//! configured budget (see `MemoryBudget` for the bound).

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::batch::error::EntryError;

use super::admission::{MemoryBudget, TenantHandle};

#[derive(Debug)]
enum Slot {
    Pending,
    /// Entry bytes flowing through: `data` holds resident (not yet
    /// consumed) bytes; `received`/`consumed` track cumulative counts so
    /// completeness survives partial drains.
    Filling { data: Vec<u8>, total: u64, received: u64, consumed: u64 },
    Failed(EntryError),
    /// Fully consumed by the assembler.
    Taken,
}

impl Slot {
    fn resident(&self) -> u64 {
        match self {
            Slot::Filling { data, .. } => data.len() as u64,
            _ => 0,
        }
    }
}

/// Outcome of waiting for a whole slot (whole-entry consumption).
#[derive(Debug, PartialEq)]
pub enum SlotWait {
    Ready(Vec<u8>),
    Failed(EntryError),
    TimedOut,
}

/// Outcome of waiting for the next bytes of a slot (streaming consumption).
#[derive(Debug, PartialEq)]
pub enum ChunkWait {
    /// Some bytes of the entry. `total` is the entry's declared full length
    /// (known from its first chunk); `done` marks the entry fully drained.
    Chunk { bytes: Vec<u8>, total: u64, done: bool },
    Failed(EntryError),
    TimedOut,
}

pub struct OrderBuffer {
    slots: Mutex<Vec<Slot>>,
    cv: Condvar,
    /// Bytes currently resident in this buffer (per-request accounting; the
    /// node-wide figure lives in the shared `MemoryBudget`).
    buffered: AtomicI64,
    /// The index the consumer is currently waiting on (head of line) —
    /// drives the budget's progress exemption.
    next_idx: AtomicU32,
    /// Set when the consumer abandons the request (abort or completion):
    /// late producers drop their bytes immediately instead of blocking on
    /// the budget until its patience runs out.
    closed: AtomicBool,
    budget: Option<Arc<MemoryBudget>>,
    /// Multi-tenant QoS: when set, producers pass the tenant's fair-share
    /// gate *before* the global budget, and every resident byte is charged
    /// to (and released from) the tenant's ledger alongside the budget.
    tenant: Option<TenantHandle>,
}

impl OrderBuffer {
    pub fn new(n: usize) -> OrderBuffer {
        OrderBuffer {
            slots: Mutex::new((0..n).map(|_| Slot::Pending).collect()),
            cv: Condvar::new(),
            buffered: AtomicI64::new(0),
            next_idx: AtomicU32::new(0),
            closed: AtomicBool::new(false),
            budget: None,
            tenant: None,
        }
    }

    /// Buffer whose producers are gated by the node-wide memory budget.
    pub fn with_budget(n: usize, budget: Arc<MemoryBudget>) -> OrderBuffer {
        let mut b = OrderBuffer::new(n);
        b.budget = Some(budget);
        b
    }

    /// Budget-gated buffer additionally charged to one tenant's fair-share
    /// ledger. Fair-share refusals are never patience-forced (an over-share
    /// tenant waiting out patience must not overrun into other tenants'
    /// room); the head-of-line progress exemption still applies, so the
    /// over-share tenant drains slowly rather than deadlocking.
    pub fn with_budget_tenant(
        n: usize,
        budget: Arc<MemoryBudget>,
        tenant: TenantHandle,
    ) -> OrderBuffer {
        let mut b = OrderBuffer::new(n);
        b.budget = Some(budget);
        b.tenant = Some(tenant);
        b
    }

    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn buffered_bytes(&self) -> i64 {
        self.buffered.load(Ordering::Relaxed)
    }

    /// Wake any waiting consumer (used when out-of-band completion state —
    /// SENDER_DONE fan-in, DT-local resolution — changes).
    pub fn poke(&self) {
        let _guard = self.slots.lock().unwrap();
        self.cv.notify_all();
    }

    /// The consumer is done with this buffer (request completed or
    /// aborted): late producers drop immediately — within one budget wait
    /// slice — instead of stalling their connection on a buffer nobody
    /// will ever drain.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.poke();
    }

    /// Reserve `bytes` against the budget (no-op without one). Blocks under
    /// memory pressure unless this is the head-of-line slot with nothing
    /// resident (progress exemption — see module docs). Returns `false` —
    /// with nothing reserved — when the slot is already consumed, so late
    /// producers of abandoned slots never stall their connection.
    fn reserve(&self, idx: u32, bytes: u64) -> bool {
        let budget = match &self.budget {
            Some(b) => b,
            None => return true,
        };
        if bytes == 0 {
            return true;
        }
        // Patience deadline on the budget's own clock, so a virtual-clock
        // budget (the scale simulator) pays patience in virtual time.
        let patience_ns = budget.patience().as_nanos() as u64;
        let start_ns = budget.now_ns();
        let mut deadline_ns = start_ns.saturating_add(patience_ns);
        let mut waited = false;
        let admitted = loop {
            if self.closed.load(Ordering::Relaxed) {
                break false;
            }
            // Tenant fair-share gate first (cheap, no condvar), then the
            // global budget; undo the tenant charge if the budget refuses.
            let ledger_ok = match &self.tenant {
                Some(t) => t.try_charge(bytes),
                None => true,
            };
            if ledger_ok {
                if budget.try_reserve(bytes) {
                    break true;
                }
                if let Some(t) = &self.tenant {
                    t.uncharge(bytes);
                }
            }
            let (exempt, dead) = {
                let slots = self.slots.lock().unwrap();
                match slots.get(idx as usize) {
                    None | Some(Slot::Taken) => (false, true),
                    Some(s) => (
                        idx == self.next_idx.load(Ordering::Relaxed) && s.resident() == 0,
                        false,
                    ),
                }
            };
            if dead {
                break false;
            }
            if exempt {
                budget.force_reserve(bytes, false);
                if let Some(t) = &self.tenant {
                    t.force_charge(bytes);
                }
                break true;
            }
            waited = true;
            if !budget.wait_room_until_ns(deadline_ns) {
                if ledger_ok {
                    // Liveness valve: waited past the budget's patience —
                    // force-admit (counted as an overrun) rather than
                    // wedging the node.
                    budget.force_reserve(bytes, true);
                    if let Some(t) = &self.tenant {
                        t.force_charge(bytes);
                    }
                    break true;
                }
                // Refused by the fair-share gate, not the budget: forcing
                // here would let one tenant overrun into everyone else's
                // room, collapsing isolation. Keep waiting on a fresh
                // patience window — head-of-line progress stays exempt
                // above, and close() breaks the loop for abandoned slots.
                deadline_ns = budget.now_ns().saturating_add(patience_ns);
            }
        };
        if waited {
            if let Some(t) = &self.tenant {
                t.note_throttle(budget.now_ns().saturating_sub(start_ns));
            }
        }
        admitted
    }

    /// Resident bytes leaving the buffer (consumed or discarded).
    fn release(&self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        self.buffered.fetch_sub(bytes as i64, Ordering::Relaxed);
        if let Some(budget) = &self.budget {
            budget.release(bytes);
        }
        if let Some(t) = &self.tenant {
            t.uncharge(bytes);
        }
    }

    /// Undo a reservation whose bytes never became resident.
    fn rollback(&self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        if let Some(budget) = &self.budget {
            budget.release(bytes);
        }
        if let Some(t) = &self.tenant {
            t.uncharge(bytes);
        }
    }

    fn note_resident(&self, bytes: u64) {
        self.buffered.fetch_add(bytes as i64, Ordering::Relaxed);
    }

    /// Producer: deliver a whole entry payload (single-frame path and GFN
    /// recovery). First write wins (recovery may race a late sender);
    /// duplicates are dropped.
    pub fn fill(&self, idx: u32, data: Vec<u8>) {
        if self.closed.load(Ordering::Relaxed) {
            return;
        }
        let len = data.len() as u64;
        if !self.reserve(idx, len) {
            return; // slot consumed or buffer closed: drop the late payload
        }
        let accepted = {
            let mut slots = self.slots.lock().unwrap();
            match slots.get_mut(idx as usize) {
                Some(s @ (Slot::Pending | Slot::Failed(_))) => {
                    *s = Slot::Filling { data, total: len, received: len, consumed: 0 };
                    self.note_resident(len);
                    self.cv.notify_all();
                    true
                }
                _ => false,
            }
        };
        if !accepted {
            self.rollback(len);
        }
    }

    /// Producer: append one chunk of entry `idx`. A `first` chunk carries
    /// the entry's declared `total`; a `first` chunk arriving at a partially
    /// received (but unconsumed) slot *resets* it — that is how a sender's
    /// stale-connection retry safely retransmits from the entry's start.
    /// Length violations fail the slot with a recoverable stream failure.
    pub fn append_chunk(&self, idx: u32, total: u64, bytes: Vec<u8>, first: bool, last: bool) {
        if self.closed.load(Ordering::Relaxed) {
            return;
        }
        let len = bytes.len() as u64;
        if !self.reserve(idx, len) {
            return; // slot consumed or buffer closed: drop the late chunk
        }
        // Resident bytes leaving the buffer / reserved bytes never admitted;
        // settled after the lock is dropped.
        let mut release_after = 0u64;
        let mut rollback_after = 0u64;
        {
            let mut slots = self.slots.lock().unwrap();
            if idx as usize >= slots.len() {
                rollback_after = len;
            } else {
                let old = std::mem::replace(&mut slots[idx as usize], Slot::Pending);
                let new = match old {
                    s @ (Slot::Pending | Slot::Failed(_)) => {
                        if first {
                            self.admit_first(bytes, total, last, &mut rollback_after)
                        } else {
                            // Middle/last chunk with no FIRST seen (frames
                            // lost): unusable — leave prior state for the
                            // recovery ladder.
                            rollback_after = len;
                            s
                        }
                    }
                    Slot::Filling { data, total: cur_total, received, consumed } => {
                        if first {
                            if consumed == 0 {
                                // Retransmission from the start: replace the
                                // stale partial bytes.
                                release_after = data.len() as u64;
                                self.admit_first(bytes, total, last, &mut rollback_after)
                            } else {
                                // Consumer already drained part of the old
                                // stream — cannot restart safely.
                                release_after = data.len() as u64;
                                rollback_after = len;
                                Slot::Failed(EntryError::StreamFailure(
                                    "duplicate chunk stream after partial consumption".into(),
                                ))
                            }
                        } else {
                            let new_received = received + len;
                            if new_received > cur_total || (last && new_received != cur_total) {
                                release_after = data.len() as u64;
                                rollback_after = len;
                                Slot::Failed(EntryError::StreamFailure(format!(
                                    "chunk stream length mismatch: {new_received}/{cur_total}"
                                )))
                            } else {
                                let mut data = data;
                                data.extend_from_slice(&bytes);
                                self.note_resident(len);
                                Slot::Filling {
                                    data,
                                    total: cur_total,
                                    received: new_received,
                                    consumed,
                                }
                            }
                        }
                    }
                    Slot::Taken => {
                        rollback_after = len;
                        Slot::Taken
                    }
                };
                slots[idx as usize] = new;
                self.cv.notify_all();
            }
        }
        self.release(release_after);
        self.rollback(rollback_after);
    }

    /// Build the slot state for an accepted FIRST chunk (also the reset
    /// path). Caller must be holding the slots lock.
    fn admit_first(&self, bytes: Vec<u8>, total: u64, last: bool, rollback: &mut u64) -> Slot {
        let len = bytes.len() as u64;
        if len > total || (last && len != total) {
            *rollback += len;
            Slot::Failed(EntryError::StreamFailure(format!(
                "chunk stream length mismatch: {len}/{total}"
            )))
        } else {
            self.note_resident(len);
            Slot::Filling { data: bytes, total, received: len, consumed: 0 }
        }
    }

    /// Producer: report a per-entry failure. Never overwrites a *fully
    /// received* entry; a pending slot fails outright, and an incomplete
    /// chunk stream fails too (its resident bytes are released) — that is
    /// how a sender's mid-entry SOFT_ERR (streaming read failure) surfaces
    /// promptly instead of waiting out the sender timeout. If the consumer
    /// already drained part of the stream, the failure routes it to the
    /// ranged GFN splice.
    pub fn fail(&self, idx: u32, err: EntryError) {
        let mut release_after = 0u64;
        {
            let mut slots = self.slots.lock().unwrap();
            if let Some(slot) = slots.get_mut(idx as usize) {
                let fail_it = match slot {
                    Slot::Pending => true,
                    Slot::Filling { data, total, received, .. } if *received < *total => {
                        release_after = data.len() as u64;
                        true
                    }
                    _ => false,
                };
                if fail_it {
                    *slot = Slot::Failed(err);
                    self.cv.notify_all();
                }
            }
        }
        self.release(release_after);
    }

    /// Consumer: wait until slot `idx` fully resolves (or `timeout`). Moves
    /// the whole payload out, releasing DT memory. Whole-entry counterpart
    /// of `wait_chunk`.
    pub fn wait_take(&self, idx: u32, timeout: Duration) -> SlotWait {
        self.next_idx.store(idx, Ordering::Relaxed);
        let deadline = Instant::now() + timeout;
        let mut slots = self.slots.lock().unwrap();
        loop {
            let old = std::mem::replace(&mut slots[idx as usize], Slot::Taken);
            match old {
                Slot::Filling { data, total, received, consumed } if received == total => {
                    assert_eq!(consumed, 0, "slot {idx}: mixed wait_take/wait_chunk use");
                    drop(slots);
                    self.release(data.len() as u64);
                    return SlotWait::Ready(data);
                }
                Slot::Failed(e) => return SlotWait::Failed(e),
                Slot::Taken => panic!("slot {idx} consumed twice"),
                other => {
                    // Pending or incomplete Filling: restore and wait.
                    slots[idx as usize] = other;
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return SlotWait::TimedOut;
            }
            let (guard, _t) = self.cv.wait_timeout(slots, deadline - now).unwrap();
            slots = guard;
        }
    }

    /// Consumer: wait for the next available bytes of slot `idx`. Returns as
    /// soon as *any* resident bytes exist (the entry need not be complete),
    /// enabling head-of-line streaming. The final `Chunk` carries
    /// `done = true` and transitions the slot to consumed.
    pub fn wait_chunk(&self, idx: u32, timeout: Duration) -> ChunkWait {
        self.next_idx.store(idx, Ordering::Relaxed);
        let deadline = Instant::now() + timeout;
        let mut slots = self.slots.lock().unwrap();
        loop {
            let old = std::mem::replace(&mut slots[idx as usize], Slot::Taken);
            match old {
                Slot::Filling { data, total, received, consumed } => {
                    if !data.is_empty() {
                        let taken = data.len() as u64;
                        let consumed = consumed + taken;
                        let done = received == total && consumed == total;
                        if !done {
                            slots[idx as usize] =
                                Slot::Filling { data: Vec::new(), total, received, consumed };
                        }
                        drop(slots);
                        self.release(taken);
                        return ChunkWait::Chunk { bytes: data, total, done };
                    }
                    if received == total && consumed == total {
                        // Zero-length entry (or already drained): done now.
                        return ChunkWait::Chunk { bytes: Vec::new(), total, done: true };
                    }
                    // Incomplete and nothing resident: restore and wait.
                    slots[idx as usize] = Slot::Filling { data, total, received, consumed };
                }
                Slot::Failed(e) => return ChunkWait::Failed(e),
                Slot::Taken => panic!("slot {idx} consumed twice"),
                Slot::Pending => {
                    slots[idx as usize] = Slot::Pending;
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return ChunkWait::TimedOut;
            }
            let (guard, _t) = self.cv.wait_timeout(slots, deadline - now).unwrap();
            slots = guard;
        }
    }

    /// Non-blocking probe (tests / diagnostics).
    pub fn is_resolved(&self, idx: u32) -> bool {
        !matches!(self.slots.lock().unwrap()[idx as usize], Slot::Pending)
    }

    /// How many slots are resolved (receiving, failed, or consumed).
    pub fn resolved_count(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .iter()
            .filter(|s| !matches!(s, Slot::Pending))
            .count()
    }
}

impl Drop for OrderBuffer {
    fn drop(&mut self) {
        // Release any still-resident bytes back to the shared budget and
        // the tenant ledger (§2.4.2: completion/termination releases all
        // per-request state).
        if self.budget.is_some() || self.tenant.is_some() {
            let resident: u64 = self.slots.lock().unwrap().iter().map(|s| s.resident()).sum();
            if resident > 0 {
                if let Some(budget) = &self.budget {
                    budget.release(resident);
                }
                if let Some(t) = &self.tenant {
                    t.uncharge(resident);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn in_order_consumption_of_out_of_order_fills() {
        let buf = Arc::new(OrderBuffer::new(4));
        let b2 = Arc::clone(&buf);
        thread::spawn(move || {
            b2.fill(3, vec![3]);
            b2.fill(1, vec![1]);
            b2.fill(0, vec![0]);
            b2.fill(2, vec![2]);
        });
        for i in 0..4u32 {
            match buf.wait_take(i, Duration::from_secs(2)) {
                SlotWait::Ready(d) => assert_eq!(d, vec![i as u8]),
                other => panic!("slot {i}: {other:?}"),
            }
        }
    }

    #[test]
    fn timeout_on_never_filled() {
        let buf = OrderBuffer::new(1);
        let t0 = Instant::now();
        assert_eq!(buf.wait_take(0, Duration::from_millis(50)), SlotWait::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(50));
    }

    #[test]
    fn failure_propagates() {
        let buf = OrderBuffer::new(2);
        buf.fill(0, vec![9]);
        buf.fail(1, EntryError::NotFound("b/x".into()));
        assert!(matches!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(_)));
        assert!(matches!(
            buf.wait_take(1, Duration::from_secs(1)),
            SlotWait::Failed(EntryError::NotFound(_))
        ));
    }

    #[test]
    fn recovery_can_overwrite_failure() {
        let buf = OrderBuffer::new(1);
        buf.fail(0, EntryError::StreamFailure("rst".into()));
        // GFN recovery delivers the payload after the failure was recorded
        // but before the consumer took it:
        buf.fill(0, vec![7; 3]);
        assert_eq!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(vec![7; 3]));
    }

    #[test]
    fn duplicate_fill_dropped() {
        let buf = OrderBuffer::new(1);
        buf.fill(0, vec![1]);
        buf.fill(0, vec![2]); // late duplicate (e.g. recovery raced sender)
        assert_eq!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(vec![1]));
        assert_eq!(buf.buffered_bytes(), 0, "accounting balanced");
    }

    #[test]
    fn fail_does_not_clobber_ready() {
        let buf = OrderBuffer::new(1);
        buf.fill(0, vec![5]);
        buf.fail(0, EntryError::SenderTimeout(0));
        assert_eq!(buf.wait_take(0, Duration::from_secs(1)), SlotWait::Ready(vec![5]));
    }

    #[test]
    fn memory_accounting() {
        let buf = OrderBuffer::new(3);
        buf.fill(0, vec![0; 100]);
        buf.fill(2, vec![0; 50]);
        assert_eq!(buf.buffered_bytes(), 150);
        buf.wait_take(0, Duration::from_secs(1));
        assert_eq!(buf.buffered_bytes(), 50);
        buf.fill(1, vec![0; 10]);
        buf.wait_take(1, Duration::from_secs(1));
        buf.wait_take(2, Duration::from_secs(1));
        assert_eq!(buf.buffered_bytes(), 0);
    }

    #[test]
    fn many_producers_one_consumer() {
        let n = 256u32;
        let buf = Arc::new(OrderBuffer::new(n as usize));
        for chunk in 0..8u32 {
            let b = Arc::clone(&buf);
            thread::spawn(move || {
                for i in (chunk..n).step_by(8) {
                    b.fill(i, i.to_le_bytes().to_vec());
                }
            });
        }
        for i in 0..n {
            match buf.wait_take(i, Duration::from_secs(5)) {
                SlotWait::Ready(d) => assert_eq!(d, i.to_le_bytes().to_vec()),
                other => panic!("slot {i}: {other:?}"),
            }
        }
        assert_eq!(buf.resolved_count(), n as usize);
    }

    // ---- chunked-path tests -------------------------------------------------

    fn drain(buf: &OrderBuffer, idx: u32) -> Result<Vec<u8>, ChunkWait> {
        let mut out = Vec::new();
        loop {
            match buf.wait_chunk(idx, Duration::from_secs(2)) {
                ChunkWait::Chunk { bytes, done, .. } => {
                    out.extend_from_slice(&bytes);
                    if done {
                        return Ok(out);
                    }
                }
                other => return Err(other),
            }
        }
    }

    #[test]
    fn chunked_append_and_streaming_drain() {
        let buf = OrderBuffer::new(1);
        buf.append_chunk(0, 10, vec![0, 1, 2, 3], true, false);
        buf.append_chunk(0, 0, vec![4, 5, 6], false, false);
        buf.append_chunk(0, 0, vec![7, 8, 9], false, true);
        assert_eq!(drain(&buf, 0).unwrap(), (0..10u8).collect::<Vec<_>>());
        assert_eq!(buf.buffered_bytes(), 0);
    }

    #[test]
    fn consumer_drains_head_before_last_chunk_arrives() {
        let buf = Arc::new(OrderBuffer::new(1));
        buf.append_chunk(0, 6, vec![1, 2, 3], true, false);
        // First wait_chunk returns the early bytes with the entry incomplete.
        match buf.wait_chunk(0, Duration::from_secs(1)) {
            ChunkWait::Chunk { bytes, total, done } => {
                assert_eq!(bytes, vec![1, 2, 3]);
                assert_eq!(total, 6);
                assert!(!done, "entry must not be complete yet");
            }
            other => panic!("{other:?}"),
        }
        let b2 = Arc::clone(&buf);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(30));
            b2.append_chunk(0, 0, vec![4, 5, 6], false, true);
        });
        match buf.wait_chunk(0, Duration::from_secs(2)) {
            ChunkWait::Chunk { bytes, done, .. } => {
                assert_eq!(bytes, vec![4, 5, 6]);
                assert!(done);
            }
            other => panic!("{other:?}"),
        }
        t.join().unwrap();
    }

    #[test]
    fn first_chunk_retransmit_resets_unconsumed_slot() {
        let buf = OrderBuffer::new(1);
        buf.append_chunk(0, 6, vec![9, 9], true, false); // attempt 1, conn died
        buf.append_chunk(0, 6, vec![1, 2, 3], true, false); // retry from start
        buf.append_chunk(0, 0, vec![4, 5, 6], false, true);
        assert_eq!(drain(&buf, 0).unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(buf.buffered_bytes(), 0, "stale attempt bytes released");
    }

    #[test]
    fn length_mismatch_fails_slot() {
        let buf = OrderBuffer::new(1);
        buf.append_chunk(0, 4, vec![1, 2], true, false);
        buf.append_chunk(0, 0, vec![3], false, true); // 3 != 4 declared
        assert!(matches!(
            buf.wait_chunk(0, Duration::from_secs(1)),
            ChunkWait::Failed(EntryError::StreamFailure(_))
        ));
        assert_eq!(buf.buffered_bytes(), 0);
    }

    #[test]
    fn fail_aborts_incomplete_chunk_stream_and_releases_bytes() {
        // Sender dies mid-entry and reports SOFT_ERR: the partially received
        // stream must fail now (not at the sender timeout) and return its
        // resident bytes.
        let buf = OrderBuffer::new(1);
        buf.append_chunk(0, 100, vec![1; 10], true, false);
        assert_eq!(buf.buffered_bytes(), 10);
        buf.fail(0, EntryError::StreamFailure("sender read failed".into()));
        assert!(matches!(
            buf.wait_chunk(0, Duration::from_secs(1)),
            ChunkWait::Failed(EntryError::StreamFailure(_))
        ));
        assert_eq!(buf.buffered_bytes(), 0, "resident bytes released on stream failure");
    }

    #[test]
    fn zero_length_entry_completes() {
        let buf = OrderBuffer::new(1);
        buf.fill(0, Vec::new());
        match buf.wait_chunk(0, Duration::from_secs(1)) {
            ChunkWait::Chunk { bytes, total, done } => {
                assert!(bytes.is_empty() && total == 0 && done);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wait_take_blocks_until_chunked_entry_completes() {
        let buf = Arc::new(OrderBuffer::new(1));
        let b2 = Arc::clone(&buf);
        let t = thread::spawn(move || {
            b2.append_chunk(0, 4, vec![1, 2], true, false);
            thread::sleep(Duration::from_millis(30));
            b2.append_chunk(0, 0, vec![3, 4], false, true);
        });
        assert_eq!(buf.wait_take(0, Duration::from_secs(2)), SlotWait::Ready(vec![1, 2, 3, 4]));
        t.join().unwrap();
    }

    #[test]
    fn append_chunk_sequences_match_whole_fill() {
        // The manual FIRST/middle/LAST split every producer performs must be
        // indistinguishable from a whole-entry fill to the consumer.
        for (len, chunk) in [(0usize, 4usize), (4, 4), (5, 4), (100, 7), (64, 64)] {
            let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
            let buf = OrderBuffer::new(1);
            if data.len() <= chunk {
                buf.fill(0, data.clone());
            } else {
                let total = data.len() as u64;
                let mut off = 0usize;
                while off < data.len() {
                    let end = (off + chunk).min(data.len());
                    buf.append_chunk(0, total, data[off..end].to_vec(), off == 0, end == data.len());
                    off = end;
                }
            }
            assert_eq!(
                buf.wait_take(0, Duration::from_secs(1)),
                SlotWait::Ready(data),
                "len={len} chunk={chunk}"
            );
        }
    }

    #[test]
    fn close_unblocks_and_drops_late_producers() {
        // Budget 32, chunk 8 → cap 24. Fill the cap with slot-0 chunks so a
        // further append blocks (head slot has resident bytes → no
        // exemption); close() must release the producer promptly.
        let budget = MemoryBudget::new(32, 8, None);
        let buf = Arc::new(OrderBuffer::with_budget(1, Arc::clone(&budget)));
        buf.append_chunk(0, 64, vec![0; 8], true, false);
        buf.append_chunk(0, 64, vec![0; 8], false, false);
        buf.append_chunk(0, 64, vec![0; 8], false, false);
        assert_eq!(budget.used(), 24);
        let b2 = Arc::clone(&buf);
        let t0 = Instant::now();
        let t = thread::spawn(move || b2.append_chunk(0, 64, vec![0; 8], false, false));
        thread::sleep(Duration::from_millis(20));
        buf.close();
        t.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(2), "producer unblocked by close");
        assert_eq!(budget.used(), 24, "late chunk dropped without leaking a reservation");
        assert_eq!(budget.overruns(), 0);
        // dropping the closed buffer returns the resident bytes
        drop(buf);
        assert_eq!(budget.used(), 0);
    }

    #[test]
    fn budget_blocks_producers_until_consumer_drains() {
        // Budget of 64 bytes, chunk 16: a 4 x 48-byte batch (192 bytes
        // total) must flow through with residency never exceeding the
        // budget and no forced admissions.
        let budget = MemoryBudget::new(64, 16, None);
        let buf = Arc::new(OrderBuffer::with_budget(4, Arc::clone(&budget)));
        let b2 = Arc::clone(&buf);
        let producer = thread::spawn(move || {
            for idx in 0..4u32 {
                let data: Vec<u8> = (0..48).map(|i| (idx as u8) ^ (i as u8)).collect();
                for (k, chunk) in data.chunks(16).enumerate() {
                    b2.append_chunk(idx, 48, chunk.to_vec(), k == 0, k == 2);
                }
            }
        });
        for idx in 0..4u32 {
            let got = drain(&buf, idx).unwrap();
            let want: Vec<u8> = (0..48).map(|i| (idx as u8) ^ (i as u8)).collect();
            assert_eq!(got, want, "slot {idx}");
        }
        producer.join().unwrap();
        assert!(budget.peak() <= 64, "peak {} > budget", budget.peak());
        assert_eq!(budget.used(), 0);
        assert_eq!(budget.overruns(), 0, "no forced admissions needed");
    }

    #[test]
    fn tenant_over_share_waits_without_overrun() {
        use super::super::admission::TenantLedger;
        use std::collections::BTreeMap;
        // Budget 64 / chunk 8 => usable cap 56; two active tenants split it
        // 28/28. The hog fills its share, then tries to go over on a
        // non-head slot: it must block past the budget's patience WITHOUT
        // being force-admitted (fair-share refusals are never overruns).
        let budget = MemoryBudget::with_patience(64, 8, Duration::from_millis(30), None);
        let ledger = TenantLedger::new(64, 8, BTreeMap::new(), None);
        let hog =
            Arc::new(OrderBuffer::with_budget_tenant(4, Arc::clone(&budget), ledger.handle("hog")));
        let _steady =
            OrderBuffer::with_budget_tenant(1, Arc::clone(&budget), ledger.handle("steady"));
        assert_eq!(ledger.share("hog"), 28);
        hog.fill(0, vec![0u8; 28]);
        assert_eq!(ledger.used("hog"), 28);
        let h2 = Arc::clone(&hog);
        let t = thread::spawn(move || h2.fill(2, vec![0u8; 8]));
        thread::sleep(Duration::from_millis(120)); // several patience windows
        assert_eq!(budget.overruns(), 0, "fair-share refusal must not patience-force");
        assert_eq!(ledger.used("hog"), 28, "over-share fill not admitted");
        hog.close();
        t.join().unwrap();
        assert_eq!(ledger.used("hog"), 28, "late producer dropped, nothing charged");
        drop(hog);
        assert_eq!(ledger.used("hog"), 0, "drop returns resident bytes to the ledger");
        assert_eq!(budget.used(), 0);
    }

    #[test]
    fn tenant_head_of_line_stays_exempt() {
        use super::super::admission::TenantLedger;
        use std::collections::BTreeMap;
        // An over-share tenant's head-of-line slot (nothing resident) keeps
        // the progress exemption: the consumer can always drain, so fair
        // share throttles throughput instead of deadlocking the request.
        let budget = MemoryBudget::with_patience(64, 8, Duration::from_secs(5), None);
        let ledger = TenantLedger::new(64, 8, BTreeMap::new(), None);
        let hog = OrderBuffer::with_budget_tenant(2, Arc::clone(&budget), ledger.handle("hog"));
        let _steady =
            OrderBuffer::with_budget_tenant(1, Arc::clone(&budget), ledger.handle("steady"));
        hog.fill(1, vec![0u8; 28]); // share (28) filled on a later slot
        assert_eq!(ledger.used("hog"), 28);
        let t0 = Instant::now();
        hog.fill(0, vec![0u8; 8]); // head slot: exempt from both gates
        assert!(t0.elapsed() < Duration::from_secs(1), "no patience stall on head slot");
        assert_eq!(ledger.used("hog"), 36, "exempt chunk still charged to the tenant");
        assert_eq!(budget.overruns(), 0, "exemption is not an overrun");
    }
}
