//! Per-request DT execution state and the ordered assembly loop (§2.3.1
//! phase 3): drains each request slot in order — streaming chunked entries
//! through as their bytes arrive — recovers soft errors via
//! get-from-neighbor (GFN), emits placeholders under continue-on-error, and
//! enforces the per-request error budgets of §2.4.2–2.4.3.
//!
//! Completion awareness: every sender emits SENDER_DONE after its last
//! frame and the DT's own local resolution reports completion too, so when
//! fan-in is complete and a slot is still unresolved the assembler starts
//! recovery *immediately* instead of burning the full `sender_wait`
//! timeout.

use std::collections::HashMap;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::batch::error::{BatchError, EntryError};
use crate::batch::request::{BatchEntry, BatchRequest};
use crate::cluster::placement;
use crate::cluster::smap::Smap;
use crate::config::GetBatchConfig;
use crate::metrics::GetBatchMetrics;
use crate::proto::frame::{Frame, FrameType};
use crate::proto::http::HttpClient;
use crate::proto::wire;
use crate::tar::TarWriter;
use crate::util::clock::{Clock, Stopwatch};

use super::admission::{MemoryBudget, TenantHandle};
use super::order::{ChunkWait, OrderBuffer};

/// How often the assembler re-checks out-of-band completion state while
/// waiting on a slot (SENDER_DONE arrival also pokes the buffer, so this is
/// a backstop, not the primary latency).
const WAIT_QUANTUM: Duration = Duration::from_millis(15);

/// Execution state of one GetBatch request on its Designated Target.
pub struct DtExec {
    pub req_id: u64,
    pub request: BatchRequest,
    pub num_senders: u32,
    pub buf: OrderBuffer,
    senders_done: AtomicU32,
    /// The DT's own local-resolution pass finished (it is a sender too).
    local_done: AtomicBool,
    /// When this execution was registered (staleness reaping).
    registered_at: Instant,
    /// A client arrived at the stream endpoint — the execution is being
    /// consumed and must not be reaped.
    claimed: AtomicBool,
}

impl DtExec {
    pub fn new(req_id: u64, request: BatchRequest, num_senders: u32) -> DtExec {
        let n = request.entries.len();
        DtExec {
            req_id,
            request,
            num_senders,
            buf: OrderBuffer::new(n),
            senders_done: AtomicU32::new(0),
            local_done: AtomicBool::new(false),
            registered_at: Instant::now(),
            claimed: AtomicBool::new(false),
        }
    }

    /// Execution whose reorder buffer reserves against the node's memory
    /// budget (production path).
    pub fn with_budget(
        req_id: u64,
        request: BatchRequest,
        num_senders: u32,
        budget: Arc<MemoryBudget>,
    ) -> DtExec {
        let n = request.entries.len();
        DtExec {
            req_id,
            request,
            num_senders,
            buf: OrderBuffer::with_budget(n, budget),
            senders_done: AtomicU32::new(0),
            local_done: AtomicBool::new(false),
            registered_at: Instant::now(),
            claimed: AtomicBool::new(false),
        }
    }

    /// Execution whose reorder buffer reserves against the node's memory
    /// budget *and* the owning tenant's fair-share ledger (multi-tenant
    /// production path; the handle keeps the tenant active for the
    /// execution's lifetime).
    pub fn with_qos(
        req_id: u64,
        request: BatchRequest,
        num_senders: u32,
        budget: Arc<MemoryBudget>,
        tenant: TenantHandle,
    ) -> DtExec {
        let n = request.entries.len();
        DtExec {
            req_id,
            request,
            num_senders,
            buf: OrderBuffer::with_budget_tenant(n, budget, tenant),
            senders_done: AtomicU32::new(0),
            local_done: AtomicBool::new(false),
            registered_at: Instant::now(),
            claimed: AtomicBool::new(false),
        }
    }

    pub fn senders_done(&self) -> u32 {
        self.senders_done.load(Ordering::Relaxed)
    }

    /// Mark this execution as being consumed (phase-3 client arrived).
    pub fn claim(&self) {
        self.claimed.store(true, Ordering::Relaxed);
    }

    pub fn is_claimed(&self) -> bool {
        self.claimed.load(Ordering::Relaxed)
    }

    /// Mark the DT-local resolution pass complete (called by the node once
    /// its own entries are resolved).
    pub fn note_local_done(&self) {
        self.local_done.store(true, Ordering::Relaxed);
        self.buf.poke();
    }

    /// All remote senders reported DONE and the DT-local pass finished — no
    /// further frames can resolve a pending slot.
    pub fn fanin_complete(&self) -> bool {
        self.local_done.load(Ordering::Relaxed)
            && self.senders_done.load(Ordering::Relaxed) >= self.num_senders
    }
}

/// Registry of in-flight executions on one target; the P2P frame handler
/// dispatches into it. Executions whose client never arrives at the
/// phase-3 stream endpoint are reaped after `abandon_ttl` so they cannot
/// pin the node-wide memory budget (reaping runs opportunistically from
/// the HTTP registration path and, throttled, from frame dispatch — the
/// exact moments an abandoned execution would otherwise accumulate bytes).
pub struct DtRegistry {
    execs: Mutex<HashMap<u64, Arc<DtExec>>>,
    abandon_ttl: Duration,
    metrics: Option<Arc<GetBatchMetrics>>,
    created: Instant,
    /// Millis (since `created`) of the last dispatch-path reap sweep.
    last_reap_ms: AtomicU64,
}

impl DtRegistry {
    pub fn new() -> Arc<DtRegistry> {
        // Standalone/test default: generous TTL, no gauge to settle.
        DtRegistry::with_config(Duration::from_secs(600), None)
    }

    pub fn with_config(
        abandon_ttl: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> Arc<DtRegistry> {
        Arc::new(DtRegistry {
            execs: Mutex::new(HashMap::new()),
            abandon_ttl,
            metrics,
            created: Instant::now(),
            last_reap_ms: AtomicU64::new(0),
        })
    }

    pub fn register(&self, exec: DtExec) -> Arc<DtExec> {
        let exec = Arc::new(exec);
        self.execs.lock().unwrap().insert(exec.req_id, Arc::clone(&exec));
        exec
    }

    pub fn get(&self, req_id: u64) -> Option<Arc<DtExec>> {
        self.execs.lock().unwrap().get(&req_id).cloned()
    }

    /// Atomically look up *and* claim an execution for consumption. The
    /// claim flag is set under the same lock `reap_stale` scans with, so a
    /// stream request and the reaper can never both win the execution.
    pub fn claim(&self, req_id: u64) -> Option<Arc<DtExec>> {
        let execs = self.execs.lock().unwrap();
        let exec = execs.get(&req_id).cloned();
        if let Some(e) = &exec {
            e.claim();
        }
        exec
    }

    /// Release all per-request state (§2.4.2: "upon successful completion or
    /// termination, the DT ... releases all per-request execution state").
    pub fn remove(&self, req_id: u64) {
        self.execs.lock().unwrap().remove(&req_id);
    }

    pub fn inflight(&self) -> usize {
        self.execs.lock().unwrap().len()
    }

    /// Drop executions that were registered more than `abandon_ttl` ago and
    /// never claimed by a phase-3 stream request (client crashed or
    /// abandoned the redirect). Closing their buffers releases any
    /// memory-budget residency and unblocks producers promptly — otherwise
    /// an abandoned request would pin the node-wide budget forever. The
    /// `dt_inflight` gauge is settled here (under the configured metrics).
    pub fn reap_stale(&self) -> usize {
        let mut reaped = Vec::new();
        {
            let mut execs = self.execs.lock().unwrap();
            execs.retain(|_, e| {
                let stale = !e.is_claimed() && e.registered_at.elapsed() > self.abandon_ttl;
                if stale {
                    reaped.push(Arc::clone(e));
                }
                !stale
            });
        }
        for e in &reaped {
            e.buf.close();
        }
        if let Some(m) = &self.metrics {
            if !reaped.is_empty() {
                m.dt_inflight.sub(reaped.len() as i64);
            }
        }
        reaped.len()
    }

    /// Throttled reap from the frame-dispatch hot path (at most one sweep
    /// per second) — frames arriving for an abandoned execution are exactly
    /// the traffic that would otherwise accumulate bytes against it.
    fn maybe_reap(&self) {
        let now_ms = self.created.elapsed().as_millis() as u64;
        let last = self.last_reap_ms.load(Ordering::Relaxed);
        if now_ms.saturating_sub(last) < 1000 {
            return;
        }
        if self
            .last_reap_ms
            .compare_exchange(last, now_ms, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            self.reap_stale();
        }
    }

    /// Frame dispatch from the P2P server. Frames for unknown requests are
    /// dropped (late frames after completion/abort are benign). DATA frames
    /// route through the chunk path; producers block here under memory
    /// pressure, which is exactly the backpressure point — the P2P reader
    /// thread stalls and TCP flow control pushes back on the sender.
    pub fn dispatch(&self, f: Frame) {
        self.maybe_reap();
        let exec = match self.get(f.req_id) {
            Some(e) => e,
            None => return,
        };
        match f.ftype {
            FrameType::Data => {
                let (first, last) = (f.is_first(), f.is_last());
                if first && last {
                    // Whole entry in one frame.
                    exec.buf.fill(f.index, f.payload);
                } else if !first {
                    // Middle/last chunk: payload is pure chunk bytes.
                    exec.buf.append_chunk(f.index, 0, f.payload, false, last);
                } else {
                    // FIRST of a multi-chunk entry: chunk_parts validates
                    // the 8-byte total prefix, which is then stripped
                    // in place (drain: memmove, no second allocation on
                    // the hot receive path).
                    let total = f.chunk_parts().map(|(t, _)| t);
                    match total {
                        Some(total) => {
                            let mut payload = f.payload;
                            payload.drain(..8);
                            exec.buf.append_chunk(f.index, total, payload, true, false);
                        }
                        None => exec.buf.fail(
                            f.index,
                            EntryError::StreamFailure("malformed first chunk".into()),
                        ),
                    }
                }
            }
            FrameType::SoftErr => {
                let reason = String::from_utf8_lossy(&f.payload).into_owned();
                let err = if reason.starts_with("missing object") {
                    EntryError::NotFound(reason)
                } else if reason.starts_with("missing member") {
                    EntryError::MemberNotFound(reason)
                } else {
                    EntryError::StreamFailure(reason)
                };
                exec.buf.fail(f.index, err);
            }
            FrameType::SenderDone => {
                exec.senders_done.fetch_add(1, Ordering::Relaxed);
                // Wake the assembler: with fan-in complete it can start
                // recovery for still-pending slots without waiting out the
                // sender timeout.
                exec.buf.poke();
            }
        }
    }
}

/// Everything the assembly loop needs to reach the rest of the cluster for
/// GFN recovery.
pub struct AssembleCtx {
    pub smap: Arc<Smap>,
    pub http: HttpClient,
    /// This DT's own target index (skipped during GFN).
    pub self_target: usize,
    pub cfg: GetBatchConfig,
    pub metrics: Arc<GetBatchMetrics>,
    pub clock: Arc<dyn Clock>,
    /// The node's data-plane memory budget: ranged GFN recovery reserves
    /// each fetched chunk against it while the chunk is resident, so a
    /// recovered multi-GiB entry respects the same cap as the live path.
    /// `None` in standalone/unit-test assembly.
    pub budget: Option<Arc<MemoryBudget>>,
}

/// Result summary of one assembly.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StreamOutcome {
    pub delivered: u32,
    pub placeholders: u32,
    pub recovered: u32,
    pub bytes: u64,
}

/// One neighbor's ranged object fetch: pulls the object in `chunk`-sized
/// slices via HTTP Range requests, learning the total length from the first
/// response's `content-range`. Nothing larger than one chunk is ever
/// resident on the recovery path.
struct RangedFetch<'a> {
    http: &'a HttpClient,
    addr: &'a str,
    pq: &'a str,
    chunk: u64,
    /// Total object length, known after the first response.
    total: Option<u64>,
    offset: u64,
    /// Stored CRC-32 sidecar advertised by the neighbor (captured off the
    /// first response carrying the header).
    crc: Option<u32>,
}

impl RangedFetch<'_> {
    /// Fetch the next chunk. `Ok(None)` once the whole object was pulled
    /// (`total` is set by then); `Err` describes a neighbor failure.
    fn next_chunk(&mut self) -> Result<Option<Vec<u8>>, String> {
        if let Some(t) = self.total {
            if self.offset >= t {
                return Ok(None);
            }
        }
        let resp = self
            .http
            .get_range(self.addr, self.pq, self.offset, self.chunk)
            .map_err(|e| format!("range fetch: {e}"))?;
        if resp.status != 206 {
            return Err(format!("range fetch: http {}", resp.status));
        }
        if self.crc.is_none() {
            self.crc = resp
                .header(wire::HDR_OBJ_CRC)
                .and_then(|h| u32::from_str_radix(h.trim(), 16).ok());
        }
        let total = resp
            .header("content-range")
            .and_then(crate::proto::http::content_range_total)
            .ok_or_else(|| "range fetch: missing content-range".to_string())?;
        match self.total {
            Some(t) if t != total => {
                return Err(format!("object resized mid-recovery: {t} -> {total}"))
            }
            None => self.total = Some(total),
            _ => {}
        }
        if self.offset >= total {
            return Ok(None);
        }
        let bytes = resp.into_bytes().map_err(|e| format!("range body: {e}"))?;
        if bytes.is_empty() {
            return Err(format!("range fetch: empty chunk at {}/{total}", self.offset));
        }
        if self.offset + bytes.len() as u64 > total {
            return Err("range fetch: over-long chunk".to_string());
        }
        self.offset += bytes.len() as u64;
        Ok(Some(bytes))
    }
}

/// Outcome of a streamed GFN recovery.
enum GfnOutcome {
    /// Entry completed into the TAR (header, payload and padding are out).
    Recovered { total: u64 },
    /// Nothing was emitted beyond what the caller had already committed —
    /// the recovery ladder may fall through to a placeholder.
    Clean,
    /// Bytes were committed to the TAR but no neighbor could complete the
    /// entry: the archive position is poisoned — hard abort.
    Poisoned,
}

/// Streamed get-from-neighbor recovery (§2.4.2), fetching the entry in
/// ranged chunks that reserve against the DT memory budget — recovery of a
/// large entry respects the same cap as the live path.
///
/// With `committed = Some((total, written, prefix_crc))` the TAR header is
/// already out along with `written` payload bytes: only a byte-identical
/// splice can finish the entry. When the candidate neighbor stores a
/// PUT-time CRC-32 sidecar, the ranged fetch starts directly at the splice
/// offset and the combined CRC (emitted prefix resumed via `prefix_crc`,
/// extended by the spliced suffix) is verified against the stored hash at
/// EOF; without a sidecar the copy is re-fetched from byte 0 — the prefix
/// chunks are CRC-verified against `prefix_crc` and discarded, the
/// remainder streams into the TAR. With `committed = None` the header is
/// emitted as soon as the first neighbor chunk reveals the total; if that
/// neighbor dies mid-stream, the next one continues through the same
/// splice path.
///
/// Probing is bounded by a *local* per-entry counter capped at
/// `cfg.gfn_attempts` — never by global metric residue, so concurrent
/// recoveries can't starve or inflate each other's neighbor budgets.
fn gfn_recover<W: Write>(
    ctx: &AssembleCtx,
    entry: &BatchEntry,
    tw: &mut TarWriter<W>,
    committed: Option<(u64, u64, u32)>,
) -> Result<GfnOutcome, BatchError> {
    let key = entry.location_key();
    let name = entry.output_name();
    let max_probes = ctx.cfg.gfn_attempts.max(1);
    let mut probes = 0u32;
    let mut pq = format!("{}?local=true", wire::object_path(&entry.bucket, &entry.obj));
    if let Some(m) = &entry.archpath {
        pq.push_str(&format!("&archpath={m}"));
    }

    // Splice state shared across neighbor attempts: once the header is out,
    // `total` is fixed and `written`/`run_crc` describe the emitted prefix
    // every further candidate must match byte-for-byte.
    let mut header_total: Option<u64> = committed.map(|(t, _, _)| t);
    let mut written: u64 = committed.map(|(_, w, _)| w).unwrap_or(0);
    let mut run_crc = match committed {
        Some((_, _, crc)) => crate::util::crc32::Hasher::resume(crc),
        None => crate::util::crc32::Hasher::new(),
    };

    for &t in placement::ranked(&ctx.smap, &key).iter() {
        if t == ctx.self_target {
            continue;
        }
        if probes >= max_probes {
            break;
        }
        probes += 1;
        ctx.metrics.recovery_attempts.inc();
        let addr = &ctx.smap.targets[t].http_addr;
        match gfn_try_neighbor(ctx, addr, &pq, &name, tw, &mut header_total, &mut written, &mut run_crc)? {
            Ok(()) => return Ok(GfnOutcome::Recovered { total: header_total.unwrap_or(0) }),
            Err(_reason) => ctx.metrics.recovery_failures.inc(),
        }
    }
    Ok(if header_total.is_none() { GfnOutcome::Clean } else { GfnOutcome::Poisoned })
}

/// 1-byte ranged probe of a neighbor's object: learns its total length
/// and, when the neighbor stores a PUT-time CRC-32 sidecar
/// ([`wire::HDR_OBJ_CRC`]), its whole-object hash — without pulling data.
fn probe_neighbor_meta(
    http: &HttpClient,
    addr: &str,
    pq: &str,
) -> Result<(u64, Option<u32>), String> {
    let resp = http.get_range(addr, pq, 0, 1).map_err(|e| format!("probe: {e}"))?;
    if resp.status != 206 {
        return Err(format!("probe: http {}", resp.status));
    }
    let total = resp
        .header("content-range")
        .and_then(crate::proto::http::content_range_total)
        .ok_or_else(|| "probe: missing content-range".to_string())?;
    let crc = resp
        .header(wire::HDR_OBJ_CRC)
        .and_then(|h| u32::from_str_radix(h.trim(), 16).ok());
    let _ = resp.into_bytes(); // drain ≤ 1 byte; recycles the connection
    Ok((total, crc))
}

/// Attempt to complete the entry from one neighbor. Outer `Err` is a local
/// TAR/output failure (aborts the request); inner `Err` is a neighbor
/// failure (try the next one). Mutates the shared splice state as bytes are
/// committed.
#[allow(clippy::too_many_arguments)]
fn gfn_try_neighbor<W: Write>(
    ctx: &AssembleCtx,
    addr: &str,
    pq: &str,
    name: &str,
    tw: &mut TarWriter<W>,
    header_total: &mut Option<u64>,
    written: &mut u64,
    run_crc: &mut crate::util::crc32::Hasher,
) -> Result<Result<(), String>, BatchError> {
    let chunk = ctx.cfg.chunk_bytes.max(1) as u64;
    let target_prefix = *written;
    let mut expect_crc: Option<u32> = None;
    let mut fetch =
        RangedFetch { http: &ctx.http, addr, pq, chunk, total: None, offset: 0, crc: None };
    if target_prefix > 0 {
        // Splice fast path: when the probe reveals a stored whole-object
        // hash, skip the prefix re-download entirely — start the ranged
        // fetch at the splice offset and verify the *combined* CRC (the
        // already-emitted prefix extended by the spliced suffix) against
        // the stored hash at EOF. Without a sidecar (e.g. shard members),
        // fall back to re-fetching and CRC-checking the prefix.
        match probe_neighbor_meta(&ctx.http, addr, pq) {
            Err(e) => return Ok(Err(e)),
            Ok((total, Some(stored))) => {
                if let Some(t) = *header_total {
                    if t != total {
                        return Ok(Err(format!(
                            "size mismatch: neighbor has {total}, committed {t}"
                        )));
                    }
                }
                fetch.total = Some(total);
                fetch.offset = target_prefix;
                expect_crc = Some(stored);
            }
            Ok((_, None)) => {}
        }
    }
    // Prefix verification state (re-download path only): the first
    // `target_prefix` neighbor bytes must reproduce the CRC of what this DT
    // already emitted. On the fast path the fetch starts past the prefix,
    // which counts as verified — the stored-hash check at EOF covers it.
    let mut check = crate::util::crc32::Hasher::new();
    let mut verified: u64 = fetch.offset;
    loop {
        // Reserve the chunk's worst case against the node budget while it is
        // resident (fetched, checked, written through), then release.
        if let Some(b) = &ctx.budget {
            b.reserve_for_recovery(chunk);
        }
        let step = fetch.next_chunk();
        let outcome = (|| -> Result<Result<bool, String>, BatchError> {
            let bytes = match step {
                Ok(Some(bytes)) => bytes,
                Ok(None) => return Ok(Ok(true)), // neighbor EOF
                Err(e) => return Ok(Err(e)),
            };
            let total = fetch.total.expect("total known after a successful chunk");
            if let Some(t) = *header_total {
                if t != total {
                    return Ok(Err(format!("size mismatch: neighbor has {total}, committed {t}")));
                }
            }
            // Split prefix-verification bytes from fresh payload.
            let mut payload: &[u8] = &bytes;
            if verified < target_prefix {
                let take = ((target_prefix - verified) as usize).min(payload.len());
                check.update(&payload[..take]);
                verified += take as u64;
                payload = &payload[take..];
                if verified == target_prefix
                    && check.clone().finalize() != run_crc.clone().finalize()
                {
                    return Ok(Err("prefix mismatch (object changed under recovery)".into()));
                }
            }
            if !payload.is_empty() {
                if header_total.is_none() {
                    tw.begin_entry(name, total).map_err(io_batch)?;
                    *header_total = Some(total);
                }
                tw.write_chunk(payload).map_err(io_batch)?;
                run_crc.update(payload);
                *written += payload.len() as u64;
            }
            Ok(Ok(false))
        })();
        if let Some(b) = &ctx.budget {
            b.release(chunk);
        }
        match outcome? {
            Ok(true) => break,  // EOF — settle below
            Ok(false) => {}     // chunk processed, keep pulling
            Err(e) => return Ok(Err(e)),
        }
    }
    // Neighbor EOF: the object must have covered the verified prefix and the
    // full declared length.
    let total = match fetch.total {
        Some(t) => t,
        None => return Ok(Err("neighbor served no data".into())),
    };
    if verified < target_prefix || *written < total {
        return Ok(Err(format!("short object: {}/{total}", *written)));
    }
    // Stored-hash verification: whichever path ran, when the neighbor
    // advertises a PUT-time sidecar the fully emitted entry must hash to
    // it — a concurrent overwrite (or a bad splice) fails closed here.
    if let Some(stored) = expect_crc.or(fetch.crc) {
        if run_crc.clone().finalize() != stored {
            return Ok(Err("entry crc mismatch vs stored sidecar hash".into()));
        }
    }
    if header_total.is_none() {
        // Zero-length entry (or empty-after-prefix): header not yet out.
        tw.begin_entry(name, total).map_err(io_batch)?;
        *header_total = Some(total);
    }
    tw.end_entry().map_err(io_batch)?;
    Ok(Ok(()))
}

/// How draining one slot ended.
enum Drained {
    /// Entry fully streamed into the TAR (`bytes` of payload).
    Done { bytes: u64 },
    /// Failure before any byte of the entry was emitted.
    Failed(EntryError),
    /// Timed out — or fan-in completed with the slot unresolved — before
    /// any byte was emitted.
    TimedOut,
    /// Failure/timeout *after* `written` of the entry's `total` bytes were
    /// already emitted: the TAR header is committed, so only a
    /// byte-identical splice (GFN re-fetch of the same object, resuming at
    /// `written`) can still complete the entry. `written_crc` is the
    /// CRC-32 of the already-emitted prefix — the splice must match it so
    /// a same-size concurrent overwrite can't be stitched in silently.
    Poisoned { err: EntryError, total: u64, written: u64, written_crc: u32 },
}

/// Stream one slot's bytes into the TAR as they arrive.
fn drain_slot<W: Write>(
    exec: &DtExec,
    ctx: &AssembleCtx,
    tw: &mut TarWriter<W>,
    idx: u32,
    entry: &BatchEntry,
) -> Result<Drained, BatchError> {
    let sender_wait = ctx.cfg.sender_wait;
    // Progress-based deadline: each arriving chunk proves the sender is
    // alive and resets the clock.
    let mut deadline = Instant::now() + sender_wait;
    let mut started = false;
    let mut entry_total = 0u64;
    let mut written = 0u64;
    let mut written_crc = crate::util::crc32::Hasher::new();
    loop {
        let now = Instant::now();
        let remaining = deadline.saturating_duration_since(now);
        let quantum = remaining.min(WAIT_QUANTUM);
        match exec.buf.wait_chunk(idx, quantum) {
            ChunkWait::Chunk { bytes, total, done } => {
                if !started {
                    tw.begin_entry(&entry.output_name(), total).map_err(io_batch)?;
                    started = true;
                    entry_total = total;
                }
                tw.write_chunk(&bytes).map_err(io_batch)?;
                written += bytes.len() as u64;
                written_crc.update(&bytes);
                if done {
                    tw.end_entry().map_err(io_batch)?;
                    return Ok(Drained::Done { bytes: written });
                }
                deadline = Instant::now() + sender_wait;
            }
            ChunkWait::Failed(e) => {
                return Ok(if started {
                    Drained::Poisoned {
                        err: e,
                        total: entry_total,
                        written,
                        written_crc: written_crc.finalize(),
                    }
                } else {
                    Drained::Failed(e)
                });
            }
            ChunkWait::TimedOut => {
                if !started && exec.fanin_complete() && !exec.buf.is_resolved(idx) {
                    // Nobody can fill this slot any more: recover now
                    // instead of waiting out the full sender timeout.
                    ctx.metrics.early_recoveries.inc();
                    return Ok(Drained::TimedOut);
                }
                if Instant::now() >= deadline {
                    return Ok(if started {
                        Drained::Poisoned {
                            err: EntryError::SenderTimeout(idx),
                            total: entry_total,
                            written,
                            written_crc: written_crc.finalize(),
                        }
                    } else {
                        Drained::TimedOut
                    });
                }
            }
        }
    }
}

fn io_batch(e: crate::tar::TarError) -> BatchError {
    BatchError::Io(std::io::Error::new(std::io::ErrorKind::Other, e.to_string()))
}

/// The ordered assembly loop: drain slots 0..n in request order into a TAR
/// stream, starting each entry as soon as its first bytes arrive (§2.3.1
/// streaming). Returns the outcome, or the hard error that aborted the
/// request.
///
/// Works identically for streaming and buffered delivery — the caller
/// decides what `out` is (the chunked HTTP body vs. an in-memory buffer).
pub fn assemble(
    exec: &DtExec,
    ctx: &AssembleCtx,
    out: &mut dyn Write,
) -> Result<StreamOutcome, BatchError> {
    let mut tw = TarWriter::new(out);
    let mut outcome = StreamOutcome::default();
    let mut soft_errs: u32 = 0;
    let mut gfn_left: u32 = ctx.cfg.gfn_attempts;
    let n = exec.request.entries.len() as u32;

    for idx in 0..n {
        let entry = &exec.request.entries[idx as usize];
        let sw = Stopwatch::start(&*ctx.clock);
        let drained = drain_slot(exec, ctx, &mut tw, idx, entry)?;
        ctx.metrics.rxwait_ns.add(sw.elapsed().as_nanos() as u64);

        // Reduce the streaming outcome to the recovery-ladder shape.
        let failure: Option<EntryError> = match drained {
            Drained::Done { bytes } => {
                deliver_metrics(ctx, entry, bytes);
                outcome.bytes += bytes;
                outcome.delivered += 1;
                continue;
            }
            Drained::Poisoned { err, total, written, written_crc } => {
                // The TAR header (with `total`) is already committed and
                // `written` payload bytes are out. The only valid repair is
                // a byte-identical splice: re-fetch the object via ranged
                // GFN and resume at `written` — this keeps a sender crash
                // mid-entry recoverable, like it was for whole-entry
                // frames. The fetched copy must match both the declared
                // size and the CRC of the already-emitted prefix, or a
                // concurrent same-size overwrite would be stitched in
                // silently.
                if err.recoverable() && gfn_left > 0 {
                    gfn_left -= 1;
                    if let GfnOutcome::Recovered { .. } =
                        gfn_recover(ctx, entry, &mut tw, Some((total, written, written_crc)))?
                    {
                        outcome.recovered += 1;
                        deliver_metrics(ctx, entry, total);
                        outcome.bytes += total;
                        outcome.delivered += 1;
                        continue;
                    }
                }
                ctx.metrics.hard_failures.inc();
                return Err(BatchError::EntryFailed { index: idx, source: err });
            }
            Drained::Failed(e) => Some(e),
            Drained::TimedOut => None,
        };

        // Recovery ladder (§2.4.2): recoverable failure or timeout → GFN,
        // streamed in ranged chunks under the DT budget.
        let recoverable = failure.as_ref().map(|e| e.recoverable()).unwrap_or(true);
        if recoverable && gfn_left > 0 {
            gfn_left -= 1;
            match gfn_recover(ctx, entry, &mut tw, None)? {
                GfnOutcome::Recovered { total } => {
                    outcome.recovered += 1;
                    deliver_metrics(ctx, entry, total);
                    outcome.bytes += total;
                    outcome.delivered += 1;
                    continue;
                }
                GfnOutcome::Clean => {}
                GfnOutcome::Poisoned => {
                    // A neighbor died mid-stream after the header went out
                    // and no other neighbor could splice the remainder.
                    ctx.metrics.hard_failures.inc();
                    return Err(BatchError::EntryFailed {
                        index: idx,
                        source: failure.unwrap_or(EntryError::SenderTimeout(idx)),
                    });
                }
            }
        }

        // Unrecovered: placeholder under continue-on-error, abort otherwise.
        if exec.request.opts.continue_on_err {
            soft_errs += 1;
            ctx.metrics.soft_errors.inc();
            if soft_errs > ctx.cfg.max_soft_errs {
                ctx.metrics.hard_failures.inc();
                return Err(BatchError::SoftErrorBudget {
                    count: soft_errs,
                    limit: ctx.cfg.max_soft_errs,
                });
            }
            tw.append_missing(&entry.output_name()).map_err(io_batch)?;
            outcome.placeholders += 1;
        } else {
            ctx.metrics.hard_failures.inc();
            return Err(BatchError::EntryFailed {
                index: idx,
                source: failure.unwrap_or(EntryError::SenderTimeout(idx)),
            });
        }
    }
    tw.finish().map_err(io_batch)?;
    Ok(outcome)
}

fn deliver_metrics(ctx: &AssembleCtx, entry: &BatchEntry, bytes: u64) {
    ctx.metrics.work_items.inc();
    if entry.archpath.is_some() {
        ctx.metrics.members_extracted.inc();
        ctx.metrics.member_bytes.add(bytes);
    } else {
        ctx.metrics.objs_delivered.inc();
        ctx.metrics.obj_bytes.add(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchRequest;
    use crate::cluster::smap::NodeInfo;
    use crate::util::clock::RealClock;
    use std::time::Duration;

    fn ctx(sender_wait_ms: u64, coer_budget: u32) -> AssembleCtx {
        ctx_n(sender_wait_ms, coer_budget, 2, 1)
    }

    fn ctx_n(sender_wait_ms: u64, coer_budget: u32, targets: usize, gfn: u32) -> AssembleCtx {
        let smap = Arc::new(Smap::new(
            1,
            vec![],
            (0..targets)
                .map(|i| NodeInfo {
                    id: format!("t{i}"),
                    http_addr: "127.0.0.1:1".into(), // unreachable: GFN fails fast
                    p2p_addr: String::new(),
                })
                .collect(),
        ));
        AssembleCtx {
            smap,
            http: HttpClient::new(true),
            self_target: 0,
            cfg: GetBatchConfig {
                sender_wait: Duration::from_millis(sender_wait_ms),
                max_soft_errs: coer_budget,
                gfn_attempts: gfn,
                ..Default::default()
            },
            metrics: GetBatchMetrics::new(),
            clock: RealClock::new(),
            budget: None,
        }
    }

    /// Neighbor stub speaking the shared internal Range contract — what
    /// every real target's object endpoint speaks after this refactor.
    fn range_server(payload: Vec<u8>) -> crate::proto::http::HttpServer {
        crate::proto::http::HttpServer::serve(
            Arc::new(move |req: crate::proto::http::Request| {
                crate::proto::http::serve_ranged_bytes(&req, &payload)
            }),
            2,
            "gfn-neighbor",
        )
        .unwrap()
    }

    /// Range stub that also advertises the payload's CRC-32 sidecar (like a
    /// real target after a PUT) and records every served `(start, len)` —
    /// observability for the splice fast path.
    #[allow(clippy::type_complexity)]
    fn crc_range_server(
        payload: Vec<u8>,
    ) -> (crate::proto::http::HttpServer, Arc<Mutex<Vec<(u64, u64)>>>) {
        use crate::proto::http::{resolve_range, serve_ranged_bytes, RangeSpec};
        let log: Arc<Mutex<Vec<(u64, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        let crc = crate::util::crc32::hash(&payload);
        let log2 = Arc::clone(&log);
        let srv = crate::proto::http::HttpServer::serve(
            Arc::new(move |req: crate::proto::http::Request| {
                match resolve_range(req.header("range"), payload.len() as u64) {
                    RangeSpec::Slice { start, end } => {
                        log2.lock().unwrap().push((start, end - start))
                    }
                    _ => log2.lock().unwrap().push((0, payload.len() as u64)),
                }
                serve_ranged_bytes(&req, &payload)
                    .with_header(wire::HDR_OBJ_CRC, &format!("{crc:08x}"))
            }),
            2,
            "gfn-crc-neighbor",
        )
        .unwrap();
        (srv, log)
    }

    fn splice_ctx(neighbor_addr: &str, chunk: usize) -> AssembleCtx {
        let smap = Arc::new(Smap::new(
            1,
            vec![],
            vec![
                NodeInfo {
                    id: "t0".into(),
                    http_addr: "127.0.0.1:1".into(),
                    p2p_addr: String::new(),
                },
                NodeInfo {
                    id: "t1".into(),
                    http_addr: neighbor_addr.to_string(),
                    p2p_addr: String::new(),
                },
            ],
        ));
        AssembleCtx {
            smap,
            http: HttpClient::new(true),
            self_target: 0,
            cfg: GetBatchConfig {
                sender_wait: Duration::from_millis(5000),
                gfn_attempts: 2,
                chunk_bytes: chunk,
                ..Default::default()
            },
            metrics: GetBatchMetrics::new(),
            clock: RealClock::new(),
            budget: None,
        }
    }

    #[test]
    fn splice_with_stored_hash_skips_prefix_redownload() {
        // A sender dies after 400 KiB of a 500 KiB entry were emitted; the
        // neighbor advertises a stored CRC-32 sidecar. The splice must
        // start its ranged fetch at the splice offset — not byte 0 — and
        // verify the combined CRC against the stored hash.
        let payload: Vec<u8> = (0..500 * 1024u32).map(|i| (i % 197) as u8).collect();
        let (srv, log) = crc_range_server(payload.clone());
        let chunk = 16 << 10;
        let c = splice_ctx(&srv.addr.to_string(), chunk);
        let exec = Arc::new(DtExec::new(1, request(1, false), 0));
        let total = payload.len() as u64;
        let prefix = 400 * 1024usize;
        exec.buf.append_chunk(0, total, payload[..prefix].to_vec(), true, false);
        let e2 = Arc::clone(&exec);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            // Duplicate FIRST after partial consumption → mid-entry failure.
            e2.buf.append_chunk(0, total, vec![9; 10], true, false);
        });
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        t.join().unwrap();
        assert_eq!((o.delivered, o.recovered), (1, 1));
        let entries = crate::tar::read_archive(&out).unwrap();
        assert_eq!(entries[0].data, payload, "spliced bytes identical");
        let log = log.lock().unwrap();
        assert!(
            log.iter().any(|&(s, _)| s == prefix as u64),
            "fetch resumed at the splice offset: {log:?}"
        );
        // Everything beyond the 1-byte probe must be suffix data — the
        // 400 KiB prefix was NOT re-downloaded.
        let data_bytes: u64 = log.iter().map(|&(_, l)| l).filter(|&l| l > 1).sum();
        assert!(
            data_bytes <= (total - prefix as u64) + chunk as u64,
            "prefix re-downloaded: {data_bytes} payload bytes served ({log:?})"
        );
    }

    #[test]
    fn splice_hash_mismatch_fails_closed() {
        // The DT emitted a prefix that does NOT match the neighbor's stored
        // object (concurrent overwrite). The stored-hash check at EOF must
        // reject the splice, and with no other neighbor the committed entry
        // position hard-aborts the request.
        let payload: Vec<u8> = (0..300 * 1024u32).map(|i| (i % 211) as u8).collect();
        let (srv, _log) = crc_range_server(payload.clone());
        let c = splice_ctx(&srv.addr.to_string(), 16 << 10);
        let exec = Arc::new(DtExec::new(1, request(1, false), 0));
        let total = payload.len() as u64;
        let mut bad_prefix = payload[..100 * 1024].to_vec();
        bad_prefix[0] ^= 0x1;
        exec.buf.append_chunk(0, total, bad_prefix, true, false);
        let e2 = Arc::clone(&exec);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            e2.buf.append_chunk(0, total, vec![9; 10], true, false);
        });
        let mut out = Vec::new();
        let err = assemble(&exec, &c, &mut out).unwrap_err();
        t.join().unwrap();
        assert!(matches!(err, BatchError::EntryFailed { index: 0, .. }));
        assert_eq!(c.metrics.hard_failures.get(), 1);
    }

    fn request(n: usize, coer: bool) -> BatchRequest {
        BatchRequest::new((0..n).map(|i| BatchEntry::obj("b", &format!("o{i}"))).collect())
            .continue_on_err(coer)
    }

    #[test]
    fn assembles_in_strict_order() {
        let exec = DtExec::new(1, request(3, false), 0);
        exec.buf.fill(2, vec![2; 10]);
        exec.buf.fill(0, vec![0; 10]);
        exec.buf.fill(1, vec![1; 10]);
        let mut out = Vec::new();
        let o = assemble(&exec, &ctx(1000, 0), &mut out).unwrap();
        assert_eq!(o.delivered, 3);
        let entries = crate::tar::read_archive(&out).unwrap();
        assert_eq!(
            entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>(),
            vec!["o0", "o1", "o2"]
        );
        assert_eq!(entries[1].data, vec![1; 10]);
    }

    #[test]
    fn assembles_chunked_entries_streamed_across_arrival() {
        // Entry 0 arrives in chunks while the assembler is already running;
        // output must be byte-identical and strictly ordered.
        let exec = Arc::new(DtExec::new(1, request(2, false), 0));
        let payload: Vec<u8> = (0..5000u32).map(|i| (i % 241) as u8).collect();
        exec.buf.fill(1, vec![7; 32]);
        let e2 = Arc::clone(&exec);
        let p2 = payload.clone();
        let t = std::thread::spawn(move || {
            let chunks: Vec<&[u8]> = p2.chunks(1024).collect();
            for (k, c) in chunks.iter().enumerate() {
                std::thread::sleep(Duration::from_millis(5));
                e2.buf.append_chunk(
                    0,
                    p2.len() as u64,
                    c.to_vec(),
                    k == 0,
                    k == chunks.len() - 1,
                );
            }
        });
        let mut out = Vec::new();
        let o = assemble(&exec, &ctx(1000, 0), &mut out).unwrap();
        t.join().unwrap();
        assert_eq!(o.delivered, 2);
        assert_eq!(o.bytes, payload.len() as u64 + 32);
        let entries = crate::tar::read_archive(&out).unwrap();
        assert_eq!(entries[0].data, payload);
        assert_eq!(entries[1].data, vec![7; 32]);
    }

    #[test]
    fn hard_error_aborts_without_coer() {
        let exec = DtExec::new(1, request(2, false), 0);
        exec.buf.fill(0, vec![0]);
        exec.buf.fail(1, EntryError::NotFound("b/o1".into()));
        let mut out = Vec::new();
        let err = assemble(&exec, &ctx(1000, 0), &mut out).unwrap_err();
        assert!(matches!(err, BatchError::EntryFailed { index: 1, .. }));
    }

    #[test]
    fn coer_emits_placeholder_preserving_positions() {
        let exec = DtExec::new(1, request(3, true), 0);
        exec.buf.fill(0, vec![0; 4]);
        exec.buf.fail(1, EntryError::NotFound("b/o1".into()));
        exec.buf.fill(2, vec![2; 4]);
        let c = ctx(1000, 5);
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(o.delivered, 2);
        assert_eq!(o.placeholders, 1);
        let items =
            crate::batch::reader::BatchReader::new(std::io::Cursor::new(out)).collect_all().unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(items[1].name(), "o1");
        assert!(items[1].is_missing());
        assert_eq!(c.metrics.soft_errors.get(), 1);
    }

    #[test]
    fn soft_error_budget_enforced() {
        let exec = DtExec::new(1, request(4, true), 0);
        for i in 0..4 {
            exec.buf.fail(i, EntryError::NotFound(format!("b/o{i}")));
        }
        let c = ctx(1000, 2); // budget: 2
        let mut out = Vec::new();
        let err = assemble(&exec, &c, &mut out).unwrap_err();
        assert!(matches!(err, BatchError::SoftErrorBudget { count: 3, limit: 2 }));
        assert_eq!(c.metrics.hard_failures.get(), 1);
    }

    #[test]
    fn timeout_becomes_hard_error_without_coer() {
        let exec = DtExec::new(1, request(1, false), 0);
        let c = ctx(30, 0);
        let mut out = Vec::new();
        let err = assemble(&exec, &c, &mut out).unwrap_err();
        assert!(matches!(
            err,
            BatchError::EntryFailed { index: 0, source: EntryError::SenderTimeout(_) }
        ));
        assert!(c.metrics.rxwait_ns.get() >= 25_000_000, "rxwait accounted");
    }

    #[test]
    fn timeout_with_coer_yields_placeholder() {
        let exec = DtExec::new(1, request(1, true), 0);
        let c = ctx(30, 5);
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(o.placeholders, 1);
    }

    #[test]
    fn fanin_complete_skips_sender_wait() {
        // One remote sender, already DONE; DT-local resolution finished;
        // slot 0 unresolved. Despite a long sender_wait the assembler must
        // recover/fail fast (well under the 10s timeout).
        let exec = DtExec::new(1, request(1, true), 1);
        exec.note_local_done();
        let reg = DtRegistry::new();
        let exec = reg.register(exec);
        reg.dispatch(Frame::sender_done(1, 0));
        assert!(exec.fanin_complete());
        let c = ctx(10_000, 5);
        let t0 = Instant::now();
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(o.placeholders, 1);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "early recovery must not burn sender_wait: {:?}",
            t0.elapsed()
        );
        assert!(c.metrics.early_recoveries.get() >= 1);
    }

    #[test]
    fn mid_entry_failure_is_hard_abort() {
        // Part of entry 0 is already in the TAR stream when its slot fails:
        // the archive position is poisoned — must abort even under coer.
        let exec = DtExec::new(1, request(1, true), 0);
        exec.buf.append_chunk(0, 100, vec![1; 10], true, false);
        let exec = Arc::new(exec);
        let e2 = Arc::clone(&exec);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            // duplicate FIRST after the consumer drained some bytes →
            // StreamFailure on a partially consumed slot
            e2.buf.append_chunk(0, 100, vec![2; 10], true, false);
        });
        let c = ctx(5000, 5);
        let mut out = Vec::new();
        let err = assemble(&exec, &c, &mut out).unwrap_err();
        t.join().unwrap();
        assert!(matches!(
            err,
            BatchError::EntryFailed { index: 0, source: EntryError::StreamFailure(_) }
        ));
        assert_eq!(c.metrics.hard_failures.get(), 1);
    }

    #[test]
    fn mid_entry_failure_recovers_by_ranged_gfn_splice() {
        // A sender dies after delivering 100 KiB of a 500 KiB entry; a
        // neighbor holds a byte-identical copy. The committed TAR header
        // must be completed by splicing the remaining bytes from *ranged*
        // GFN fetches, and recovery residency must respect a DT budget far
        // smaller than the entry.
        let payload: Vec<u8> = (0..500 * 1024u32).map(|i| (i % 193) as u8).collect();
        let srv = range_server(payload.clone());
        let smap = Arc::new(Smap::new(
            1,
            vec![],
            vec![
                NodeInfo { id: "t0".into(), http_addr: "127.0.0.1:1".into(), p2p_addr: String::new() },
                NodeInfo { id: "t1".into(), http_addr: srv.addr.to_string(), p2p_addr: String::new() },
            ],
        ));
        let chunk = 16 << 10;
        let budget = MemoryBudget::new(64 << 10, chunk as u64, None);
        let c = AssembleCtx {
            smap,
            http: HttpClient::new(true),
            self_target: 0,
            cfg: GetBatchConfig {
                sender_wait: Duration::from_millis(5000),
                gfn_attempts: 2,
                chunk_bytes: chunk,
                ..Default::default()
            },
            metrics: GetBatchMetrics::new(),
            clock: RealClock::new(),
            budget: Some(Arc::clone(&budget)),
        };
        let exec = Arc::new(DtExec::new(1, request(1, false), 0));
        let total = payload.len() as u64;
        exec.buf.append_chunk(0, total, payload[..100 * 1024].to_vec(), true, false);
        let e2 = Arc::clone(&exec);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            // Duplicate FIRST after partial consumption → mid-entry failure
            // (the kill-sender-mid-entry signal at the buffer level).
            e2.buf.append_chunk(0, total, vec![9; 10], true, false);
        });
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        t.join().unwrap();
        assert_eq!(o.delivered, 1);
        assert_eq!(o.recovered, 1, "entry completed via ranged GFN splice");
        let entries = crate::tar::read_archive(&out).unwrap();
        assert_eq!(entries[0].data, payload, "spliced bytes identical");
        assert_eq!(c.metrics.hard_failures.get(), 0);
        // Recovery never held more than one chunk against the budget.
        assert!(
            budget.peak() <= budget.budget(),
            "recovery residency {} exceeded budget {}",
            budget.peak(),
            budget.budget()
        );
        assert_eq!(budget.used(), 0, "all recovery reservations released");
        assert_eq!(budget.overruns(), 0, "no forced admissions needed");
    }

    #[test]
    fn fresh_recovery_streams_in_ranged_chunks_under_budget() {
        // Slot fails recoverably before any byte is emitted: recovery must
        // stream the whole entry from a neighbor via ranged fetches —
        // learning the total from the first content-range — while reserving
        // at most one chunk against the DT budget.
        let payload: Vec<u8> = (0..300 * 1024u32).map(|i| (i % 241) as u8).collect();
        let srv = range_server(payload.clone());
        let smap = Arc::new(Smap::new(
            1,
            vec![],
            vec![
                NodeInfo { id: "t0".into(), http_addr: "127.0.0.1:1".into(), p2p_addr: String::new() },
                NodeInfo { id: "t1".into(), http_addr: srv.addr.to_string(), p2p_addr: String::new() },
            ],
        ));
        let chunk = 16 << 10;
        let budget = MemoryBudget::new(64 << 10, chunk as u64, None);
        let c = AssembleCtx {
            smap,
            http: HttpClient::new(true),
            self_target: 0,
            cfg: GetBatchConfig {
                sender_wait: Duration::from_millis(1000),
                gfn_attempts: 2,
                chunk_bytes: chunk,
                ..Default::default()
            },
            metrics: GetBatchMetrics::new(),
            clock: RealClock::new(),
            budget: Some(Arc::clone(&budget)),
        };
        let exec = DtExec::new(1, request(1, false), 0);
        exec.buf.fail(0, EntryError::StreamFailure("conn reset".into()));
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(o.delivered, 1);
        assert_eq!(o.recovered, 1);
        assert_eq!(o.bytes, payload.len() as u64);
        let entries = crate::tar::read_archive(&out).unwrap();
        assert_eq!(entries[0].data, payload, "recovered bytes identical");
        assert!(budget.peak() <= budget.budget(), "peak {} > budget", budget.peak());
        assert_eq!(budget.used(), 0);
    }

    #[test]
    fn recovery_of_zero_length_entry_via_ranged_probe() {
        // The ranged probe of an empty object still learns total = 0 from
        // content-range and emits a valid zero-length TAR entry.
        let srv = range_server(Vec::new());
        let smap = Arc::new(Smap::new(
            1,
            vec![],
            vec![
                NodeInfo { id: "t0".into(), http_addr: "127.0.0.1:1".into(), p2p_addr: String::new() },
                NodeInfo { id: "t1".into(), http_addr: srv.addr.to_string(), p2p_addr: String::new() },
            ],
        ));
        let c = AssembleCtx {
            smap,
            http: HttpClient::new(true),
            self_target: 0,
            cfg: GetBatchConfig { gfn_attempts: 2, ..Default::default() },
            metrics: GetBatchMetrics::new(),
            clock: RealClock::new(),
            budget: None,
        };
        let exec = DtExec::new(1, request(1, false), 0);
        exec.buf.fail(0, EntryError::ReadFailure("eio".into()));
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        assert_eq!((o.delivered, o.recovered, o.bytes), (1, 1, 0));
        let entries = crate::tar::read_archive(&out).unwrap();
        assert_eq!(entries[0].data, Vec::<u8>::new());
    }

    #[test]
    fn gfn_probes_bounded_by_local_counter_not_global_residue() {
        // 6 targets (5 neighbors), gfn_attempts = 2: exactly 2 neighbors
        // probed per entry, regardless of pre-existing global counter
        // residue (the old code keyed the bound off
        // `recovery_attempts % gfn_attempts`, so residue skewed it).
        for residue in [0u64, 1, 2, 3, 7] {
            let c = ctx_n(10, 0, 6, 2);
            c.metrics.recovery_attempts.add(residue);
            let entry = BatchEntry::obj("b", "o");
            let mut tw = TarWriter::new(Vec::new());
            assert!(
                matches!(gfn_recover(&c, &entry, &mut tw, None).unwrap(), GfnOutcome::Clean),
                "unreachable neighbors"
            );
            let probed = c.metrics.recovery_attempts.get() - residue;
            assert_eq!(probed, 2, "residue {residue}: probed {probed}");
            assert_eq!(c.metrics.recovery_failures.get(), 2);
        }
    }

    #[test]
    fn reap_stale_drops_unclaimed_but_spares_claimed() {
        let metrics = GetBatchMetrics::new();
        metrics.dt_inflight.set(2);
        let reg = DtRegistry::with_config(Duration::from_millis(1), Some(Arc::clone(&metrics)));
        let abandoned = reg.register(DtExec::new(1, request(1, false), 0));
        reg.register(DtExec::new(2, request(1, false), 0));
        assert!(reg.claim(2).is_some(), "stream request claims atomically");
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(reg.reap_stale(), 1);
        assert!(reg.get(1).is_none(), "abandoned execution reaped");
        assert!(reg.get(2).is_some(), "claimed execution retained");
        assert_eq!(metrics.dt_inflight.get(), 1, "gauge settled by the reaper");
        // A reaped execution's buffer is closed: late producers drop fast.
        abandoned.buf.fill(0, vec![1, 2, 3]);
        assert!(!abandoned.buf.is_resolved(0), "late fill dropped after close");

        // Fresh registrations survive a sane TTL.
        let reg_long = DtRegistry::with_config(Duration::from_secs(60), None);
        reg_long.register(DtExec::new(3, request(1, false), 0));
        assert_eq!(reg_long.reap_stale(), 0);
        assert_eq!(reg_long.inflight(), 1);
    }

    #[test]
    fn registry_dispatch_routes_frames() {
        let reg = DtRegistry::new();
        let exec = reg.register(DtExec::new(42, request(2, true), 3));
        reg.dispatch(Frame::data(42, 1, vec![9]));
        reg.dispatch(Frame::soft_err(42, 0, "missing object b/o0"));
        reg.dispatch(Frame::sender_done(42, 1));
        reg.dispatch(Frame::data(777, 0, vec![1])); // unknown req: dropped
        assert!(exec.buf.is_resolved(0) && exec.buf.is_resolved(1));
        assert_eq!(exec.senders_done(), 1);
        reg.remove(42);
        assert_eq!(reg.inflight(), 0);
    }

    #[test]
    fn registry_dispatch_reassembles_chunk_frames() {
        let reg = DtRegistry::new();
        let exec = reg.register(DtExec::new(43, request(1, false), 1));
        let payload: Vec<u8> = (0..3000u32).map(|i| (i % 199) as u8).collect();
        for f in crate::proto::frame::chunk_frames(43, 0, payload.clone(), 1024) {
            reg.dispatch(f);
        }
        match exec.buf.wait_take(0, Duration::from_secs(1)) {
            crate::dt::order::SlotWait::Ready(d) => assert_eq!(d, payload),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn work_item_metrics_distinguish_members() {
        let req = BatchRequest::new(vec![
            BatchEntry::obj("b", "whole"),
            BatchEntry::member("b", "s.tar", "m"),
        ]);
        let exec = DtExec::new(1, req, 0);
        exec.buf.fill(0, vec![1; 100]);
        exec.buf.fill(1, vec![2; 40]);
        let c = ctx(1000, 0);
        let mut out = Vec::new();
        assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(c.metrics.objs_delivered.get(), 1);
        assert_eq!(c.metrics.obj_bytes.get(), 100);
        assert_eq!(c.metrics.members_extracted.get(), 1);
        assert_eq!(c.metrics.member_bytes.get(), 40);
        assert_eq!(c.metrics.work_items.get(), 2);
    }
}
