//! Per-request DT execution state and the ordered assembly loop (§2.3.1
//! phase 3): waits on each request slot in order, recovers soft errors via
//! get-from-neighbor (GFN), emits placeholders under continue-on-error, and
//! enforces the per-request error budgets of §2.4.2–2.4.3.

use std::collections::HashMap;
use std::io::Write;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

use crate::batch::error::{BatchError, EntryError};
use crate::batch::request::{BatchEntry, BatchRequest};
use crate::cluster::placement;
use crate::cluster::smap::Smap;
use crate::config::GetBatchConfig;
use crate::metrics::GetBatchMetrics;
use crate::proto::frame::{Frame, FrameType};
use crate::proto::http::HttpClient;
use crate::proto::wire;
use crate::tar::TarWriter;
use crate::util::clock::{Clock, Stopwatch};

use super::order::{OrderBuffer, SlotWait};

/// Execution state of one GetBatch request on its Designated Target.
pub struct DtExec {
    pub req_id: u64,
    pub request: BatchRequest,
    pub num_senders: u32,
    pub buf: OrderBuffer,
    senders_done: AtomicU32,
}

impl DtExec {
    pub fn new(req_id: u64, request: BatchRequest, num_senders: u32) -> DtExec {
        let n = request.entries.len();
        DtExec { req_id, request, num_senders, buf: OrderBuffer::new(n), senders_done: AtomicU32::new(0) }
    }

    pub fn senders_done(&self) -> u32 {
        self.senders_done.load(Ordering::Relaxed)
    }
}

/// Registry of in-flight executions on one target; the P2P frame handler
/// dispatches into it.
#[derive(Default)]
pub struct DtRegistry {
    execs: Mutex<HashMap<u64, Arc<DtExec>>>,
}

impl DtRegistry {
    pub fn new() -> Arc<DtRegistry> {
        Arc::new(DtRegistry::default())
    }

    pub fn register(&self, exec: DtExec) -> Arc<DtExec> {
        let exec = Arc::new(exec);
        self.execs.lock().unwrap().insert(exec.req_id, Arc::clone(&exec));
        exec
    }

    pub fn get(&self, req_id: u64) -> Option<Arc<DtExec>> {
        self.execs.lock().unwrap().get(&req_id).cloned()
    }

    /// Release all per-request state (§2.4.2: "upon successful completion or
    /// termination, the DT ... releases all per-request execution state").
    pub fn remove(&self, req_id: u64) {
        self.execs.lock().unwrap().remove(&req_id);
    }

    pub fn inflight(&self) -> usize {
        self.execs.lock().unwrap().len()
    }

    /// Frame dispatch from the P2P server. Frames for unknown requests are
    /// dropped (late frames after completion/abort are benign).
    pub fn dispatch(&self, f: Frame) {
        let exec = match self.get(f.req_id) {
            Some(e) => e,
            None => return,
        };
        match f.ftype {
            FrameType::Data => exec.buf.fill(f.index, f.payload),
            FrameType::SoftErr => {
                let reason = String::from_utf8_lossy(&f.payload).into_owned();
                let err = if reason.starts_with("missing object") {
                    EntryError::NotFound(reason)
                } else if reason.starts_with("missing member") {
                    EntryError::MemberNotFound(reason)
                } else {
                    EntryError::StreamFailure(reason)
                };
                exec.buf.fail(f.index, err);
            }
            FrameType::SenderDone => {
                exec.senders_done.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Everything the assembly loop needs to reach the rest of the cluster for
/// GFN recovery.
pub struct AssembleCtx {
    pub smap: Arc<Smap>,
    pub http: HttpClient,
    /// This DT's own target index (skipped during GFN).
    pub self_target: usize,
    pub cfg: GetBatchConfig,
    pub metrics: Arc<GetBatchMetrics>,
    pub clock: Arc<dyn Clock>,
}

/// Result summary of one assembly.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StreamOutcome {
    pub delivered: u32,
    pub placeholders: u32,
    pub recovered: u32,
    pub bytes: u64,
}

/// Try to fetch the entry directly from the next-best owners ("neighbors").
/// Used when a sender timed out or reported a recoverable failure.
fn gfn_recover(ctx: &AssembleCtx, entry: &BatchEntry) -> Option<Vec<u8>> {
    let key = entry.location_key();
    for &t in placement::ranked(&ctx.smap, &key).iter() {
        if t == ctx.self_target {
            continue;
        }
        ctx.metrics.recovery_attempts.inc();
        let target = &ctx.smap.targets[t];
        let mut pq = format!("{}?local=true", wire::object_path(&entry.bucket, &entry.obj));
        if let Some(m) = &entry.archpath {
            pq.push_str(&format!("&archpath={m}"));
        }
        match ctx.http.get(&target.http_addr, &pq) {
            Ok(resp) if resp.status == 200 => match resp.into_bytes() {
                Ok(data) => return Some(data),
                Err(_) => ctx.metrics.recovery_failures.inc(),
            },
            _ => ctx.metrics.recovery_failures.inc(),
        }
        // Only probe a bounded number of neighbors per entry.
        if ctx.metrics.recovery_attempts.get() % (ctx.cfg.gfn_attempts.max(1) as u64) == 0 {
            break;
        }
    }
    None
}

/// The ordered assembly loop: drain slots 0..n in request order into a TAR
/// stream. Returns the outcome, or the hard error that aborted the request.
///
/// Works identically for streaming and buffered delivery — the caller
/// decides what `out` is (the chunked HTTP body vs. an in-memory buffer).
pub fn assemble(
    exec: &DtExec,
    ctx: &AssembleCtx,
    out: &mut dyn Write,
) -> Result<StreamOutcome, BatchError> {
    let mut tw = TarWriter::new(out);
    let mut outcome = StreamOutcome::default();
    let mut soft_errs: u32 = 0;
    let mut gfn_left: u32 = ctx.cfg.gfn_attempts;
    let n = exec.request.entries.len() as u32;

    for idx in 0..n {
        let entry = &exec.request.entries[idx as usize];
        // Pressure throttle: scale with resident buffered bytes (soft gate).
        ctx.metrics.dt_buffered_bytes.set(exec.buf.buffered_bytes());
        let sw = Stopwatch::start(&*ctx.clock);
        let mut slot = exec.buf.wait_take(idx, ctx.cfg.sender_wait);
        ctx.metrics.rxwait_ns.add(sw.elapsed().as_nanos() as u64);

        // Recovery ladder (§2.4.2): recoverable failure or timeout → GFN.
        if matches!(slot, SlotWait::TimedOut)
            || matches!(&slot, SlotWait::Failed(e) if e.recoverable())
        {
            if gfn_left > 0 {
                gfn_left -= 1;
                if let Some(data) = gfn_recover(ctx, entry) {
                    outcome.recovered += 1;
                    slot = SlotWait::Ready(data);
                }
            }
        }

        match slot {
            SlotWait::Ready(data) => {
                outcome.bytes += data.len() as u64;
                ctx.metrics.work_items.inc();
                if entry.archpath.is_some() {
                    ctx.metrics.members_extracted.inc();
                    ctx.metrics.member_bytes.add(data.len() as u64);
                } else {
                    ctx.metrics.objs_delivered.inc();
                    ctx.metrics.obj_bytes.add(data.len() as u64);
                }
                tw.append(&entry.output_name(), &data)
                    .map_err(|e| BatchError::Io(std::io::Error::new(std::io::ErrorKind::Other, e.to_string())))?;
                outcome.delivered += 1;
            }
            SlotWait::Failed(_) | SlotWait::TimedOut if exec.request.opts.continue_on_err => {
                soft_errs += 1;
                ctx.metrics.soft_errors.inc();
                if soft_errs > ctx.cfg.max_soft_errs {
                    ctx.metrics.hard_failures.inc();
                    return Err(BatchError::SoftErrorBudget {
                        count: soft_errs,
                        limit: ctx.cfg.max_soft_errs,
                    });
                }
                tw.append_missing(&entry.output_name())
                    .map_err(|e| BatchError::Io(std::io::Error::new(std::io::ErrorKind::Other, e.to_string())))?;
                outcome.placeholders += 1;
            }
            SlotWait::Failed(err) => {
                ctx.metrics.hard_failures.inc();
                return Err(BatchError::EntryFailed { index: idx, source: err });
            }
            SlotWait::TimedOut => {
                ctx.metrics.hard_failures.inc();
                return Err(BatchError::EntryFailed {
                    index: idx,
                    source: EntryError::SenderTimeout(idx),
                });
            }
        }
    }
    tw.finish()
        .map_err(|e| BatchError::Io(std::io::Error::new(std::io::ErrorKind::Other, e.to_string())))?;
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchRequest;
    use crate::cluster::smap::NodeInfo;
    use crate::util::clock::RealClock;
    use std::time::Duration;

    fn ctx(sender_wait_ms: u64, coer_budget: u32) -> AssembleCtx {
        let smap = Arc::new(Smap::new(
            1,
            vec![],
            (0..2)
                .map(|i| NodeInfo {
                    id: format!("t{i}"),
                    http_addr: "127.0.0.1:1".into(), // unreachable: GFN fails fast
                    p2p_addr: String::new(),
                })
                .collect(),
        ));
        AssembleCtx {
            smap,
            http: HttpClient::new(true),
            self_target: 0,
            cfg: GetBatchConfig {
                sender_wait: Duration::from_millis(sender_wait_ms),
                max_soft_errs: coer_budget,
                gfn_attempts: 1,
                ..Default::default()
            },
            metrics: GetBatchMetrics::new(),
            clock: RealClock::new(),
        }
    }

    fn request(n: usize, coer: bool) -> BatchRequest {
        BatchRequest::new((0..n).map(|i| BatchEntry::obj("b", &format!("o{i}"))).collect())
            .continue_on_err(coer)
    }

    #[test]
    fn assembles_in_strict_order() {
        let exec = DtExec::new(1, request(3, false), 0);
        exec.buf.fill(2, vec![2; 10]);
        exec.buf.fill(0, vec![0; 10]);
        exec.buf.fill(1, vec![1; 10]);
        let mut out = Vec::new();
        let o = assemble(&exec, &ctx(1000, 0), &mut out).unwrap();
        assert_eq!(o.delivered, 3);
        let entries = crate::tar::read_archive(&out).unwrap();
        assert_eq!(
            entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>(),
            vec!["o0", "o1", "o2"]
        );
        assert_eq!(entries[1].data, vec![1; 10]);
    }

    #[test]
    fn hard_error_aborts_without_coer() {
        let exec = DtExec::new(1, request(2, false), 0);
        exec.buf.fill(0, vec![0]);
        exec.buf.fail(1, EntryError::NotFound("b/o1".into()));
        let mut out = Vec::new();
        let err = assemble(&exec, &ctx(1000, 0), &mut out).unwrap_err();
        assert!(matches!(err, BatchError::EntryFailed { index: 1, .. }));
    }

    #[test]
    fn coer_emits_placeholder_preserving_positions() {
        let exec = DtExec::new(1, request(3, true), 0);
        exec.buf.fill(0, vec![0; 4]);
        exec.buf.fail(1, EntryError::NotFound("b/o1".into()));
        exec.buf.fill(2, vec![2; 4]);
        let c = ctx(1000, 5);
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(o.delivered, 2);
        assert_eq!(o.placeholders, 1);
        let items =
            crate::batch::reader::BatchReader::new(std::io::Cursor::new(out)).collect_all().unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(items[1].name(), "o1");
        assert!(items[1].is_missing());
        assert_eq!(c.metrics.soft_errors.get(), 1);
    }

    #[test]
    fn soft_error_budget_enforced() {
        let exec = DtExec::new(1, request(4, true), 0);
        for i in 0..4 {
            exec.buf.fail(i, EntryError::NotFound(format!("b/o{i}")));
        }
        let c = ctx(1000, 2); // budget: 2
        let mut out = Vec::new();
        let err = assemble(&exec, &c, &mut out).unwrap_err();
        assert!(matches!(err, BatchError::SoftErrorBudget { count: 3, limit: 2 }));
        assert_eq!(c.metrics.hard_failures.get(), 1);
    }

    #[test]
    fn timeout_becomes_hard_error_without_coer() {
        let exec = DtExec::new(1, request(1, false), 0);
        let c = ctx(30, 0);
        let mut out = Vec::new();
        let err = assemble(&exec, &c, &mut out).unwrap_err();
        assert!(matches!(
            err,
            BatchError::EntryFailed { index: 0, source: EntryError::SenderTimeout(_) }
        ));
        assert!(c.metrics.rxwait_ns.get() >= 25_000_000, "rxwait accounted");
    }

    #[test]
    fn timeout_with_coer_yields_placeholder() {
        let exec = DtExec::new(1, request(1, true), 0);
        let c = ctx(30, 5);
        let mut out = Vec::new();
        let o = assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(o.placeholders, 1);
    }

    #[test]
    fn registry_dispatch_routes_frames() {
        let reg = DtRegistry::new();
        let exec = reg.register(DtExec::new(42, request(2, true), 3));
        reg.dispatch(Frame::data(42, 1, vec![9]));
        reg.dispatch(Frame::soft_err(42, 0, "missing object b/o0"));
        reg.dispatch(Frame::sender_done(42, 1));
        reg.dispatch(Frame::data(777, 0, vec![1])); // unknown req: dropped
        assert!(exec.buf.is_resolved(0) && exec.buf.is_resolved(1));
        assert_eq!(exec.senders_done(), 1);
        reg.remove(42);
        assert_eq!(reg.inflight(), 0);
    }

    #[test]
    fn work_item_metrics_distinguish_members() {
        let req = BatchRequest::new(vec![
            BatchEntry::obj("b", "whole"),
            BatchEntry::member("b", "s.tar", "m"),
        ]);
        let exec = DtExec::new(1, req, 0);
        exec.buf.fill(0, vec![1; 100]);
        exec.buf.fill(1, vec![2; 40]);
        let c = ctx(1000, 0);
        let mut out = Vec::new();
        assemble(&exec, &c, &mut out).unwrap();
        assert_eq!(c.metrics.objs_delivered.get(), 1);
        assert_eq!(c.metrics.obj_bytes.get(), 100);
        assert_eq!(c.metrics.members_extracted.get(), 1);
        assert_eq!(c.metrics.member_bytes.get(), 40);
        assert_eq!(c.metrics.work_items.get(), 2);
    }
}
