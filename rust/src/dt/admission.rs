//! Admission control & backpressure at the DT (§2.4.3).
//!
//! Memory pressure is a *hard* constraint, enforced at two levels:
//!
//! 1. [`Admission::check_register`] — new work is rejected with HTTP 429
//!    once DT-buffered bytes cross the critical threshold;
//! 2. [`MemoryBudget`] — an *enforced* resident-bytes budget on the data
//!    plane: every byte entering a DT reorder buffer must reserve against
//!    the node's budget first, and producers block (which propagates as TCP
//!    backpressure to senders) while the buffer is full. This replaces the
//!    earlier "soft gate" that only wrote a gauge.
//!
//! CPU/disk pressure stays *soft* — the DT inserts calibrated sleeps
//! ([`Admission::throttle`]) while in-flight work proceeds.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::GetBatchConfig;
use crate::metrics::GetBatchMetrics;
use crate::util::clock::{Clock, RealClock};

/// Node-wide resident-bytes budget shared by every in-flight DT execution
/// on one target.
///
/// Admission rule (see `OrderBuffer::reserve` for the caller side):
///
/// * normal reservations are admitted only while `used + bytes <= cap`,
///   where `cap = budget - chunk_bytes`;
/// * the consumer's head-of-line slot may force one chunk in while it holds
///   no resident bytes (progress exemption).
///
/// Because an exempt chunk is at most `chunk_bytes` and normal admissions
/// never push `used` past `cap`, peak residency stays ≤ `budget` for a
/// single in-flight execution (requires `budget ≥ 2 × chunk_bytes`;
/// `config::GetBatchConfig` documents the knobs). With R concurrent
/// executions each head may hold one exempt chunk, so the worst case is
/// `cap + R × chunk_bytes`; the `mem_critical_bytes` 429 gate bounds R
/// under sustained pressure. A patience timeout force-admits rather than
/// wedging the node if a consumer stalls indefinitely; such overruns are
/// counted.
pub struct MemoryBudget {
    budget: u64,
    cap: u64,
    state: Mutex<BudgetState>,
    cv: Condvar,
    patience: Duration,
    metrics: Option<Arc<GetBatchMetrics>>,
    /// Deadlines and wait slices run on this clock. Production budgets use
    /// the real monotonic clock; the scale simulator injects a
    /// `VirtualClock` so millions of patience windows elapse in CI seconds.
    clock: Arc<dyn Clock>,
}

struct BudgetState {
    used: u64,
    peak: u64,
    overruns: u64,
}

impl MemoryBudget {
    /// Default patience (see [`MemoryBudget::with_patience`] /
    /// `GetBatchConfig::budget_patience` for the configurable path).
    pub const DEFAULT_PATIENCE: Duration = Duration::from_secs(10);

    pub fn new(budget_bytes: u64, chunk_bytes: u64, metrics: Option<Arc<GetBatchMetrics>>) -> Arc<MemoryBudget> {
        MemoryBudget::with_patience(budget_bytes, chunk_bytes, MemoryBudget::DEFAULT_PATIENCE, metrics)
    }

    /// Budget with an explicit producer patience — how long a producer may
    /// block on a full budget before being force-admitted (the
    /// `budget_patience_ms` config knob).
    pub fn with_patience(
        budget_bytes: u64,
        chunk_bytes: u64,
        patience: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> Arc<MemoryBudget> {
        MemoryBudget::with_clock(budget_bytes, chunk_bytes, patience, metrics, RealClock::new())
    }

    /// Budget on an explicit clock (the simulation-harness entry point; the
    /// production constructors above pin the real clock).
    pub fn with_clock(
        budget_bytes: u64,
        chunk_bytes: u64,
        patience: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
        clock: Arc<dyn Clock>,
    ) -> Arc<MemoryBudget> {
        let budget = budget_bytes.max(1);
        let cap = budget.saturating_sub(chunk_bytes).max(1);
        Arc::new(MemoryBudget {
            budget,
            cap,
            state: Mutex::new(BudgetState { used: 0, peak: 0, overruns: 0 }),
            cv: Condvar::new(),
            patience,
            metrics,
            clock,
        })
    }

    /// Configured budget (the operator-facing number).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// How long a producer may block before being force-admitted.
    pub fn patience(&self) -> Duration {
        self.patience
    }

    /// Current time on the budget's clock (nanoseconds). Deadlines handed to
    /// [`MemoryBudget::wait_room_until_ns`] must come from here so that real
    /// and virtual budgets share one code path.
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Would a normal (non-exempt) reservation of `bytes` be admitted right
    /// now? Pure query — reserves nothing. The simulator uses this to model
    /// TCP backpressure: a sender whose chunk has no room is rescheduled
    /// instead of force-admitted.
    pub fn has_room(&self, bytes: u64) -> bool {
        self.state.lock().unwrap().used + bytes <= self.cap
    }

    pub fn used(&self) -> u64 {
        self.state.lock().unwrap().used
    }

    /// High-water mark of resident bytes (test/diagnostic hook for the
    /// "never exceeds the budget" guarantee).
    pub fn peak(&self) -> u64 {
        self.state.lock().unwrap().peak
    }

    /// Forced admissions after patience ran out (0 in healthy operation).
    pub fn overruns(&self) -> u64 {
        self.state.lock().unwrap().overruns
    }

    fn admit_locked(&self, st: &mut BudgetState, bytes: u64) {
        st.used += bytes;
        st.peak = st.peak.max(st.used);
        if let Some(m) = &self.metrics {
            m.dt_buffered_bytes.set(st.used as i64);
        }
    }

    /// Admit `bytes` iff it fits under the cap.
    pub fn try_reserve(&self, bytes: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.used + bytes > self.cap {
            return false;
        }
        self.admit_locked(&mut st, bytes);
        true
    }

    /// Admit `bytes` unconditionally (head-of-line exemption or patience
    /// overrun).
    pub fn force_reserve(&self, bytes: u64, overrun: bool) {
        let mut st = self.state.lock().unwrap();
        if overrun {
            st.overruns += 1;
            if let Some(m) = &self.metrics {
                m.budget_overruns.inc();
            }
        }
        self.admit_locked(&mut st, bytes);
    }

    /// Block briefly waiting for room (or an exemption-state change — the
    /// caller re-checks its exemption between slices). Returns `false` once
    /// `deadline` has passed. Wall-clock convenience over
    /// [`MemoryBudget::wait_room_until_ns`].
    pub fn wait_room_until(&self, deadline: Instant) -> bool {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return false;
        }
        self.wait_room_until_ns(self.clock.now_ns().saturating_add(remaining.as_nanos() as u64))
    }

    /// Clock-relative variant: `deadline_ns` is on the budget's own clock
    /// ([`MemoryBudget::now_ns`]). On a real clock this parks on the budget
    /// condvar in ≤ 5 ms slices exactly as before; on a virtual clock it
    /// *advances* the clock by the slice instead — parking would deadlock,
    /// since virtual time only moves when someone moves it.
    pub fn wait_room_until_ns(&self, deadline_ns: u64) -> bool {
        let now = self.clock.now_ns();
        if now >= deadline_ns {
            return false;
        }
        // Short slice: exemption state (the consumer's head index) changes
        // without a budget notification, so never park for long.
        let slice = Duration::from_nanos((deadline_ns - now).min(5_000_000));
        if self.clock.is_virtual() {
            self.clock.sleep(slice);
            if let Some(m) = &self.metrics {
                m.budget_wait_ns.add(slice.as_nanos() as u64);
            }
        } else {
            let st = self.state.lock().unwrap();
            let t0 = Instant::now();
            let _ = self.cv.wait_timeout(st, slice).unwrap();
            if let Some(m) = &self.metrics {
                m.budget_wait_ns.add(t0.elapsed().as_nanos() as u64);
            }
        }
        self.clock.now_ns() < deadline_ns
    }

    /// Consumer-side reservation for GFN recovery chunks. Recovery *is* the
    /// head-of-line consumer: the resident bytes saturating the budget may
    /// belong to later slots of the very request being recovered, and those
    /// can only drain after recovery completes — so blocking here (let
    /// alone a patience window per chunk) would stall or even wedge the
    /// node. Give room a brief chance, then take the head-of-line exemption
    /// (`force_reserve`, *not* counted as an overrun). Residency per
    /// recovery is a single chunk held only while it is written through, so
    /// the peak bound matches the producer-side exemption
    /// (`cap + R × chunk_bytes` for R concurrent heads).
    pub fn reserve_for_recovery(&self, bytes: u64) {
        if bytes == 0 || self.try_reserve(bytes) {
            return;
        }
        let deadline_ns = self.clock.now_ns().saturating_add(50_000_000);
        while self.wait_room_until_ns(deadline_ns) {
            if self.try_reserve(bytes) {
                return;
            }
        }
        self.force_reserve(bytes, false);
    }

    pub fn release(&self, bytes: u64) {
        let mut st = self.state.lock().unwrap();
        st.used = st.used.saturating_sub(bytes);
        if let Some(m) = &self.metrics {
            m.dt_buffered_bytes.set(st.used as i64);
        }
        drop(st);
        self.cv.notify_all();
    }
}

pub struct Admission {
    cfg: GetBatchConfig,
    metrics: Arc<GetBatchMetrics>,
    clock: Arc<dyn Clock>,
    /// `budget_overruns` counter value observed at the last registration
    /// check — the overrun gate rejects on the *delta* since then.
    overruns_seen: std::sync::atomic::AtomicU64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    Ok,
    /// Reject with HTTP 429 — client backs off and retries.
    RejectMemory { buffered: i64, critical: u64 },
    /// Reject with HTTP 429: the data plane force-admitted (overran) its
    /// memory budget since the last registration — producers are waiting
    /// out the budget patience, so new work would only deepen the hole.
    RejectOverrun { overruns: u64, limit: u64 },
}

impl Admission {
    pub fn new(cfg: GetBatchConfig, metrics: Arc<GetBatchMetrics>, clock: Arc<dyn Clock>) -> Admission {
        Admission { cfg, metrics, clock, overruns_seen: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Hard gate at DT registration: memory critical ⇒ 429; a burst of
    /// budget overruns (≥ `budget_overrun_limit` forced admissions since
    /// the previous registration) ⇒ 429 too (`budget_overrun_limit = 0`
    /// disables the overrun gate).
    pub fn check_register(&self) -> Admit {
        let buffered = self.metrics.dt_buffered_bytes.get();
        if buffered >= self.cfg.mem_critical_bytes as i64 {
            self.metrics.admission_rejects.inc();
            return Admit::RejectMemory { buffered, critical: self.cfg.mem_critical_bytes };
        }
        let limit = self.cfg.budget_overrun_limit as u64;
        if limit > 0 {
            use std::sync::atomic::Ordering;
            let total = self.metrics.budget_overruns.get();
            let seen = self.overruns_seen.swap(total, Ordering::Relaxed);
            let fresh = total.saturating_sub(seen);
            if fresh >= limit {
                self.metrics.admission_rejects.inc();
                return Admit::RejectOverrun { overruns: fresh, limit };
            }
        }
        Admit::Ok
    }

    /// Soft gate on the work loops: sleep proportionally to overload above
    /// the watermark. Returns the slept duration (accounted as `throttle`).
    pub fn throttle(&self, inflight_items: i64) -> Duration {
        if inflight_items <= self.cfg.throttle_watermark {
            return Duration::ZERO;
        }
        let over = (inflight_items - self.cfg.throttle_watermark) as u32;
        // Calibrated: base × overload factor, capped at 50 ms per step so
        // in-flight work keeps making forward progress (§2.4.3).
        let d = (self.cfg.throttle_base * over).min(Duration::from_millis(50));
        self.clock.sleep(d);
        self.metrics.throttle_ns.add(d.as_nanos() as u64);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn setup(mem_critical: u64, watermark: i64) -> (Admission, Arc<GetBatchMetrics>, Arc<VirtualClock>) {
        let metrics = GetBatchMetrics::new();
        let clock = VirtualClock::new();
        let cfg = GetBatchConfig {
            mem_critical_bytes: mem_critical,
            throttle_watermark: watermark,
            throttle_base: Duration::from_micros(100),
            ..Default::default()
        };
        (Admission::new(cfg, Arc::clone(&metrics), clock.clone()), metrics, clock)
    }

    #[test]
    fn admits_under_threshold() {
        let (adm, m, _) = setup(1000, 10);
        m.dt_buffered_bytes.set(999);
        assert_eq!(adm.check_register(), Admit::Ok);
        assert_eq!(m.admission_rejects.get(), 0);
    }

    #[test]
    fn rejects_at_memory_critical() {
        let (adm, m, _) = setup(1000, 10);
        m.dt_buffered_bytes.set(1000);
        assert!(matches!(adm.check_register(), Admit::RejectMemory { buffered: 1000, .. }));
        assert_eq!(m.admission_rejects.get(), 1);
    }

    #[test]
    fn overrun_burst_rejects_then_readmits() {
        let (adm, m, _) = setup(1 << 30, 10); // memory gate never fires
        // default limit is small but nonzero; drive a burst past it
        let limit = GetBatchConfig::default().budget_overrun_limit as u64;
        assert!(limit > 0, "overrun gate enabled by default");
        m.budget_overruns.add(limit);
        assert!(matches!(adm.check_register(), Admit::RejectOverrun { .. }));
        assert_eq!(m.admission_rejects.get(), 1);
        // burst consumed: the next registration is admitted again
        assert_eq!(adm.check_register(), Admit::Ok);
        // below-limit trickle never rejects
        m.budget_overruns.add(limit - 1);
        assert_eq!(adm.check_register(), Admit::Ok);
    }

    #[test]
    fn overrun_gate_disabled_at_zero_limit() {
        let metrics = GetBatchMetrics::new();
        let cfg = GetBatchConfig {
            mem_critical_bytes: 1 << 30,
            budget_overrun_limit: 0,
            ..Default::default()
        };
        let adm = Admission::new(cfg, Arc::clone(&metrics), VirtualClock::new());
        metrics.budget_overruns.add(1_000);
        assert_eq!(adm.check_register(), Admit::Ok);
    }

    #[test]
    fn configurable_patience_and_recovery_reservation() {
        // Patience flows from the constructor (producer side)...
        let b = MemoryBudget::with_patience(10, 2, Duration::from_millis(30), None);
        assert_eq!(b.patience(), Duration::from_millis(30));
        assert!(b.try_reserve(8)); // cap reached
        // ...but recovery never pays patience per chunk: it takes the
        // head-of-line exemption after a brief grace, and that is NOT an
        // overrun — the blocking bytes may be this very request's later
        // slots, which only drain once recovery finishes.
        let t0 = Instant::now();
        b.reserve_for_recovery(4);
        assert!(t0.elapsed() < Duration::from_secs(2), "no patience-long stall");
        assert_eq!(b.used(), 12);
        assert_eq!(b.overruns(), 0, "head-of-line exemption, not an overrun");
        b.release(12);
        // with room available the reservation is immediate and clean
        b.reserve_for_recovery(4);
        assert_eq!(b.used(), 4);
        assert_eq!(b.overruns(), 0);
    }

    #[test]
    fn no_throttle_below_watermark() {
        let (adm, m, clock) = setup(1 << 30, 10);
        assert_eq!(adm.throttle(10), Duration::ZERO);
        assert_eq!(clock.now_ns(), 0);
        assert_eq!(m.throttle_ns.get(), 0);
    }

    #[test]
    fn throttle_scales_with_overload() {
        let (adm, m, _clock) = setup(1 << 30, 10);
        let d1 = adm.throttle(11); // 1 over
        let d5 = adm.throttle(15); // 5 over
        assert_eq!(d1, Duration::from_micros(100));
        assert_eq!(d5, Duration::from_micros(500));
        assert_eq!(m.throttle_ns.get(), (d1 + d5).as_nanos() as u64);
    }

    #[test]
    fn throttle_capped() {
        let (adm, _, _) = setup(1 << 30, 0);
        assert_eq!(adm.throttle(1_000_000), Duration::from_millis(50));
    }

    #[test]
    fn budget_cap_leaves_headroom_for_exempt_chunk() {
        let b = MemoryBudget::new(100, 30, None);
        // cap = 70: normal admissions stop there...
        assert!(b.try_reserve(70));
        assert!(!b.try_reserve(1));
        // ...so one exempt chunk (≤ 30) can never push past the budget.
        b.force_reserve(30, false);
        assert_eq!(b.used(), 100);
        assert!(b.peak() <= b.budget());
        b.release(100);
        assert_eq!(b.used(), 0);
        assert_eq!(b.peak(), 100, "peak is a high-water mark");
        assert_eq!(b.overruns(), 0);
    }

    #[test]
    fn budget_tracks_overruns_and_gauge() {
        let metrics = GetBatchMetrics::new();
        let b = MemoryBudget::new(64, 16, Some(Arc::clone(&metrics)));
        assert!(b.try_reserve(40));
        assert_eq!(metrics.dt_buffered_bytes.get(), 40);
        b.force_reserve(10, true);
        assert_eq!(b.overruns(), 1);
        assert_eq!(metrics.budget_overruns.get(), 1);
        b.release(50);
        assert_eq!(metrics.dt_buffered_bytes.get(), 0);
    }

    #[test]
    fn virtual_budget_waits_advance_time_instead_of_parking() {
        let clock = VirtualClock::new();
        let b = MemoryBudget::with_clock(10, 2, Duration::from_millis(30), None, clock.clone());
        assert!(b.try_reserve(8)); // cap reached
        assert!(!b.has_room(1));
        let t0 = Instant::now();
        let deadline = b.now_ns() + 30_000_000;
        let mut slices = 0;
        while b.wait_room_until_ns(deadline) {
            slices += 1;
            assert!(slices < 1000, "must terminate");
        }
        assert!(slices >= 5, "30 ms of patience in 5 ms virtual slices, saw {slices}");
        assert_eq!(clock.now_ns(), 30_000_000, "waits advanced the virtual clock");
        assert!(t0.elapsed() < Duration::from_secs(1), "no real-time parking");
        b.release(8);
        assert!(b.has_room(2));
    }

    #[test]
    fn virtual_budget_recovery_reservation_is_instant_in_real_time() {
        let clock = VirtualClock::new();
        let b = MemoryBudget::with_clock(10, 2, Duration::from_secs(3600), None, clock.clone());
        assert!(b.try_reserve(8)); // saturated
        b.reserve_for_recovery(4); // 50 ms virtual grace, then exemption
        assert_eq!(b.used(), 12);
        assert_eq!(b.overruns(), 0, "recovery exemption is not an overrun");
        assert!(clock.now_ns() >= 50_000_000, "grace elapsed virtually");
    }

    #[test]
    fn budget_wait_room_respects_deadline() {
        let b = MemoryBudget::new(10, 2, None);
        assert!(b.try_reserve(8)); // cap reached
        let deadline = Instant::now() + Duration::from_millis(25);
        let mut waited = 0;
        while b.wait_room_until(deadline) {
            waited += 1;
            assert!(waited < 1000, "must terminate");
        }
        assert!(Instant::now() >= deadline);
    }
}
