//! Admission control & throttling at the DT (§2.4.3): memory pressure is a
//! *hard* constraint — new work is rejected with HTTP 429 once DT-buffered
//! bytes cross the critical threshold; CPU/disk pressure is *soft* — the DT
//! inserts calibrated sleeps (backpressure) while in-flight work proceeds.

use std::sync::Arc;
use std::time::Duration;

use crate::config::GetBatchConfig;
use crate::metrics::GetBatchMetrics;
use crate::util::clock::Clock;

pub struct Admission {
    cfg: GetBatchConfig,
    metrics: Arc<GetBatchMetrics>,
    clock: Arc<dyn Clock>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    Ok,
    /// Reject with HTTP 429 — client backs off and retries.
    RejectMemory { buffered: i64, critical: u64 },
}

impl Admission {
    pub fn new(cfg: GetBatchConfig, metrics: Arc<GetBatchMetrics>, clock: Arc<dyn Clock>) -> Admission {
        Admission { cfg, metrics, clock }
    }

    /// Hard gate at DT registration: memory critical ⇒ 429.
    pub fn check_register(&self) -> Admit {
        let buffered = self.metrics.dt_buffered_bytes.get();
        if buffered >= self.cfg.mem_critical_bytes as i64 {
            self.metrics.admission_rejects.inc();
            return Admit::RejectMemory { buffered, critical: self.cfg.mem_critical_bytes };
        }
        Admit::Ok
    }

    /// Soft gate on the work loops: sleep proportionally to overload above
    /// the watermark. Returns the slept duration (accounted as `throttle`).
    pub fn throttle(&self, inflight_items: i64) -> Duration {
        if inflight_items <= self.cfg.throttle_watermark {
            return Duration::ZERO;
        }
        let over = (inflight_items - self.cfg.throttle_watermark) as u32;
        // Calibrated: base × overload factor, capped at 50 ms per step so
        // in-flight work keeps making forward progress (§2.4.3).
        let d = (self.cfg.throttle_base * over).min(Duration::from_millis(50));
        self.clock.sleep(d);
        self.metrics.throttle_ns.add(d.as_nanos() as u64);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn setup(mem_critical: u64, watermark: i64) -> (Admission, Arc<GetBatchMetrics>, Arc<VirtualClock>) {
        let metrics = GetBatchMetrics::new();
        let clock = VirtualClock::new();
        let cfg = GetBatchConfig {
            mem_critical_bytes: mem_critical,
            throttle_watermark: watermark,
            throttle_base: Duration::from_micros(100),
            ..Default::default()
        };
        (Admission::new(cfg, Arc::clone(&metrics), clock.clone()), metrics, clock)
    }

    #[test]
    fn admits_under_threshold() {
        let (adm, m, _) = setup(1000, 10);
        m.dt_buffered_bytes.set(999);
        assert_eq!(adm.check_register(), Admit::Ok);
        assert_eq!(m.admission_rejects.get(), 0);
    }

    #[test]
    fn rejects_at_memory_critical() {
        let (adm, m, _) = setup(1000, 10);
        m.dt_buffered_bytes.set(1000);
        assert!(matches!(adm.check_register(), Admit::RejectMemory { buffered: 1000, .. }));
        assert_eq!(m.admission_rejects.get(), 1);
    }

    #[test]
    fn no_throttle_below_watermark() {
        let (adm, m, clock) = setup(1 << 30, 10);
        assert_eq!(adm.throttle(10), Duration::ZERO);
        assert_eq!(clock.now_ns(), 0);
        assert_eq!(m.throttle_ns.get(), 0);
    }

    #[test]
    fn throttle_scales_with_overload() {
        let (adm, m, _clock) = setup(1 << 30, 10);
        let d1 = adm.throttle(11); // 1 over
        let d5 = adm.throttle(15); // 5 over
        assert_eq!(d1, Duration::from_micros(100));
        assert_eq!(d5, Duration::from_micros(500));
        assert_eq!(m.throttle_ns.get(), (d1 + d5).as_nanos() as u64);
    }

    #[test]
    fn throttle_capped() {
        let (adm, _, _) = setup(1 << 30, 0);
        assert_eq!(adm.throttle(1_000_000), Duration::from_millis(50));
    }
}
