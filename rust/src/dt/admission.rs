//! Admission control & backpressure at the DT (§2.4.3).
//!
//! Memory pressure is a *hard* constraint, enforced at two levels:
//!
//! 1. [`Admission::check_register`] — new work is rejected with HTTP 429
//!    once DT-buffered bytes cross the critical threshold;
//! 2. [`MemoryBudget`] — an *enforced* resident-bytes budget on the data
//!    plane: every byte entering a DT reorder buffer must reserve against
//!    the node's budget first, and producers block (which propagates as TCP
//!    backpressure to senders) while the buffer is full. This replaces the
//!    earlier "soft gate" that only wrote a gauge.
//!
//! CPU/disk pressure stays *soft* — the DT inserts calibrated sleeps
//! ([`Admission::throttle`]) while in-flight work proceeds.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::GetBatchConfig;
use crate::metrics::GetBatchMetrics;
use crate::util::clock::{Clock, RealClock};

/// Node-wide resident-bytes budget shared by every in-flight DT execution
/// on one target.
///
/// Admission rule (see `OrderBuffer::reserve` for the caller side):
///
/// * normal reservations are admitted only while `used + bytes <= cap`,
///   where `cap = budget - chunk_bytes`;
/// * the consumer's head-of-line slot may force one chunk in while it holds
///   no resident bytes (progress exemption).
///
/// Because an exempt chunk is at most `chunk_bytes` and normal admissions
/// never push `used` past `cap`, peak residency stays ≤ `budget` for a
/// single in-flight execution (requires `budget ≥ 2 × chunk_bytes`;
/// `config::GetBatchConfig` documents the knobs). With R concurrent
/// executions each head may hold one exempt chunk, so the worst case is
/// `cap + R × chunk_bytes`; the `mem_critical_bytes` 429 gate bounds R
/// under sustained pressure. A patience timeout force-admits rather than
/// wedging the node if a consumer stalls indefinitely; such overruns are
/// counted.
pub struct MemoryBudget {
    budget: u64,
    cap: u64,
    state: Mutex<BudgetState>,
    cv: Condvar,
    patience: Duration,
    metrics: Option<Arc<GetBatchMetrics>>,
    /// Deadlines and wait slices run on this clock. Production budgets use
    /// the real monotonic clock; the scale simulator injects a
    /// `VirtualClock` so millions of patience windows elapse in CI seconds.
    clock: Arc<dyn Clock>,
}

struct BudgetState {
    used: u64,
    peak: u64,
    overruns: u64,
}

impl MemoryBudget {
    /// Default patience (see [`MemoryBudget::with_patience`] /
    /// `GetBatchConfig::budget_patience` for the configurable path).
    pub const DEFAULT_PATIENCE: Duration = Duration::from_secs(10);

    pub fn new(budget_bytes: u64, chunk_bytes: u64, metrics: Option<Arc<GetBatchMetrics>>) -> Arc<MemoryBudget> {
        MemoryBudget::with_patience(budget_bytes, chunk_bytes, MemoryBudget::DEFAULT_PATIENCE, metrics)
    }

    /// Budget with an explicit producer patience — how long a producer may
    /// block on a full budget before being force-admitted (the
    /// `budget_patience_ms` config knob).
    pub fn with_patience(
        budget_bytes: u64,
        chunk_bytes: u64,
        patience: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> Arc<MemoryBudget> {
        MemoryBudget::with_clock(budget_bytes, chunk_bytes, patience, metrics, RealClock::new())
    }

    /// Budget on an explicit clock (the simulation-harness entry point; the
    /// production constructors above pin the real clock).
    pub fn with_clock(
        budget_bytes: u64,
        chunk_bytes: u64,
        patience: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
        clock: Arc<dyn Clock>,
    ) -> Arc<MemoryBudget> {
        let budget = budget_bytes.max(1);
        let cap = budget.saturating_sub(chunk_bytes).max(1);
        Arc::new(MemoryBudget {
            budget,
            cap,
            state: Mutex::new(BudgetState { used: 0, peak: 0, overruns: 0 }),
            cv: Condvar::new(),
            patience,
            metrics,
            clock,
        })
    }

    /// Configured budget (the operator-facing number).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// How long a producer may block before being force-admitted.
    pub fn patience(&self) -> Duration {
        self.patience
    }

    /// Current time on the budget's clock (nanoseconds). Deadlines handed to
    /// [`MemoryBudget::wait_room_until_ns`] must come from here so that real
    /// and virtual budgets share one code path.
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Would a normal (non-exempt) reservation of `bytes` be admitted right
    /// now? Pure query — reserves nothing. The simulator uses this to model
    /// TCP backpressure: a sender whose chunk has no room is rescheduled
    /// instead of force-admitted.
    pub fn has_room(&self, bytes: u64) -> bool {
        if bytes > self.budget {
            return false;
        }
        self.state.lock().unwrap().used.checked_add(bytes).is_some_and(|total| total <= self.cap)
    }

    pub fn used(&self) -> u64 {
        self.state.lock().unwrap().used
    }

    /// High-water mark of resident bytes (test/diagnostic hook for the
    /// "never exceeds the budget" guarantee).
    pub fn peak(&self) -> u64 {
        self.state.lock().unwrap().peak
    }

    /// Forced admissions after patience ran out (0 in healthy operation).
    pub fn overruns(&self) -> u64 {
        self.state.lock().unwrap().overruns
    }

    fn admit_locked(&self, st: &mut BudgetState, bytes: u64) {
        st.used += bytes;
        st.peak = st.peak.max(st.used);
        if let Some(m) = &self.metrics {
            m.dt_buffered_bytes.set(st.used as i64);
        }
    }

    /// Admit `bytes` iff it fits under the cap. A single reservation larger
    /// than the whole budget, or one that would overflow the resident-bytes
    /// counter (a corrupt frame header), is rejected outright — the old
    /// unchecked `used + bytes` wrapped in release builds and falsely
    /// admitted unbounded reservations.
    pub fn try_reserve(&self, bytes: u64) -> bool {
        if bytes > self.budget {
            return false;
        }
        let mut st = self.state.lock().unwrap();
        match st.used.checked_add(bytes) {
            Some(total) if total <= self.cap => {}
            _ => return false,
        }
        self.admit_locked(&mut st, bytes);
        true
    }

    /// Admit `bytes` unconditionally (head-of-line exemption or patience
    /// overrun).
    pub fn force_reserve(&self, bytes: u64, overrun: bool) {
        let mut st = self.state.lock().unwrap();
        if overrun {
            st.overruns += 1;
            if let Some(m) = &self.metrics {
                m.budget_overruns.inc();
            }
        }
        self.admit_locked(&mut st, bytes);
    }

    /// Block briefly waiting for room (or an exemption-state change — the
    /// caller re-checks its exemption between slices). Returns `false` once
    /// `deadline` has passed. Wall-clock convenience over
    /// [`MemoryBudget::wait_room_until_ns`].
    pub fn wait_room_until(&self, deadline: Instant) -> bool {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return false;
        }
        self.wait_room_until_ns(self.clock.now_ns().saturating_add(remaining.as_nanos() as u64))
    }

    /// Clock-relative variant: `deadline_ns` is on the budget's own clock
    /// ([`MemoryBudget::now_ns`]). On a real clock this parks on the budget
    /// condvar in ≤ 5 ms slices exactly as before; on a virtual clock it
    /// *advances* the clock by the slice instead — parking would deadlock,
    /// since virtual time only moves when someone moves it.
    pub fn wait_room_until_ns(&self, deadline_ns: u64) -> bool {
        let now = self.clock.now_ns();
        if now >= deadline_ns {
            return false;
        }
        // Short slice: exemption state (the consumer's head index) changes
        // without a budget notification, so never park for long.
        let slice = Duration::from_nanos((deadline_ns - now).min(5_000_000));
        if self.clock.is_virtual() {
            self.clock.sleep(slice);
            if let Some(m) = &self.metrics {
                m.budget_wait_ns.add(slice.as_nanos() as u64);
            }
        } else {
            let st = self.state.lock().unwrap();
            let t0 = Instant::now();
            let _ = self.cv.wait_timeout(st, slice).unwrap();
            if let Some(m) = &self.metrics {
                m.budget_wait_ns.add(t0.elapsed().as_nanos() as u64);
            }
        }
        self.clock.now_ns() < deadline_ns
    }

    /// Consumer-side reservation for GFN recovery chunks. Recovery *is* the
    /// head-of-line consumer: the resident bytes saturating the budget may
    /// belong to later slots of the very request being recovered, and those
    /// can only drain after recovery completes — so blocking here (let
    /// alone a patience window per chunk) would stall or even wedge the
    /// node. Give room a brief chance, then take the head-of-line exemption
    /// (`force_reserve`, *not* counted as an overrun). Residency per
    /// recovery is a single chunk held only while it is written through, so
    /// the peak bound matches the producer-side exemption
    /// (`cap + R × chunk_bytes` for R concurrent heads).
    pub fn reserve_for_recovery(&self, bytes: u64) {
        if bytes == 0 || self.try_reserve(bytes) {
            return;
        }
        let deadline_ns = self.clock.now_ns().saturating_add(50_000_000);
        while self.wait_room_until_ns(deadline_ns) {
            if self.try_reserve(bytes) {
                return;
            }
        }
        self.force_reserve(bytes, false);
    }

    pub fn release(&self, bytes: u64) {
        let mut st = self.state.lock().unwrap();
        st.used = st.used.saturating_sub(bytes);
        if let Some(m) = &self.metrics {
            m.dt_buffered_bytes.set(st.used as i64);
        }
        drop(st);
        self.cv.notify_all();
    }
}

/// Priority class carried on DT registration (the `priority` field /
/// `x-getbatch-priority` header). Declaration order is shed order: as
/// buffered bytes approach `mem_critical_bytes` the lowest class is
/// rejected first (`Bulk` < `Batch` < `Interactive`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Bulk,
    Batch,
    Interactive,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "bulk" => Some(Priority::Bulk),
            "batch" => Some(Priority::Batch),
            "interactive" => Some(Priority::Interactive),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Bulk => "bulk",
            Priority::Batch => "batch",
            Priority::Interactive => "interactive",
        }
    }

    /// Multiplier on the 429 `Retry-After` hint: lower classes are asked to
    /// back off longer, so freed room is retried into by interactive work
    /// first.
    pub fn backoff_factor(self) -> u64 {
        match self {
            Priority::Interactive => 1,
            Priority::Batch => 2,
            Priority::Bulk => 4,
        }
    }

    /// Buffered-bytes level at which this class is shed: bulk at 1/2 of
    /// critical, batch at 3/4, interactive at the full threshold.
    fn shed_threshold(self, critical: u64) -> u64 {
        match self {
            Priority::Bulk => (critical / 2).max(1),
            Priority::Batch => (critical - critical / 4).max(1),
            Priority::Interactive => critical,
        }
    }
}

/// Weighted-fair per-tenant token accounting layered over [`MemoryBudget`].
///
/// The node-wide budget stays the hard cap; the ledger divides the
/// budget's usable cap among *active* tenants (those holding in-flight
/// executions or resident bytes) in proportion to their configured
/// weights. A tenant under its share is always admitted (subject to the
/// global budget); a tenant over its share may *borrow* only headroom not
/// reserved for other active tenants. Idle shares are therefore
/// borrowable, but a greedy tenant blocks before the global cap as soon
/// as anyone else is active — `OrderBuffer::reserve` consults the ledger
/// ahead of the budget and never patience-forces past a fair-share
/// refusal (isolation would collapse if it did; head-of-line progress is
/// still exempt, so the over-share tenant drains slowly instead of
/// wedging).
pub struct TenantLedger {
    cap: u64,
    chunk: u64,
    weights: BTreeMap<String, u64>,
    state: Mutex<LedgerState>,
    metrics: Option<Arc<GetBatchMetrics>>,
}

#[derive(Default)]
struct LedgerState {
    tenants: BTreeMap<String, TenantUse>,
}

#[derive(Default)]
struct TenantUse {
    weight: u64,
    used: u64,
    inflight: u64,
}

impl TenantLedger {
    /// Ledger over the same (budget, chunk) geometry as the node's
    /// [`MemoryBudget`] — shares are fractions of the budget's usable cap,
    /// so "every active tenant at its share" sums to exactly the cap.
    pub fn new(
        budget_bytes: u64,
        chunk_bytes: u64,
        weights: BTreeMap<String, u64>,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> Arc<TenantLedger> {
        let budget = budget_bytes.max(1);
        let cap = budget.saturating_sub(chunk_bytes).max(1);
        Arc::new(TenantLedger {
            cap,
            chunk: chunk_bytes.max(1),
            weights,
            state: Mutex::new(LedgerState::default()),
            metrics,
        })
    }

    /// Handle for one execution owned by `tenant`. The tenant counts as
    /// active — its unused share reserved, not borrowable — for the
    /// handle's lifetime plus as long as it holds resident bytes.
    pub fn handle(self: &Arc<TenantLedger>, tenant: &str) -> TenantHandle {
        let weight = self.weights.get(tenant).copied().unwrap_or(1).max(1);
        let mut st = self.state.lock().unwrap();
        let t = st.tenants.entry(tenant.to_string()).or_default();
        t.weight = weight;
        t.inflight += 1;
        drop(st);
        TenantHandle { ledger: Arc::clone(self), tenant: tenant.to_string() }
    }

    fn share_locked(&self, st: &LedgerState, tenant: &str) -> u64 {
        let total_w: u64 = st
            .tenants
            .iter()
            .filter(|(name, t)| t.inflight > 0 || t.used > 0 || name.as_str() == tenant)
            .map(|(_, t)| t.weight.max(1))
            .sum();
        let w = st.tenants.get(tenant).map_or(1, |t| t.weight.max(1));
        if total_w <= w {
            return self.cap; // sole active tenant: the whole budget
        }
        let share = ((self.cap as u128 * w as u128) / total_w as u128) as u64;
        // Floor of two chunks so a tiny share can still stream.
        share.max(2 * self.chunk)
    }

    fn admissible_locked(&self, st: &LedgerState, tenant: &str, bytes: u64) -> bool {
        let used = st.tenants.get(tenant).map_or(0, |t| t.used);
        let Some(after) = used.checked_add(bytes) else { return false };
        if after <= self.share_locked(st, tenant) {
            return true;
        }
        // Over share: borrow only headroom not reserved for other *active*
        // tenants (their unused share is spoken for; idle tenants reserve
        // nothing and are fully borrowable).
        let mut reserved = 0u64;
        let mut total_used = 0u64;
        for (name, t) in st.tenants.iter() {
            total_used = total_used.saturating_add(t.used);
            if name.as_str() != tenant && (t.inflight > 0 || t.used > 0) {
                reserved = reserved
                    .saturating_add(self.share_locked(st, name).saturating_sub(t.used));
            }
        }
        total_used.saturating_add(bytes).saturating_add(reserved) <= self.cap
    }

    fn charge_locked(&self, st: &mut LedgerState, tenant: &str, bytes: u64) {
        let t = st.tenants.entry(tenant.to_string()).or_default();
        if t.weight == 0 {
            t.weight = self.weights.get(tenant).copied().unwrap_or(1).max(1);
        }
        t.used = t.used.saturating_add(bytes);
        if let Some(m) = &self.metrics {
            m.tenant_resident_add(tenant, bytes as i64);
        }
    }

    /// Fair-share gate plus charge: admit `bytes` for `tenant` iff within
    /// its share or borrowable headroom; charges the tenant on success.
    pub fn try_charge(&self, tenant: &str, bytes: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        if !self.admissible_locked(&st, tenant, bytes) {
            return false;
        }
        self.charge_locked(&mut st, tenant, bytes);
        true
    }

    /// Unconditional charge (head-of-line exemption or patience overrun):
    /// residency accounting must stay exact even when the gate is bypassed.
    pub fn force_charge(&self, tenant: &str, bytes: u64) {
        let mut st = self.state.lock().unwrap();
        self.charge_locked(&mut st, tenant, bytes);
    }

    pub fn uncharge(&self, tenant: &str, bytes: u64) {
        let mut st = self.state.lock().unwrap();
        let gone = match st.tenants.get_mut(tenant) {
            Some(t) => {
                t.used = t.used.saturating_sub(bytes);
                t.inflight == 0 && t.used == 0
            }
            None => false,
        };
        if gone {
            st.tenants.remove(tenant);
        }
        drop(st);
        if let Some(m) = &self.metrics {
            m.tenant_resident_add(tenant, -(bytes.min(i64::MAX as u64) as i64));
        }
    }

    /// Pure query (the simulator's backpressure model): would `bytes` be
    /// admitted for `tenant` right now? Reserves nothing.
    pub fn would_admit(&self, tenant: &str, bytes: u64) -> bool {
        let st = self.state.lock().unwrap();
        self.admissible_locked(&st, tenant, bytes)
    }

    /// Current resident bytes charged to `tenant`.
    pub fn used(&self, tenant: &str) -> u64 {
        self.state.lock().unwrap().tenants.get(tenant).map_or(0, |t| t.used)
    }

    /// Current weighted-fair share of `tenant` given the active set.
    pub fn share(&self, tenant: &str) -> u64 {
        let st = self.state.lock().unwrap();
        self.share_locked(&st, tenant)
    }

    fn note_throttle(&self, tenant: &str, ns: u64) {
        if let Some(m) = &self.metrics {
            m.tenant_throttle_add(tenant, ns);
        }
    }
}

/// One execution's claim on a tenant: keeps the tenant active in the
/// ledger for the handle's lifetime. Charging goes through the handle so
/// `OrderBuffer` stays tenant-agnostic beyond holding one of these.
pub struct TenantHandle {
    ledger: Arc<TenantLedger>,
    tenant: String,
}

impl TenantHandle {
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    pub fn try_charge(&self, bytes: u64) -> bool {
        self.ledger.try_charge(&self.tenant, bytes)
    }

    pub fn force_charge(&self, bytes: u64) {
        self.ledger.force_charge(&self.tenant, bytes)
    }

    pub fn uncharge(&self, bytes: u64) {
        self.ledger.uncharge(&self.tenant, bytes)
    }

    /// Account time a producer spent blocked on the fair-share gate or the
    /// budget (per-tenant throttle-time metric).
    pub fn note_throttle(&self, ns: u64) {
        self.ledger.note_throttle(&self.tenant, ns);
    }
}

impl Drop for TenantHandle {
    fn drop(&mut self) {
        let mut st = self.ledger.state.lock().unwrap();
        let gone = match st.tenants.get_mut(&self.tenant) {
            Some(t) => {
                t.inflight = t.inflight.saturating_sub(1);
                t.inflight == 0 && t.used == 0
            }
            None => false,
        };
        if gone {
            st.tenants.remove(&self.tenant);
        }
    }
}

pub struct Admission {
    cfg: GetBatchConfig,
    metrics: Arc<GetBatchMetrics>,
    clock: Arc<dyn Clock>,
    /// `budget_overruns` counter value observed at the last registration
    /// check — the overrun gate rejects on the *delta* since then.
    overruns_seen: std::sync::atomic::AtomicU64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    Ok,
    /// Reject with HTTP 429 — client backs off and retries.
    RejectMemory { buffered: i64, critical: u64 },
    /// Reject with HTTP 429: the data plane force-admitted (overran) its
    /// memory budget since the last registration — producers are waiting
    /// out the budget patience, so new work would only deepen the hole.
    RejectOverrun { overruns: u64, limit: u64 },
}

impl Admission {
    pub fn new(cfg: GetBatchConfig, metrics: Arc<GetBatchMetrics>, clock: Arc<dyn Clock>) -> Admission {
        Admission { cfg, metrics, clock, overruns_seen: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Hard gate at DT registration: memory critical ⇒ 429; a burst of
    /// budget overruns (≥ `budget_overrun_limit` forced admissions since
    /// the previous registration) ⇒ 429 too (`budget_overrun_limit = 0`
    /// disables the overrun gate).
    ///
    /// Class-agnostic legacy entry point: checks the full
    /// `mem_critical_bytes` threshold, i.e. behaves like
    /// [`Priority::Interactive`]. Class-aware callers use
    /// [`Admission::check_register_class`].
    pub fn check_register(&self) -> Admit {
        self.check_register_class(Priority::Interactive)
    }

    /// Class-aware registration gate: each [`Priority`] sheds at its own
    /// fraction of `mem_critical_bytes` (bulk at 1/2, batch at 3/4,
    /// interactive at the full threshold), so as buffered bytes approach
    /// critical the lowest class is rejected first and interactive work
    /// keeps landing until the node is genuinely out of room.
    pub fn check_register_class(&self, class: Priority) -> Admit {
        let buffered = self.metrics.dt_buffered_bytes.get();
        let critical = class.shed_threshold(self.cfg.mem_critical_bytes);
        if buffered >= critical as i64 {
            self.metrics.admission_rejects.inc();
            return Admit::RejectMemory { buffered, critical };
        }
        let limit = self.cfg.budget_overrun_limit as u64;
        if limit > 0 {
            use std::sync::atomic::Ordering;
            let total = self.metrics.budget_overruns.get();
            // fetch_max, not swap: a racing registration holding a stale
            // (smaller) total must never rewind the watermark, or the same
            // overrun burst is counted twice and healthy registrations get
            // spurious 429s.
            let seen = self.overruns_seen.fetch_max(total, Ordering::Relaxed);
            let fresh = total.saturating_sub(seen);
            if fresh >= limit {
                self.metrics.admission_rejects.inc();
                return Admit::RejectOverrun { overruns: fresh, limit };
            }
        }
        Admit::Ok
    }

    /// Soft gate on the work loops: sleep proportionally to overload above
    /// the watermark. Returns the slept duration (accounted as `throttle`).
    pub fn throttle(&self, inflight_items: i64) -> Duration {
        if inflight_items <= self.cfg.throttle_watermark {
            return Duration::ZERO;
        }
        let cap = Duration::from_millis(50);
        let over = u32::try_from(inflight_items.saturating_sub(self.cfg.throttle_watermark))
            .unwrap_or(u32::MAX);
        // Calibrated: base × overload factor, capped at 50 ms per step so
        // in-flight work keeps making forward progress (§2.4.3). checked_mul
        // because `Duration * u32` panics on overflow and `over` is
        // unbounded; overflow means "way past the cap", so fall back to it.
        let d = self.cfg.throttle_base.checked_mul(over).unwrap_or(cap).min(cap);
        self.clock.sleep(d);
        self.metrics.throttle_ns.add(d.as_nanos() as u64);
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn setup(mem_critical: u64, watermark: i64) -> (Admission, Arc<GetBatchMetrics>, Arc<VirtualClock>) {
        let metrics = GetBatchMetrics::new();
        let clock = VirtualClock::new();
        let cfg = GetBatchConfig {
            mem_critical_bytes: mem_critical,
            throttle_watermark: watermark,
            throttle_base: Duration::from_micros(100),
            ..Default::default()
        };
        (Admission::new(cfg, Arc::clone(&metrics), clock.clone()), metrics, clock)
    }

    #[test]
    fn admits_under_threshold() {
        let (adm, m, _) = setup(1000, 10);
        m.dt_buffered_bytes.set(999);
        assert_eq!(adm.check_register(), Admit::Ok);
        assert_eq!(m.admission_rejects.get(), 0);
    }

    #[test]
    fn rejects_at_memory_critical() {
        let (adm, m, _) = setup(1000, 10);
        m.dt_buffered_bytes.set(1000);
        assert!(matches!(adm.check_register(), Admit::RejectMemory { buffered: 1000, .. }));
        assert_eq!(m.admission_rejects.get(), 1);
    }

    #[test]
    fn overrun_burst_rejects_then_readmits() {
        let (adm, m, _) = setup(1 << 30, 10); // memory gate never fires
        // default limit is small but nonzero; drive a burst past it
        let limit = GetBatchConfig::default().budget_overrun_limit as u64;
        assert!(limit > 0, "overrun gate enabled by default");
        m.budget_overruns.add(limit);
        assert!(matches!(adm.check_register(), Admit::RejectOverrun { .. }));
        assert_eq!(m.admission_rejects.get(), 1);
        // burst consumed: the next registration is admitted again
        assert_eq!(adm.check_register(), Admit::Ok);
        // below-limit trickle never rejects
        m.budget_overruns.add(limit - 1);
        assert_eq!(adm.check_register(), Admit::Ok);
    }

    #[test]
    fn overrun_gate_disabled_at_zero_limit() {
        let metrics = GetBatchMetrics::new();
        let cfg = GetBatchConfig {
            mem_critical_bytes: 1 << 30,
            budget_overrun_limit: 0,
            ..Default::default()
        };
        let adm = Admission::new(cfg, Arc::clone(&metrics), VirtualClock::new());
        metrics.budget_overruns.add(1_000);
        assert_eq!(adm.check_register(), Admit::Ok);
    }

    #[test]
    fn configurable_patience_and_recovery_reservation() {
        // Patience flows from the constructor (producer side)...
        let b = MemoryBudget::with_patience(10, 2, Duration::from_millis(30), None);
        assert_eq!(b.patience(), Duration::from_millis(30));
        assert!(b.try_reserve(8)); // cap reached
        // ...but recovery never pays patience per chunk: it takes the
        // head-of-line exemption after a brief grace, and that is NOT an
        // overrun — the blocking bytes may be this very request's later
        // slots, which only drain once recovery finishes.
        let t0 = Instant::now();
        b.reserve_for_recovery(4);
        assert!(t0.elapsed() < Duration::from_secs(2), "no patience-long stall");
        assert_eq!(b.used(), 12);
        assert_eq!(b.overruns(), 0, "head-of-line exemption, not an overrun");
        b.release(12);
        // with room available the reservation is immediate and clean
        b.reserve_for_recovery(4);
        assert_eq!(b.used(), 4);
        assert_eq!(b.overruns(), 0);
    }

    #[test]
    fn no_throttle_below_watermark() {
        let (adm, m, clock) = setup(1 << 30, 10);
        assert_eq!(adm.throttle(10), Duration::ZERO);
        assert_eq!(clock.now_ns(), 0);
        assert_eq!(m.throttle_ns.get(), 0);
    }

    #[test]
    fn throttle_scales_with_overload() {
        let (adm, m, _clock) = setup(1 << 30, 10);
        let d1 = adm.throttle(11); // 1 over
        let d5 = adm.throttle(15); // 5 over
        assert_eq!(d1, Duration::from_micros(100));
        assert_eq!(d5, Duration::from_micros(500));
        assert_eq!(m.throttle_ns.get(), (d1 + d5).as_nanos() as u64);
    }

    #[test]
    fn throttle_capped() {
        let (adm, _, _) = setup(1 << 30, 0);
        assert_eq!(adm.throttle(1_000_000), Duration::from_millis(50));
    }

    #[test]
    fn budget_cap_leaves_headroom_for_exempt_chunk() {
        let b = MemoryBudget::new(100, 30, None);
        // cap = 70: normal admissions stop there...
        assert!(b.try_reserve(70));
        assert!(!b.try_reserve(1));
        // ...so one exempt chunk (≤ 30) can never push past the budget.
        b.force_reserve(30, false);
        assert_eq!(b.used(), 100);
        assert!(b.peak() <= b.budget());
        b.release(100);
        assert_eq!(b.used(), 0);
        assert_eq!(b.peak(), 100, "peak is a high-water mark");
        assert_eq!(b.overruns(), 0);
    }

    #[test]
    fn budget_tracks_overruns_and_gauge() {
        let metrics = GetBatchMetrics::new();
        let b = MemoryBudget::new(64, 16, Some(Arc::clone(&metrics)));
        assert!(b.try_reserve(40));
        assert_eq!(metrics.dt_buffered_bytes.get(), 40);
        b.force_reserve(10, true);
        assert_eq!(b.overruns(), 1);
        assert_eq!(metrics.budget_overruns.get(), 1);
        b.release(50);
        assert_eq!(metrics.dt_buffered_bytes.get(), 0);
    }

    #[test]
    fn virtual_budget_waits_advance_time_instead_of_parking() {
        let clock = VirtualClock::new();
        let b = MemoryBudget::with_clock(10, 2, Duration::from_millis(30), None, clock.clone());
        assert!(b.try_reserve(8)); // cap reached
        assert!(!b.has_room(1));
        let t0 = Instant::now();
        let deadline = b.now_ns() + 30_000_000;
        let mut slices = 0;
        while b.wait_room_until_ns(deadline) {
            slices += 1;
            assert!(slices < 1000, "must terminate");
        }
        assert!(slices >= 5, "30 ms of patience in 5 ms virtual slices, saw {slices}");
        assert_eq!(clock.now_ns(), 30_000_000, "waits advanced the virtual clock");
        assert!(t0.elapsed() < Duration::from_secs(1), "no real-time parking");
        b.release(8);
        assert!(b.has_room(2));
    }

    #[test]
    fn virtual_budget_recovery_reservation_is_instant_in_real_time() {
        let clock = VirtualClock::new();
        let b = MemoryBudget::with_clock(10, 2, Duration::from_secs(3600), None, clock.clone());
        assert!(b.try_reserve(8)); // saturated
        b.reserve_for_recovery(4); // 50 ms virtual grace, then exemption
        assert_eq!(b.used(), 12);
        assert_eq!(b.overruns(), 0, "recovery exemption is not an overrun");
        assert!(clock.now_ns() >= 50_000_000, "grace elapsed virtually");
    }

    #[test]
    fn budget_wait_room_respects_deadline() {
        let b = MemoryBudget::new(10, 2, None);
        assert!(b.try_reserve(8)); // cap reached
        let deadline = Instant::now() + Duration::from_millis(25);
        let mut waited = 0;
        while b.wait_room_until(deadline) {
            waited += 1;
            assert!(waited < 1000, "must terminate");
        }
        assert!(Instant::now() >= deadline);
    }

    #[test]
    fn oversized_reservation_rejected_not_wrapped() {
        let b = MemoryBudget::new(100, 10, None);
        // u64::MAX used to wrap `used + bytes` in release builds and falsely
        // admit an unbounded reservation (panic in debug).
        assert!(!b.has_room(u64::MAX));
        assert!(!b.try_reserve(u64::MAX));
        assert_eq!(b.used(), 0);
        // a single reservation larger than the whole budget is nonsense
        assert!(!b.try_reserve(101));
        assert!(b.try_reserve(50));
        // overflow with nonzero `used` (the original wrap site)
        assert!(!b.has_room(u64::MAX - 10));
        assert!(!b.try_reserve(u64::MAX - 10));
        assert_eq!(b.used(), 50);
    }

    #[test]
    fn overrun_watermark_never_rewinds_under_concurrent_registrations() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let (adm, m, _) = setup(1 << 30, 10);
        let limit = GetBatchConfig::default().budget_overrun_limit as u64;
        assert!(limit > 0);
        let adm = Arc::new(adm);
        let stop = Arc::new(AtomicBool::new(false));
        let total_overruns = 20_000u64;
        let adder = {
            let m = Arc::clone(&m);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for _ in 0..total_overruns {
                    m.budget_overruns.inc();
                }
                stop.store(true, Ordering::Release);
            })
        };
        let checkers: Vec<_> = (0..4)
            .map(|_| {
                let adm = Arc::clone(&adm);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut consumed = 0u64;
                    while !stop.load(Ordering::Acquire) {
                        if let Admit::RejectOverrun { overruns, .. } = adm.check_register() {
                            consumed += overruns;
                        }
                    }
                    consumed
                })
            })
            .collect();
        adder.join().unwrap();
        let consumed: u64 = checkers.into_iter().map(|h| h.join().unwrap()).sum();
        // A monotone watermark (fetch_max) hands each overrun delta to at
        // most one registration; the racy swap could rewind the watermark
        // and count the same burst twice, pushing `consumed` past the true
        // total and 429ing healthy registrations.
        assert!(consumed <= total_overruns, "burst double-counted: {consumed} > {total_overruns}");
        // gate settles: consume the residue, then a below-limit trickle is Ok
        let _ = adm.check_register();
        m.budget_overruns.add(limit - 1);
        assert_eq!(adm.check_register(), Admit::Ok);
    }

    #[test]
    fn throttle_survives_extreme_inflight() {
        // i64::MAX inflight with a pathological base: the old unchecked
        // `Duration * u32` panicked on overflow. Now it falls back to the cap.
        let metrics = GetBatchMetrics::new();
        let cfg = GetBatchConfig {
            throttle_watermark: 64,
            throttle_base: Duration::from_secs(1 << 40),
            ..Default::default()
        };
        let adm = Admission::new(cfg, Arc::clone(&metrics), VirtualClock::new());
        assert_eq!(adm.throttle(i64::MAX), Duration::from_millis(50));
        // and the old `as u32` truncation can no longer wrap `over` to 0
        // (watermark + 2^32 over used to throttle not at all)
        let (adm2, _, _) = setup(1 << 30, 64);
        assert_eq!(adm2.throttle(64 + (1i64 << 32)), Duration::from_millis(50));
    }

    #[test]
    fn shed_order_is_lowest_class_first() {
        let (adm, m, _) = setup(1000, 10);
        m.dt_buffered_bytes.set(600); // past bulk's 1/2, under batch's 3/4
        assert!(matches!(adm.check_register_class(Priority::Bulk), Admit::RejectMemory { .. }));
        assert_eq!(adm.check_register_class(Priority::Batch), Admit::Ok);
        assert_eq!(adm.check_register_class(Priority::Interactive), Admit::Ok);
        m.dt_buffered_bytes.set(800); // past batch's 3/4, under critical
        assert!(matches!(adm.check_register_class(Priority::Batch), Admit::RejectMemory { .. }));
        assert_eq!(adm.check_register_class(Priority::Interactive), Admit::Ok);
        m.dt_buffered_bytes.set(1000); // critical: everyone sheds
        assert!(matches!(
            adm.check_register_class(Priority::Interactive),
            Admit::RejectMemory { .. }
        ));
    }

    #[test]
    fn priority_parse_order_and_backoff() {
        assert_eq!(Priority::parse("interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse("batch"), Some(Priority::Batch));
        assert_eq!(Priority::parse("bulk"), Some(Priority::Bulk));
        assert_eq!(Priority::parse("turbo"), None);
        assert!(Priority::Bulk < Priority::Batch && Priority::Batch < Priority::Interactive);
        assert!(Priority::Bulk.backoff_factor() > Priority::Batch.backoff_factor());
        assert!(Priority::Batch.backoff_factor() > Priority::Interactive.backoff_factor());
        assert_eq!(Priority::parse(Priority::Bulk.as_str()), Some(Priority::Bulk));
    }

    #[test]
    fn sole_tenant_gets_the_whole_budget() {
        let ledger = TenantLedger::new(100, 10, BTreeMap::new(), None);
        let h = ledger.handle("a");
        assert_eq!(ledger.share("a"), 90, "cap = budget - chunk");
        assert!(h.try_charge(90));
        assert!(!h.try_charge(1));
        h.uncharge(90);
        assert_eq!(ledger.used("a"), 0);
    }

    #[test]
    fn active_tenant_share_is_not_borrowable() {
        let ledger = TenantLedger::new(1000, 10, BTreeMap::new(), None);
        let hog = ledger.handle("hog");
        let steady = ledger.handle("steady");
        // equal weights, two active tenants: each share is cap/2
        assert_eq!(ledger.share("hog"), 495);
        assert!(hog.try_charge(495));
        assert!(!hog.try_charge(1), "steady is active: its share is reserved");
        assert!(steady.try_charge(400), "the reserved room is really there");
        steady.uncharge(400);
        drop(steady); // steady goes idle...
        assert!(hog.try_charge(200), "...and its share becomes borrowable");
        hog.uncharge(695);
    }

    #[test]
    fn weighted_shares_follow_config() {
        let mut w = BTreeMap::new();
        w.insert("gold".to_string(), 3);
        w.insert("bronze".to_string(), 1);
        let ledger = TenantLedger::new(4000, 10, w, None);
        let _g = ledger.handle("gold");
        let _b = ledger.handle("bronze");
        // cap = 3990: gold gets 3/4 of it, bronze 1/4
        assert_eq!(ledger.share("gold"), 2992);
        assert_eq!(ledger.share("bronze"), 997);
    }

    #[test]
    fn forced_charges_keep_residency_exact() {
        let ledger = TenantLedger::new(100, 10, BTreeMap::new(), None);
        let h = ledger.handle("t");
        h.force_charge(95); // exemption path bypasses the gate...
        assert_eq!(ledger.used("t"), 95);
        assert!(!h.try_charge(1), "...but still counts against the share");
        assert!(!ledger.would_admit("t", 1));
        h.uncharge(95);
        assert_eq!(ledger.used("t"), 0);
    }
}
