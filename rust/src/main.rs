//! `getbatch` CLI — the launcher for the GetBatch reproduction.
//!
//! Subcommands:
//!   serve     boot a live in-process cluster and keep it running
//!   put/get   object I/O against a running cluster (`--proxy host:port`)
//!   getbatch  batched retrieval of named objects
//!   bench     aisloader-style throughput run on a fresh local cluster
//!   sim       paper-scale simulator (Table 1 / Table 2 rows)
//!   train     end-to-end training demo (AOT artifacts required)
//!   metrics   scrape a node's Prometheus exposition

use std::io::Write as _;
use std::time::Duration;

use getbatch::aisloader::{self, LoadSpec};
use getbatch::batch::request::{BatchEntry, BatchRequest};
use getbatch::client::loader::{AccessMode, DataLoader};
use getbatch::client::sdk::Client;
use getbatch::cluster::node::Cluster;
use getbatch::config::ClusterConfig;
use getbatch::sim::model::CostModel;
use getbatch::sim::workload;
use getbatch::testutil::fixtures;
use getbatch::util::cli::Args;
use getbatch::util::error as anyhow;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("put") => put(&args),
        Some("get") => get(&args),
        Some("getbatch") => getbatch(&args),
        Some("bench") => bench(&args),
        Some("sim") => sim(&args),
        Some("train") => train(&args),
        Some("metrics") => metrics(&args),
        _ => {
            eprintln!(
                "usage: getbatch <serve|put|get|getbatch|bench|sim|train|metrics> [--flags]\n\
                 see README.md for examples"
            );
            Ok(())
        }
    }
}

fn cluster_from(args: &Args) -> anyhow::Result<Cluster> {
    let cfg = ClusterConfig {
        targets: args.usize_or("targets", 4),
        proxies: args.usize_or("proxies", 1),
        mountpaths: args.usize_or("mountpaths", 2),
        http_workers: args.usize_or("http-workers", 8),
        root_dir: args.str_or("root", ""),
        ..Default::default()
    };
    Ok(Cluster::start(cfg)?)
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let c = cluster_from(args)?;
    println!("proxy: {}", c.proxy_addr());
    for t in &c.targets {
        println!("target {}: http={} p2p={}", t.info.id, t.info.http_addr, t.info.p2p_addr);
    }
    println!("serving; ctrl-c to stop");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn put(args: &Args) -> anyhow::Result<()> {
    let client = Client::new(&args.str_or("proxy", "127.0.0.1:8080"));
    let bucket = args.str_or("bucket", "data");
    let obj = args.positional.first().cloned().ok_or_else(|| anyhow::anyhow!("object name"))?;
    let file = args.str("file").ok_or_else(|| anyhow::anyhow!("--file required"))?;
    let data = std::fs::read(file)?;
    client.put(&bucket, &obj, &data).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("put {bucket}/{obj} ({} bytes)", data.len());
    Ok(())
}

fn get(args: &Args) -> anyhow::Result<()> {
    let client = Client::new(&args.str_or("proxy", "127.0.0.1:8080"));
    let bucket = args.str_or("bucket", "data");
    let obj = args.positional.first().cloned().ok_or_else(|| anyhow::anyhow!("object name"))?;
    let data = client.get(&bucket, &obj).map_err(|e| anyhow::anyhow!("{e}"))?;
    std::io::stdout().write_all(&data)?;
    Ok(())
}

fn getbatch(args: &Args) -> anyhow::Result<()> {
    let client = Client::new(&args.str_or("proxy", "127.0.0.1:8080"));
    let bucket = args.str_or("bucket", "data");
    let entries: Vec<BatchEntry> =
        args.positional.iter().map(|o| BatchEntry::obj(&bucket, o)).collect();
    anyhow::ensure!(!entries.is_empty(), "list object names as positional args");
    let req = BatchRequest::new(entries)
        .continue_on_err(args.bool("coer"))
        .colocation(args.bool("coloc"))
        .streaming(!args.bool("no-strm"));
    let (items, stats) = client.get_batch_timed(&req).map_err(|e| anyhow::anyhow!("{e}"))?;
    for it in &items {
        eprintln!(
            "{} {}",
            it.name(),
            it.data().map(|d| format!("{} bytes", d.len())).unwrap_or("<missing>".into())
        );
    }
    eprintln!(
        "batch: {} items, {} bytes, total {:.1} ms, ttfb {:.1} ms",
        stats.items,
        stats.bytes,
        stats.total.as_secs_f64() * 1e3,
        stats.ttfb.as_secs_f64() * 1e3
    );
    Ok(())
}

fn bench(args: &Args) -> anyhow::Result<()> {
    let c = cluster_from(args)?;
    let spec = LoadSpec {
        object_size: args.size_or("size", 10 << 10),
        batch: args.str("batch").and_then(|b| b.parse().ok()),
        workers: args.usize_or("workers", 8),
        duration: Duration::from_millis(args.u64_or("ms", 2000)),
        num_objects: args.usize_or("objects", 512),
        seed: args.u64_or("seed", 1),
        coloc: args.bool("coloc"),
        no_reuse: args.bool("no-reuse"),
    };
    eprintln!("staging {} objects of {} ...", spec.num_objects, spec.object_size);
    aisloader::stage_uniform(&c, "bench", &spec);
    let r = aisloader::run(&c, "bench", &spec);
    println!(
        "{:<24} {:>8.3} GiB/s {:>10.0} obj/s   lat {}   errors={}",
        r.label,
        r.throughput.gib_per_sec(),
        r.throughput.ops_per_sec(),
        r.request_ms,
        r.errors
    );
    Ok(())
}

fn sim(args: &Args) -> anyhow::Result<()> {
    let m = CostModel::oci_16node();
    match args.str_or("table", "1").as_str() {
        "1" => {
            let secs = args.f64_or("secs", 5.0);
            println!("Simulated Table 1 (16-node OCI model, 80 workers, {secs}s virtual):");
            for size in [10 << 10, 100 << 10, 1 << 20] {
                let get = workload::run_synthetic(&m, 80, size, None, secs, 1);
                print!(
                    "{:>8}  GET {:>6.2} GiB/s |",
                    getbatch::util::bytes::fmt_size(size),
                    get.throughput.gib_per_sec()
                );
                for k in [32, 64, 128] {
                    let b = workload::run_synthetic(&m, 80, size, Some(k), secs, k as u64);
                    print!(
                        "  B{k}: {:>6.2} GiB/s ({:.1}x)",
                        b.throughput.gib_per_sec(),
                        b.throughput.gib_per_sec() / get.throughput.gib_per_sec()
                    );
                }
                println!();
            }
        }
        "2" => {
            println!("Simulated Table 2 (256 loaders, bursty):");
            for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
                let r = workload::run_training(&m, mode, 256, 128, 8, 120.0, 7);
                println!("{:<16} batch {}  per-obj {}", r.mode.name(), r.batch_ms, r.per_object_ms);
            }
        }
        other => anyhow::bail!("unknown table {other}"),
    }
    Ok(())
}

fn train(args: &Args) -> anyhow::Result<()> {
    let dir = getbatch::runtime::trainer::artifacts_dir()?;
    let rt = getbatch::runtime::pjrt::Runtime::load(&dir)?;
    eprintln!("runtime: {} ({} params)", rt.platform(), rt.meta.n_params);
    let c = cluster_from(args)?;
    let manifest = fixtures::stage_shards(&c, "corpus", 8, 32, 2048.0, 11);
    let mode = AccessMode::parse(&args.str_or("mode", "getbatch"))
        .ok_or_else(|| anyhow::anyhow!("mode: seq|get|getbatch"))?;
    let mut loader =
        DataLoader::new(Client::new(&c.proxy_addr()), manifest, mode, rt.meta.batch, 5);
    let steps = args.usize_or("steps", 50);
    let report = getbatch::runtime::trainer::train(&rt, &mut loader, steps, 0)?;
    println!(
        "{}: {} steps, loss {:.3} -> {:.3}, load {} | step {}",
        report.mode,
        steps,
        report.losses.first().unwrap_or(&f32::NAN),
        getbatch::runtime::trainer::final_loss(&report.losses, 10),
        report.load_ms,
        report.step_ms
    );
    Ok(())
}

fn metrics(args: &Args) -> anyhow::Result<()> {
    let proxy = args.str_or("proxy", "127.0.0.1:8080");
    let client = Client::new(&proxy);
    print!("{}", client.metrics(&proxy).map_err(|e| anyhow::anyhow!("{e}"))?);
    Ok(())
}
