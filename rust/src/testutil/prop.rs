//! Mini property-testing framework (no `proptest` offline): seeded
//! generators + a runner that, on failure, retries with simple shrinking
//! (halving sizes) and reports the seed for reproduction.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0x9e3779b97f4a7c15, max_shrink: 64 }
    }
}

/// A sized generator: given an RNG and a size budget, produce a value.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng, size: usize) -> T;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Run `prop` over `cases` generated inputs. On failure, retry the same
/// case seed at smaller sizes to find a smaller witness, then panic with
/// the reproducing (seed, size).
pub fn check<T: std::fmt::Debug>(
    cfg: PropConfig,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let size = 2 + case * 4;
        let mut rng = Rng::new(case_seed);
        let input = gen.generate(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink: same seed, smaller sizes.
            let mut best: (usize, String, String) = (size, msg, format!("{input:?}"));
            let mut s = size / 2;
            let mut budget = cfg.max_shrink;
            while s >= 1 && budget > 0 {
                budget -= 1;
                let mut rng = Rng::new(case_seed);
                let smaller = gen.generate(&mut rng, s);
                if let Err(m) = prop(&smaller) {
                    best = (s, m, format!("{smaller:?}"));
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property failed (seed={case_seed:#x}, size={}): {}\ninput: {}",
                best.0, best.1, best.2
            );
        }
    }
}

// ---- common generators -----------------------------------------------------

/// Vec<u8> with length up to `size`.
pub fn bytes_gen(rng: &mut Rng, size: usize) -> Vec<u8> {
    let n = rng.usize_below(size.max(1) + 1);
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

/// Printable-ish object name.
pub fn name_gen(rng: &mut Rng, size: usize) -> String {
    let n = 1 + rng.usize_below(size.clamp(1, 60));
    (0..n)
        .map(|_| {
            let alphabet = b"abcdefghijklmnopqrstuvwxyz0123456789-_./";
            alphabet[rng.usize_below(alphabet.len())] as char
        })
        .collect::<String>()
        .trim_matches('/')
        .replace("//", "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check(
            PropConfig { cases: 10, ..Default::default() },
            |rng: &mut Rng, size: usize| bytes_gen(rng, size),
            |v| {
                counter.set(counter.get() + 1);
                if v.len() <= 10_000 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            PropConfig { cases: 5, ..Default::default() },
            |_rng: &mut Rng, size: usize| size,
            |&s| if s < 3 { Ok(()) } else { Err(format!("size {s} >= 3")) },
        );
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        assert_eq!(bytes_gen(&mut a, 50), bytes_gen(&mut b, 50));
        let mut a = Rng::new(10);
        let mut b = Rng::new(10);
        assert_eq!(name_gen(&mut a, 20), name_gen(&mut b, 20));
    }
}
