//! Test utilities: mini property-testing framework + cluster fixtures.
pub mod prop;
pub mod fixtures;
