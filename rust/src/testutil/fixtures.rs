//! Shared fixtures for integration tests, benches and examples: cluster
//! construction and synthetic dataset staging (standalone objects and TAR
//! shards with variable "audio-like" sample sizes).

use crate::client::loader::{Manifest, SampleRef};
use crate::cluster::node::Cluster;
use crate::config::ClusterConfig;
use crate::tar::{write_archive, Entry};
use crate::util::rng::Rng;

/// A small live cluster for tests: `targets` targets, 1 proxy.
pub fn cluster(targets: usize) -> Cluster {
    Cluster::start(ClusterConfig { targets, http_workers: 8, ..Default::default() })
        .expect("cluster start")
}

/// Like [`cluster`] but with a custom GetBatch section — used by the
/// memory-budget / chunk-size scenarios (tests and benches).
pub fn cluster_cfg(targets: usize, getbatch: crate::config::GetBatchConfig) -> Cluster {
    Cluster::start(ClusterConfig { targets, http_workers: 8, getbatch, ..Default::default() })
        .expect("cluster start")
}

/// Stage `n` standalone objects of fixed `size` in `bucket`; returns names.
pub fn stage_objects(c: &Cluster, bucket: &str, n: usize, size: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    let mut names = Vec::with_capacity(n);
    let mut buf = vec![0u8; size];
    for i in 0..n {
        rng.fill_bytes(&mut buf);
        let name = format!("obj-{i:06}");
        c.put_direct(bucket, &name, &buf).expect("put");
        names.push(name);
    }
    names
}

/// Stage a sharded dataset with log-normal sample sizes (speech-segment
/// like, §4.1) and return its manifest. `median` bytes, sigma 0.6.
pub fn stage_shards(
    c: &Cluster,
    bucket: &str,
    n_shards: usize,
    per_shard: usize,
    median: f64,
    seed: u64,
) -> Manifest {
    let mut rng = Rng::new(seed);
    let mut manifest = Manifest::default();
    for s in 0..n_shards {
        let entries: Vec<Entry> = (0..per_shard)
            .map(|i| {
                let len = rng.lognormal(median, 0.6).clamp(64.0, 4.0 * median) as usize;
                let mut data = vec![0u8; len];
                rng.fill_bytes(&mut data);
                Entry { name: format!("utt-{s:04}-{i:04}.wav"), data }
            })
            .collect();
        let shard_name = format!("shards/s-{s:05}.tar");
        c.put_direct(bucket, &shard_name, &write_archive(&entries).expect("tar")).expect("put");
        for e in &entries {
            manifest.samples.push(SampleRef {
                bucket: bucket.to_string(),
                shard: Some(shard_name.clone()),
                name: e.name.clone(),
                size: e.data.len() as u64,
            });
        }
    }
    manifest
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_stage_consistently() {
        let c = cluster(2);
        let names = stage_objects(&c, "b", 8, 512, 3);
        assert_eq!(names.len(), 8);
        let m = stage_shards(&c, "audio", 2, 5, 4096.0, 4);
        assert_eq!(m.len(), 10);
        assert_eq!(m.shards().len(), 2);
        // sizes vary (log-normal)
        let sizes: Vec<u64> = m.samples.iter().map(|s| s.size).collect();
        assert!(sizes.iter().max() != sizes.iter().min());
    }
}
