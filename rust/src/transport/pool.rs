//! Peer connection pool + P2P frame server.
//!
//! Senders check a connection out of the pool, write a burst of frames, and
//! check it back in — exclusive use while checked out, so frames of
//! concurrent requests never interleave on one socket. Idle connections are
//! reclaimed after `idle_timeout`, amortizing TCP setup across requests and
//! avoiding connection storms under concurrent load (§2.3.1).
//!
//! Stale-connection handling: a pooled connection may have been closed by
//! the peer since its last use (peer restart, idle reclaim on the far
//! side). Checkout probes pooled sockets (non-blocking peek: a received FIN
//! reads as EOF) and drops dead ones, and `send`/`send_iter` additionally
//! retry once on a freshly established connection when a pooled socket
//! fails mid-handshake — closing the FIN-in-flight race window.

use std::collections::HashMap;
use std::io::{self, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::proto::frame::{self, Frame};

struct IdleConn {
    stream: TcpStream,
    since: Instant,
}

/// `true` iff a pooled connection is still usable: no FIN received and no
/// unexpected inbound bytes (the frame protocol is strictly one-way).
fn conn_alive(s: &TcpStream) -> bool {
    if s.set_nonblocking(true).is_err() {
        return false;
    }
    let mut probe = [0u8; 1];
    let alive = match s.peek(&mut probe) {
        Ok(0) => false,                                           // peer closed
        Ok(_) => false,                                           // protocol violation
        Err(e) if e.kind() == io::ErrorKind::WouldBlock => true,  // healthy idle
        Err(_) => false,
    };
    s.set_nonblocking(false).is_ok() && alive
}

/// Sender-side pool of persistent peer connections.
pub struct PeerPool {
    idle: Mutex<HashMap<String, Vec<IdleConn>>>,
    idle_timeout: Duration,
    max_per_peer: usize,
    /// Connections established (visible to the A3 pooling ablation).
    pub established: AtomicU64,
    /// When true, checkin drops the connection instead of pooling —
    /// models per-request connection setup for the ablation.
    pub disable_reuse: AtomicBool,
}

impl PeerPool {
    pub fn new(idle_timeout: Duration) -> Arc<PeerPool> {
        Arc::new(PeerPool {
            idle: Mutex::new(HashMap::new()),
            idle_timeout,
            max_per_peer: 16,
            established: AtomicU64::new(0),
            disable_reuse: AtomicBool::new(false),
        })
    }

    fn connect_fresh(&self, addr: &str) -> io::Result<TcpStream> {
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        self.established.fetch_add(1, Ordering::Relaxed);
        Ok(s)
    }

    /// Returns (stream, came_from_pool). Pooled candidates are probed for
    /// liveness; stale/dead ones are discarded.
    fn checkout(&self, addr: &str) -> io::Result<(TcpStream, bool)> {
        if !self.disable_reuse.load(Ordering::Relaxed) {
            let mut idle = self.idle.lock().unwrap();
            if let Some(v) = idle.get_mut(addr) {
                while let Some(c) = v.pop() {
                    if c.since.elapsed() < self.idle_timeout && conn_alive(&c.stream) {
                        return Ok((c.stream, true));
                    }
                    // stale or dead: drop (reclaim)
                }
            }
        }
        Ok((self.connect_fresh(addr)?, false))
    }

    fn checkin(&self, addr: &str, stream: TcpStream) {
        if self.disable_reuse.load(Ordering::Relaxed) {
            return; // drop ⇒ close
        }
        let mut idle = self.idle.lock().unwrap();
        let v = idle.entry(addr.to_string()).or_default();
        if v.len() < self.max_per_peer {
            v.push(IdleConn { stream, since: Instant::now() });
        }
    }

    /// Write a burst of frames to `addr` on one pooled connection. A dead
    /// pooled socket is replaced by a fresh connection, but only while
    /// nothing of this burst has been delivered — frames are not idempotent
    /// (a duplicated SENDER_DONE would double-count fan-in completion), so
    /// a mid-burst failure is surfaced instead of blindly resent; the DT's
    /// sender-wait + GFN ladder owns recovery from partial bursts.
    /// The encode buffer is reused across frames (hot path).
    pub fn send(&self, addr: &str, frames: &[Frame]) -> io::Result<()> {
        let (mut stream, mut from_pool) = self.checkout(addr)?;
        let mut scratch = Vec::with_capacity(64 * 1024);
        let mut sent_any = false;
        for f in frames {
            frame::encode_into(f, &mut scratch);
            match stream.write_all(&scratch) {
                Ok(()) => {}
                Err(e) => {
                    if sent_any || !from_pool {
                        return Err(e);
                    }
                    // Stale pooled socket caught on the first write: retry
                    // the same frame on a fresh connection.
                    stream = self.connect_fresh(addr)?;
                    from_pool = false;
                    stream.write_all(&scratch)?;
                }
            }
            sent_any = true;
        }
        self.checkin(addr, stream);
        Ok(())
    }

    /// Send frames produced lazily, transmitting each as soon as it's
    /// encoded — lets a sender overlap disk reads with transmission. A dead
    /// pooled connection is replaced by a fresh one if the failure hits
    /// before anything was delivered (after that, recovery is the DT's
    /// job — sender-wait timeout + GFN).
    pub fn send_iter(
        &self,
        addr: &str,
        frames: impl Iterator<Item = Frame>,
    ) -> io::Result<()> {
        let (mut stream, mut from_pool) = self.checkout(addr)?;
        let mut scratch = Vec::with_capacity(64 * 1024);
        let mut sent_any = false;
        for f in frames {
            frame::encode_into(&f, &mut scratch);
            match stream.write_all(&scratch) {
                Ok(()) => {}
                Err(e) => {
                    if sent_any || !from_pool {
                        return Err(e);
                    }
                    // Stale pooled socket detected on first write: retry the
                    // same frame on a fresh connection.
                    stream = self.connect_fresh(addr)?;
                    from_pool = false;
                    stream.write_all(&scratch)?;
                }
            }
            sent_any = true;
        }
        self.checkin(addr, stream);
        Ok(())
    }

    /// Lending variant of [`PeerPool::send_iter`] for the sender hot loop:
    /// `fill` appends the next frame's wire payload into the reusable
    /// buffer (cleared between frames) and returns its head, or `None` to
    /// end the burst — one payload allocation and one encode buffer serve
    /// every chunk frame, instead of a fresh `Vec` per chunk. Stale-pool
    /// handling mirrors `send_iter`: a dead pooled socket is replaced only
    /// while nothing of the burst has been delivered.
    pub fn send_stream(
        &self,
        addr: &str,
        mut fill: impl FnMut(&mut Vec<u8>) -> Option<frame::FrameHead>,
    ) -> io::Result<()> {
        let (mut stream, mut from_pool) = self.checkout(addr)?;
        let mut payload = Vec::with_capacity(64 * 1024);
        let mut scratch = Vec::with_capacity(64 * 1024);
        let mut sent_any = false;
        loop {
            payload.clear();
            let head = match fill(&mut payload) {
                Some(h) => h,
                None => break,
            };
            frame::encode_head_into(head, &payload, &mut scratch);
            match stream.write_all(&scratch) {
                Ok(()) => {}
                Err(e) => {
                    if sent_any || !from_pool {
                        return Err(e);
                    }
                    // Stale pooled socket detected on first write: retry the
                    // same frame on a fresh connection.
                    stream = self.connect_fresh(addr)?;
                    from_pool = false;
                    stream.write_all(&scratch)?;
                }
            }
            sent_any = true;
        }
        self.checkin(addr, stream);
        Ok(())
    }

    /// Reap idle connections past the timeout (called opportunistically).
    pub fn reap(&self) {
        let mut idle = self.idle.lock().unwrap();
        for v in idle.values_mut() {
            v.retain(|c| c.since.elapsed() < self.idle_timeout);
        }
        idle.retain(|_, v| !v.is_empty());
    }

    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().values().map(|v| v.len()).sum()
    }
}

/// Socket reader that retries short poll timeouts internally, so a frame
/// read can never desynchronize mid-frame: the 200 ms socket timeout is a
/// shutdown-poll interval, not a protocol deadline. (Previously a timeout
/// between the header's first byte and its tail made the reader restart at
/// the wrong offset — BadMagic — and drop the connection.)
struct PatientReader {
    stream: TcpStream,
    stop: Arc<AtomicBool>,
}

impl Read for PatientReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        loop {
            match self.stream.read(buf) {
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    if self.stop.load(Ordering::Relaxed) {
                        return Err(e); // shutdown requested
                    }
                }
                r => return r,
            }
        }
    }
}

/// Receiver side: accepts peer connections and dispatches every incoming
/// frame to the handler (the DT registry). One reader thread per peer
/// connection — connections are few (pooled) and long-lived. The handler
/// may block (memory-budget backpressure): the stalled reader thread stops
/// draining the socket and TCP flow control pushes back on the sender.
pub struct P2pServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

pub type FrameHandler = Arc<dyn Fn(Frame) + Send + Sync>;

impl P2pServer {
    pub fn serve(handler: FrameHandler, name: &str) -> io::Result<P2pServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let name = name.to_string();
        let accept_thread = std::thread::Builder::new()
            .name(format!("{name}-p2p"))
            .spawn(move || {
                let mut conns: Vec<JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = Arc::clone(&handler);
                            let stop3 = Arc::clone(&stop2);
                            conns.push(std::thread::spawn(move || {
                                let _ = stream.set_nodelay(true);
                                // Poll interval so idle connections notice
                                // shutdown; PatientReader retries these
                                // timeouts, keeping frame reads atomic.
                                let _ = stream
                                    .set_read_timeout(Some(Duration::from_millis(200)));
                                let mut r = BufReader::with_capacity(
                                    256 * 1024,
                                    PatientReader { stream, stop: stop3 },
                                );
                                loop {
                                    match frame::read_frame(&mut r) {
                                        Ok(Some(f)) => h(f),
                                        Ok(None) => break, // peer closed
                                        Err(_) => break,   // shutdown or corrupt stream
                                    }
                                }
                            }));
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(P2pServer { addr, stop, accept_thread: Some(accept_thread) })
    }
}

impl Drop for P2pServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::frame::read_frame;
    use std::sync::mpsc;

    fn collector() -> (P2pServer, mpsc::Receiver<Frame>) {
        let (tx, rx) = mpsc::channel();
        let tx = Mutex::new(tx);
        let srv = P2pServer::serve(
            Arc::new(move |f| {
                let _ = tx.lock().unwrap().send(f);
            }),
            "test",
        )
        .unwrap();
        (srv, rx)
    }

    #[test]
    fn frames_arrive() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        pool.send(
            &addr,
            &[
                Frame::data(1, 0, vec![1, 2, 3]),
                Frame::soft_err(1, 1, "missing"),
                Frame::sender_done(1, 1),
            ],
        )
        .unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        }
        assert_eq!(got[0].payload, vec![1, 2, 3]);
        assert_eq!(got[2].index, 1);
    }

    #[test]
    fn chunked_frames_arrive_in_order() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i % 233) as u8).collect();
        let frames = frame::chunk_frames(4, 2, payload.clone(), 1 << 10);
        assert!(frames.len() > 2, "multi-chunk");
        pool.send_iter(&addr, frames.into_iter()).unwrap();
        let mut rebuilt = Vec::new();
        loop {
            let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            let (total, bytes) = f.chunk_parts().unwrap();
            if f.is_first() {
                assert_eq!(total, payload.len() as u64);
            }
            rebuilt.extend_from_slice(bytes);
            if f.is_last() {
                break;
            }
        }
        assert_eq!(rebuilt, payload);
    }

    #[test]
    fn send_stream_delivers_borrowed_frames() {
        // The lending path must be wire-identical to owned frames: a
        // 3-chunk entry produced into one reused payload buffer arrives
        // reassemblable and in order, followed by SENDER_DONE.
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let payload: Vec<u8> = (0..3000u32).map(|i| (i % 239) as u8).collect();
        let chunk = 1024usize;
        let total = payload.len() as u64;
        let mut off = 0usize;
        let mut done = false;
        pool.send_stream(&addr, |buf| {
            if done {
                return None;
            }
            if off >= payload.len() {
                done = true;
                return Some(frame::FrameHead {
                    ftype: frame::FrameType::SenderDone,
                    flags: 0,
                    req_id: 9,
                    index: 1,
                });
            }
            let first = off == 0;
            let end = (off + chunk).min(payload.len());
            let last = end == payload.len();
            if first && !last {
                buf.extend_from_slice(&total.to_le_bytes());
            }
            buf.extend_from_slice(&payload[off..end]);
            off = end;
            let flags = if first { frame::FLAG_FIRST } else if last { frame::FLAG_LAST } else { 0 };
            Some(frame::FrameHead { ftype: frame::FrameType::Data, flags, req_id: 9, index: 0 })
        })
        .unwrap();
        let mut rebuilt = Vec::new();
        loop {
            let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            if f.ftype == frame::FrameType::SenderDone {
                break;
            }
            let (t, bytes) = f.chunk_parts().unwrap();
            if f.is_first() {
                assert_eq!(t, total);
            }
            rebuilt.extend_from_slice(bytes);
        }
        assert_eq!(rebuilt, payload, "borrowed frames reassemble byte-identically");
    }

    #[test]
    fn connections_reused_across_sends() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        for i in 0..10 {
            pool.send(&addr, &[Frame::data(i, 0, vec![0u8; 128])]).unwrap();
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        assert_eq!(pool.established.load(Ordering::Relaxed), 1, "one conn for 10 sends");
        assert_eq!(pool.idle_count(), 1);
    }

    #[test]
    fn disable_reuse_reconnects_every_time() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        pool.disable_reuse.store(true, Ordering::Relaxed);
        let addr = srv.addr.to_string();
        for i in 0..5 {
            pool.send(&addr, &[Frame::data(i, 0, vec![1])]).unwrap();
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        assert_eq!(pool.established.load(Ordering::Relaxed), 5);
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn idle_reclaim() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_millis(30));
        let addr = srv.addr.to_string();
        pool.send(&addr, &[Frame::data(1, 0, vec![1])]).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(pool.idle_count(), 1);
        std::thread::sleep(Duration::from_millis(60));
        pool.reap();
        assert_eq!(pool.idle_count(), 0);
        // next send re-establishes
        pool.send(&addr, &[Frame::data(2, 0, vec![2])]).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(pool.established.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn stale_pooled_connection_replaced_by_fresh_one() {
        // A raw server that reads one frame per connection and then kills
        // the socket — the pooled connection the client holds is dead on
        // its next checkout; send() must succeed via a fresh connection.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let mut got = Vec::new();
            for _ in 0..2 {
                let (mut s, _) = listener.accept().unwrap();
                got.push(read_frame(&mut s).unwrap().unwrap());
                // socket dropped here: server-side kill between sends
            }
            got
        });

        let pool = PeerPool::new(Duration::from_secs(30));
        pool.send(&addr, &[Frame::data(1, 0, vec![1; 64])]).unwrap();
        // Give the server's FIN time to reach our pooled socket.
        std::thread::sleep(Duration::from_millis(100));
        pool.send(&addr, &[Frame::data(2, 0, vec![2; 64])]).unwrap();

        let got = server.join().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].req_id, 1);
        assert_eq!(got[1].req_id, 2);
        assert_eq!(pool.established.load(Ordering::Relaxed), 2, "second send reconnected");
    }

    #[test]
    fn send_iter_survives_stale_pooled_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let mut bursts = Vec::new();
            for want in [1usize, 3] {
                let (mut s, _) = listener.accept().unwrap();
                let mut frames = Vec::new();
                for _ in 0..want {
                    frames.push(read_frame(&mut s).unwrap().unwrap());
                }
                bursts.push(frames);
            }
            bursts
        });

        let pool = PeerPool::new(Duration::from_secs(30));
        pool.send_iter(&addr, std::iter::once(Frame::data(1, 0, vec![1]))).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let frames = vec![
            Frame::data(2, 0, vec![2; 2048]),
            Frame::data(2, 1, vec![3; 2048]),
            Frame::sender_done(2, 2),
        ];
        pool.send_iter(&addr, frames.into_iter()).unwrap();
        let bursts = server.join().unwrap();
        assert_eq!(bursts[1].len(), 3);
        assert_eq!(bursts[1][2].ftype, frame::FrameType::SenderDone);
    }

    #[test]
    fn concurrent_senders_no_interleave() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let pool2 = Arc::clone(&pool);
        crate::util::threadpool::scoped_map(&(0..8u64).collect::<Vec<_>>(), 8, |_, &i| {
            pool2
                .send(&addr, &[Frame::data(i, 0, vec![i as u8; 1000]), Frame::sender_done(i, 1)])
                .unwrap();
        });
        let mut frames = Vec::new();
        for _ in 0..16 {
            frames.push(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        }
        // every data frame intact (crc verified by read_frame already)
        for f in frames.iter().filter(|f| f.ftype == frame::FrameType::Data) {
            assert!(f.payload.iter().all(|&b| b == f.req_id as u8));
        }
    }
}
