//! Peer connection pool + P2P frame server.
//!
//! Senders check a connection out of the pool, write a burst of frames, and
//! check it back in — exclusive use while checked out, so frames of
//! concurrent requests never interleave on one socket. Idle connections are
//! reclaimed after `idle_timeout`, amortizing TCP setup across requests and
//! avoiding connection storms under concurrent load (§2.3.1).

use std::collections::HashMap;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::proto::frame::{self, Frame};

struct IdleConn {
    stream: TcpStream,
    since: Instant,
}

/// Sender-side pool of persistent peer connections.
pub struct PeerPool {
    idle: Mutex<HashMap<String, Vec<IdleConn>>>,
    idle_timeout: Duration,
    max_per_peer: usize,
    /// Connections established (visible to the A3 pooling ablation).
    pub established: AtomicU64,
    /// When true, checkin drops the connection instead of pooling —
    /// models per-request connection setup for the ablation.
    pub disable_reuse: AtomicBool,
}

impl PeerPool {
    pub fn new(idle_timeout: Duration) -> Arc<PeerPool> {
        Arc::new(PeerPool {
            idle: Mutex::new(HashMap::new()),
            idle_timeout,
            max_per_peer: 16,
            established: AtomicU64::new(0),
            disable_reuse: AtomicBool::new(false),
        })
    }

    fn checkout(&self, addr: &str) -> io::Result<TcpStream> {
        if !self.disable_reuse.load(Ordering::Relaxed) {
            let mut idle = self.idle.lock().unwrap();
            if let Some(v) = idle.get_mut(addr) {
                while let Some(c) = v.pop() {
                    if c.since.elapsed() < self.idle_timeout {
                        return Ok(c.stream);
                    }
                    // stale: drop (reclaim)
                }
            }
        }
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        self.established.fetch_add(1, Ordering::Relaxed);
        Ok(s)
    }

    fn checkin(&self, addr: &str, stream: TcpStream) {
        if self.disable_reuse.load(Ordering::Relaxed) {
            return; // drop ⇒ close
        }
        let mut idle = self.idle.lock().unwrap();
        let v = idle.entry(addr.to_string()).or_default();
        if v.len() < self.max_per_peer {
            v.push(IdleConn { stream, since: Instant::now() });
        }
    }

    /// Write a burst of frames to `addr` on one pooled connection.
    /// The encode buffer is reused across frames (hot path).
    pub fn send(&self, addr: &str, frames: &[Frame]) -> io::Result<()> {
        let stream = self.checkout(addr)?;
        let mut w = BufWriter::with_capacity(256 * 1024, stream);
        let mut scratch = Vec::with_capacity(64 * 1024);
        for f in frames {
            frame::encode_into(f, &mut scratch);
            w.write_all(&scratch)?;
        }
        w.flush()?;
        let stream = w.into_inner().map_err(|e| e.into_error())?;
        self.checkin(addr, stream);
        Ok(())
    }

    /// Send frames produced lazily, flushing each as soon as it's encoded —
    /// lets a sender overlap disk reads with transmission.
    pub fn send_iter(
        &self,
        addr: &str,
        frames: impl Iterator<Item = Frame>,
    ) -> io::Result<()> {
        let stream = self.checkout(addr)?;
        let mut w = BufWriter::with_capacity(256 * 1024, stream);
        let mut scratch = Vec::with_capacity(64 * 1024);
        for f in frames {
            frame::encode_into(&f, &mut scratch);
            w.write_all(&scratch)?;
            w.flush()?;
        }
        let stream = w.into_inner().map_err(|e| e.into_error())?;
        self.checkin(addr, stream);
        Ok(())
    }

    /// Reap idle connections past the timeout (called opportunistically).
    pub fn reap(&self) {
        let mut idle = self.idle.lock().unwrap();
        for v in idle.values_mut() {
            v.retain(|c| c.since.elapsed() < self.idle_timeout);
        }
        idle.retain(|_, v| !v.is_empty());
    }

    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().values().map(|v| v.len()).sum()
    }
}

/// Receiver side: accepts peer connections and dispatches every incoming
/// frame to the handler (the DT registry). One reader thread per peer
/// connection — connections are few (pooled) and long-lived.
pub struct P2pServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

pub type FrameHandler = Arc<dyn Fn(Frame) + Send + Sync>;

impl P2pServer {
    pub fn serve(handler: FrameHandler, name: &str) -> io::Result<P2pServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let name = name.to_string();
        let accept_thread = std::thread::Builder::new()
            .name(format!("{name}-p2p"))
            .spawn(move || {
                let mut conns: Vec<JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = Arc::clone(&handler);
                            let stop3 = Arc::clone(&stop2);
                            conns.push(std::thread::spawn(move || {
                                let _ = stream.set_nodelay(true);
                                let _ = stream
                                    .set_read_timeout(Some(Duration::from_millis(200)));
                                let mut r = BufReader::with_capacity(256 * 1024, stream);
                                loop {
                                    match frame::read_frame(&mut r) {
                                        Ok(Some(f)) => h(f),
                                        Ok(None) => break, // peer closed
                                        Err(frame::FrameError::Io(e))
                                            if e.kind() == io::ErrorKind::WouldBlock
                                                || e.kind() == io::ErrorKind::TimedOut =>
                                        {
                                            if stop3.load(Ordering::Relaxed) {
                                                break;
                                            }
                                        }
                                        Err(_) => break, // corrupt stream: drop conn
                                    }
                                }
                            }));
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(P2pServer { addr, stop, accept_thread: Some(accept_thread) })
    }
}

impl Drop for P2pServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn collector() -> (P2pServer, mpsc::Receiver<Frame>) {
        let (tx, rx) = mpsc::channel();
        let tx = Mutex::new(tx);
        let srv = P2pServer::serve(
            Arc::new(move |f| {
                let _ = tx.lock().unwrap().send(f);
            }),
            "test",
        )
        .unwrap();
        (srv, rx)
    }

    #[test]
    fn frames_arrive() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        pool.send(
            &addr,
            &[
                Frame::data(1, 0, vec![1, 2, 3]),
                Frame::soft_err(1, 1, "missing"),
                Frame::sender_done(1, 1),
            ],
        )
        .unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        }
        assert_eq!(got[0].payload, vec![1, 2, 3]);
        assert_eq!(got[2].index, 1);
    }

    #[test]
    fn connections_reused_across_sends() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        for i in 0..10 {
            pool.send(&addr, &[Frame::data(i, 0, vec![0u8; 128])]).unwrap();
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        assert_eq!(pool.established.load(Ordering::Relaxed), 1, "one conn for 10 sends");
        assert_eq!(pool.idle_count(), 1);
    }

    #[test]
    fn disable_reuse_reconnects_every_time() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        pool.disable_reuse.store(true, Ordering::Relaxed);
        let addr = srv.addr.to_string();
        for i in 0..5 {
            pool.send(&addr, &[Frame::data(i, 0, vec![1])]).unwrap();
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        assert_eq!(pool.established.load(Ordering::Relaxed), 5);
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn idle_reclaim() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_millis(30));
        let addr = srv.addr.to_string();
        pool.send(&addr, &[Frame::data(1, 0, vec![1])]).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(pool.idle_count(), 1);
        std::thread::sleep(Duration::from_millis(60));
        pool.reap();
        assert_eq!(pool.idle_count(), 0);
        // next send re-establishes
        pool.send(&addr, &[Frame::data(2, 0, vec![2])]).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(pool.established.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn concurrent_senders_no_interleave() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let pool2 = Arc::clone(&pool);
        crate::util::threadpool::scoped_map(&(0..8u64).collect::<Vec<_>>(), 8, |_, &i| {
            pool2
                .send(&addr, &[Frame::data(i, 0, vec![i as u8; 1000]), Frame::sender_done(i, 1)])
                .unwrap();
        });
        let mut frames = Vec::new();
        for _ in 0..16 {
            frames.push(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        }
        // every data frame intact (crc verified by read_frame already)
        for f in frames.iter().filter(|f| f.ftype == frame::FrameType::Data) {
            assert!(f.payload.iter().all(|&b| b == f.req_id as u8));
        }
    }
}
