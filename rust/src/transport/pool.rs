//! Peer connection pool + P2P frame server, on the shared reactor.
//!
//! Outbound: the pool keeps **one multiplexed connection per peer** (a
//! [`Mux`]) instead of a checkout pool of exclusive sockets. Senders
//! enqueue each frame atomically into the connection's reactor write
//! buffer, so bursts from concurrent senders interleave **by frame** —
//! never inside one — and a burst completes when its flush watermark is
//! reached. Idle peers are reclaimed after `idle_timeout`, amortizing TCP
//! setup across requests and avoiding connection storms under concurrent
//! load (§2.3.1).
//!
//! Inbound: the P2P server parses frames incrementally off the reactor's
//! input buffer and hands them, per connection and in order, to a
//! worker-pool drain job. A handler may block (memory-budget
//! backpressure): the connection's frame queue fills to its bound, read
//! interest is dropped, and TCP flow control pushes back on the sender —
//! no thread parks while holding the socket.
//!
//! Stale-connection handling: a pooled peer connection may have been
//! closed since its last use (peer restart, idle reclaim on the far
//! side). The reactor notices the FIN as it arrives and marks the mux
//! dead, so checkout discards it up front; if the race is lost mid-burst,
//! the burst retries on a fresh connection only while **nothing of it has
//! reached the wire** — frames are not idempotent (a duplicated
//! SENDER_DONE would double-count fan-in completion), so a partially
//! delivered burst is surfaced instead of blindly resent; the DT's
//! sender-wait + GFN ladder owns that recovery.

use std::collections::{HashMap, VecDeque};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::proto::frame::{self, Frame};

use super::reactor::{
    ConnIo, ConnProto, ProtoFactory, Reactor, ReactorConfig, ReactorStats, WorkerPool,
};

/// One multiplexed connection to a peer: shared by every sender targeting
/// that address. Death is observed through the reactor (`io.is_closed()`).
struct Mux {
    io: Arc<ConnIo>,
    st: Mutex<MuxState>,
}

struct MuxState {
    /// Senders currently inside a burst on this mux.
    active: usize,
    last_used: Instant,
}

/// Client-side protocol: the frame stream is strictly one-way, so any
/// inbound byte is a violation and EOF (the default `on_eof`) closes the
/// connection — which is exactly how the pool learns a peer went away.
struct ClientConn;

impl ConnProto for ClientConn {
    fn on_data(&mut self, inbuf: &mut Vec<u8>, _io: &Arc<ConnIo>) -> io::Result<()> {
        if inbuf.is_empty() {
            Ok(())
        } else {
            Err(io::Error::new(io::ErrorKind::InvalidData, "unexpected inbound bytes on p2p send"))
        }
    }
}

/// Sender-side pool of persistent, multiplexed peer connections.
pub struct PeerPool {
    reactor: Arc<Reactor>,
    muxes: Mutex<HashMap<String, Arc<Mux>>>,
    idle_timeout: Duration,
    /// Connections established (visible to the A3 pooling ablation).
    pub established: AtomicU64,
    /// When true, every burst runs on its own fresh connection, closed at
    /// the end — models per-request connection setup for the ablation.
    pub disable_reuse: AtomicBool,
}

impl PeerPool {
    pub fn new(idle_timeout: Duration) -> Arc<PeerPool> {
        let cfg = ReactorConfig {
            threads: 1,
            min_workers: 1,
            write_buf_limit: 512 << 10,
            ..Default::default()
        };
        let reactor = Reactor::new(cfg, "peer-pool").expect("peer-pool reactor");
        Arc::new(PeerPool {
            reactor,
            muxes: Mutex::new(HashMap::new()),
            idle_timeout,
            established: AtomicU64::new(0),
            disable_reuse: AtomicBool::new(false),
        })
    }

    /// Register a fresh connection with the reactor, checked out for one
    /// sender (`active = 1`), and pool it unless reuse is disabled.
    fn connect_fresh(&self, addr: &str) -> io::Result<Arc<Mux>> {
        let stream = TcpStream::connect(addr)?;
        self.established.fetch_add(1, Ordering::Relaxed);
        let io = self.reactor.register(stream, Box::new(ClientConn))?;
        let mux = Arc::new(Mux {
            io,
            st: Mutex::new(MuxState { active: 1, last_used: Instant::now() }),
        });
        if !self.disable_reuse.load(Ordering::Relaxed) {
            self.muxes.lock().unwrap().insert(addr.to_string(), Arc::clone(&mux));
        }
        Ok(mux)
    }

    /// Returns `(mux, came_from_pool)`; dead or idle-expired muxes are
    /// discarded up front.
    fn checkout(&self, addr: &str) -> io::Result<(Arc<Mux>, bool)> {
        if !self.disable_reuse.load(Ordering::Relaxed) {
            let mut muxes = self.muxes.lock().unwrap();
            if let Some(m) = muxes.get(addr) {
                let usable = !m.io.is_closed() && {
                    let mut st = m.st.lock().unwrap();
                    let live = st.active > 0 || st.last_used.elapsed() < self.idle_timeout;
                    if live {
                        st.active += 1;
                    }
                    live
                };
                if usable {
                    return Ok((Arc::clone(m), true));
                }
                if let Some(stale) = muxes.remove(addr) {
                    stale.io.close();
                }
            }
        }
        Ok((self.connect_fresh(addr)?, false))
    }

    /// End a sender's use of `mux`; `kill` drops it from the pool and
    /// closes the socket (burst failure).
    fn finish(&self, mux: &Arc<Mux>, addr: &str, kill: bool) {
        let active = {
            let mut st = mux.st.lock().unwrap();
            st.active = st.active.saturating_sub(1);
            st.last_used = Instant::now();
            st.active
        };
        if kill {
            mux.io.close();
            let mut muxes = self.muxes.lock().unwrap();
            if muxes.get(addr).is_some_and(|cur| Arc::ptr_eq(cur, mux)) {
                muxes.remove(addr);
            }
        } else if active == 0 && self.disable_reuse.load(Ordering::Relaxed) {
            // Un-pooled ablation mode: one burst per connection.
            mux.io.close_after_flush();
        }
    }

    /// Core burst path shared by `send`/`send_iter`/`send_stream`: `next`
    /// encodes the burst's next frame into the scratch buffer (returning
    /// `false` when the burst ends). Each encoded frame is enqueued
    /// atomically — concurrent bursts interleave frame-by-frame — and the
    /// call returns once the mux has flushed this burst's last byte.
    ///
    /// Stale-pool retry: if the pooled mux fails on the burst's FIRST
    /// frame with nothing flushed, that frame (still in hand) replays on a
    /// fresh connection; any later failure is surfaced to the caller.
    fn send_encoded(&self, addr: &str, mut next: impl FnMut(&mut Vec<u8>) -> bool) -> io::Result<()> {
        let (mut mux, mut from_pool) = self.checkout(addr)?;
        let mut scratch = Vec::with_capacity(64 * 1024);
        let mut burst_start: Option<u64> = None;
        let mut end = 0u64;
        loop {
            scratch.clear();
            if !next(&mut scratch) {
                break;
            }
            let wire = std::mem::take(&mut scratch);
            // Only the first frame of a pooled burst keeps a retry copy.
            let retry = if from_pool && burst_start.is_none() { Some(wire.clone()) } else { None };
            match mux.io.send_vec(wire) {
                Ok((s, e)) => {
                    burst_start.get_or_insert(s);
                    end = e;
                }
                Err(err) => {
                    self.finish(&mux, addr, true);
                    let replay = match retry {
                        Some(r) if burst_start.is_none() => r,
                        _ => return Err(err),
                    };
                    mux = self.connect_fresh(addr)?;
                    from_pool = false;
                    match mux.io.send_vec(replay) {
                        Ok((s, e)) => {
                            burst_start = Some(s);
                            end = e;
                        }
                        Err(err) => {
                            self.finish(&mux, addr, true);
                            return Err(err);
                        }
                    }
                }
            }
        }
        if burst_start.is_some() {
            if let Err(err) = mux.io.wait_flushed(end) {
                self.finish(&mux, addr, true);
                return Err(err);
            }
        }
        self.finish(&mux, addr, false);
        Ok(())
    }

    /// Write a burst of frames to `addr` on the peer's multiplexed
    /// connection; returns once every byte has been handed to the socket.
    pub fn send(&self, addr: &str, frames: &[Frame]) -> io::Result<()> {
        let mut it = frames.iter();
        self.send_encoded(addr, move |buf| match it.next() {
            Some(f) => {
                frame::encode_into(f, buf);
                true
            }
            None => false,
        })
    }

    /// Send frames produced lazily, enqueueing each as soon as it's
    /// encoded — lets a sender overlap disk reads with transmission.
    pub fn send_iter(
        &self,
        addr: &str,
        frames: impl Iterator<Item = Frame>,
    ) -> io::Result<()> {
        let mut frames = frames;
        self.send_encoded(addr, move |buf| match frames.next() {
            Some(f) => {
                frame::encode_into(&f, buf);
                true
            }
            None => false,
        })
    }

    /// Lending variant of [`PeerPool::send_iter`] for the sender hot loop:
    /// `fill` appends the next frame's wire payload into the reusable
    /// buffer (cleared between frames) and returns its head, or `None` to
    /// end the burst — one payload buffer serves every chunk frame.
    pub fn send_stream(
        &self,
        addr: &str,
        mut fill: impl FnMut(&mut Vec<u8>) -> Option<frame::FrameHead>,
    ) -> io::Result<()> {
        let mut payload = Vec::with_capacity(64 * 1024);
        self.send_encoded(addr, move |buf| {
            payload.clear();
            match fill(&mut payload) {
                Some(head) => {
                    frame::encode_head_into(head, &payload, buf);
                    true
                }
                None => false,
            }
        })
    }

    /// Reap idle peer connections past the timeout (called
    /// opportunistically).
    pub fn reap(&self) {
        let mut muxes = self.muxes.lock().unwrap();
        muxes.retain(|_, m| {
            let keep = {
                let st = m.st.lock().unwrap();
                st.active > 0
                    || (st.last_used.elapsed() < self.idle_timeout && !m.io.is_closed())
            };
            if !keep {
                m.io.close();
            }
            keep
        });
    }

    /// Pooled peer connections currently open and not inside a burst.
    pub fn idle_count(&self) -> usize {
        let muxes = self.muxes.lock().unwrap();
        muxes
            .values()
            .filter(|m| !m.io.is_closed() && m.st.lock().unwrap().active == 0)
            .count()
    }
}

// ---------------------------------------------------------------- server --

pub type FrameHandler = Arc<dyn Fn(Frame) + Send + Sync>;

/// Per-connection inbound frame queue: the reactor thread appends decoded
/// frames; a single worker-pool drain job per connection pops them in
/// order (the handler may block on the memory budget).
#[derive(Default)]
struct FrameQueue {
    st: Mutex<QueueState>,
}

#[derive(Default)]
struct QueueState {
    frames: VecDeque<Frame>,
    bytes: usize,
    /// A drain job currently owns this queue.
    running: bool,
}

/// Queue bound: above this, the connection's read interest is dropped so
/// TCP pushes back on the sender; reads resume below half.
const QUEUE_PAUSE_BYTES: usize = 1 << 20;
const QUEUE_RESUME_BYTES: usize = QUEUE_PAUSE_BYTES / 2;

fn frame_cost(f: &Frame) -> usize {
    frame::HEADER_LEN + f.payload.len()
}

struct P2pConn {
    handler: FrameHandler,
    pool: WorkerPool,
    queue: Arc<FrameQueue>,
}

fn drain_queue(queue: &Arc<FrameQueue>, handler: &FrameHandler, io: &Arc<ConnIo>) {
    loop {
        let f = {
            let mut st = queue.st.lock().unwrap();
            match st.frames.pop_front() {
                Some(f) => {
                    st.bytes -= frame_cost(&f);
                    if st.bytes <= QUEUE_RESUME_BYTES {
                        io.resume_reads();
                    }
                    f
                }
                None => {
                    st.running = false;
                    io.resume_reads();
                    return;
                }
            }
        };
        handler(f);
    }
}

impl ConnProto for P2pConn {
    fn on_data(&mut self, inbuf: &mut Vec<u8>, io: &Arc<ConnIo>) -> io::Result<()> {
        let mut consumed = 0usize;
        let mut start_drain = false;
        {
            let mut st = self.queue.st.lock().unwrap();
            loop {
                match frame::decode_slice(&inbuf[consumed..]) {
                    Ok(Some((f, used))) => {
                        consumed += used;
                        st.bytes += frame_cost(&f);
                        st.frames.push_back(f);
                        if !st.running {
                            st.running = true;
                            start_drain = true;
                        }
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // Corrupt stream: drop the connection (the per-frame
                        // CRC already classified chunk corruption upstream).
                        return Err(io::Error::new(io::ErrorKind::InvalidData, e.to_string()));
                    }
                }
            }
            if st.bytes > QUEUE_PAUSE_BYTES {
                io.pause_reads();
            }
        }
        if consumed > 0 {
            inbuf.drain(..consumed);
        }
        if start_drain {
            let queue = Arc::clone(&self.queue);
            let handler = Arc::clone(&self.handler);
            let io = Arc::clone(io);
            self.pool.execute(move || drain_queue(&queue, &handler, &io));
        }
        Ok(())
    }
}

/// Receiver side: accepts peer connections on a reactor loop and
/// dispatches every incoming frame, per connection and in order, to the
/// handler (the DT registry). Dropping the server stops the reactor and
/// joins its loop + worker threads after draining queued frames.
pub struct P2pServer {
    pub addr: SocketAddr,
    reactor: Arc<Reactor>,
}

impl P2pServer {
    pub fn serve(handler: FrameHandler, name: &str) -> io::Result<P2pServer> {
        let cfg = ReactorConfig { threads: 1, min_workers: 1, ..Default::default() };
        P2pServer::serve_opts(handler, name, cfg)
    }

    /// [`P2pServer::serve`] with explicit reactor tuning.
    pub fn serve_opts(
        handler: FrameHandler,
        name: &str,
        cfg: ReactorConfig,
    ) -> io::Result<P2pServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let reactor = Reactor::new(cfg, name)?;
        let pool = reactor.worker_pool();
        let factory: ProtoFactory = Arc::new(move |_peer| {
            Box::new(P2pConn {
                handler: Arc::clone(&handler),
                pool: pool.clone(),
                queue: Arc::new(FrameQueue::default()),
            })
        });
        reactor.listen(listener, factory)?;
        Ok(P2pServer { addr, reactor })
    }

    /// Reactor counters (open connections, wake-ups, shed accepts).
    pub fn stats(&self) -> Arc<ReactorStats> {
        Arc::clone(self.reactor.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::frame::read_frame;
    use std::sync::mpsc;

    fn collector() -> (P2pServer, mpsc::Receiver<Frame>) {
        let (tx, rx) = mpsc::channel();
        let tx = Mutex::new(tx);
        let srv = P2pServer::serve(
            Arc::new(move |f| {
                let _ = tx.lock().unwrap().send(f);
            }),
            "test",
        )
        .unwrap();
        (srv, rx)
    }

    #[test]
    fn frames_arrive() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        pool.send(
            &addr,
            &[
                Frame::data(1, 0, vec![1, 2, 3]),
                Frame::soft_err(1, 1, "missing"),
                Frame::sender_done(1, 1),
            ],
        )
        .unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        }
        assert_eq!(got[0].payload, vec![1, 2, 3]);
        assert_eq!(got[2].index, 1);
    }

    #[test]
    fn chunked_frames_arrive_in_order() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i % 233) as u8).collect();
        let frames = frame::chunk_frames(4, 2, payload.clone(), 1 << 10);
        assert!(frames.len() > 2, "multi-chunk");
        pool.send_iter(&addr, frames.into_iter()).unwrap();
        let mut rebuilt = Vec::new();
        loop {
            let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            let (total, bytes) = f.chunk_parts().unwrap();
            if f.is_first() {
                assert_eq!(total, payload.len() as u64);
            }
            rebuilt.extend_from_slice(bytes);
            if f.is_last() {
                break;
            }
        }
        assert_eq!(rebuilt, payload);
    }

    #[test]
    fn send_stream_delivers_borrowed_frames() {
        // The lending path must be wire-identical to owned frames: a
        // 3-chunk entry produced into one reused payload buffer arrives
        // reassemblable and in order, followed by SENDER_DONE.
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let payload: Vec<u8> = (0..3000u32).map(|i| (i % 239) as u8).collect();
        let chunk = 1024usize;
        let total = payload.len() as u64;
        let mut off = 0usize;
        let mut done = false;
        pool.send_stream(&addr, |buf| {
            if done {
                return None;
            }
            if off >= payload.len() {
                done = true;
                return Some(frame::FrameHead {
                    ftype: frame::FrameType::SenderDone,
                    flags: 0,
                    req_id: 9,
                    index: 1,
                });
            }
            let first = off == 0;
            let end = (off + chunk).min(payload.len());
            let last = end == payload.len();
            if first && !last {
                buf.extend_from_slice(&total.to_le_bytes());
            }
            buf.extend_from_slice(&payload[off..end]);
            off = end;
            let flags = if first { frame::FLAG_FIRST } else if last { frame::FLAG_LAST } else { 0 };
            Some(frame::FrameHead { ftype: frame::FrameType::Data, flags, req_id: 9, index: 0 })
        })
        .unwrap();
        let mut rebuilt = Vec::new();
        loop {
            let f = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            if f.ftype == frame::FrameType::SenderDone {
                break;
            }
            let (t, bytes) = f.chunk_parts().unwrap();
            if f.is_first() {
                assert_eq!(t, total);
            }
            rebuilt.extend_from_slice(bytes);
        }
        assert_eq!(rebuilt, payload, "borrowed frames reassemble byte-identically");
    }

    #[test]
    fn connections_reused_across_sends() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        for i in 0..10 {
            pool.send(&addr, &[Frame::data(i, 0, vec![0u8; 128])]).unwrap();
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        assert_eq!(pool.established.load(Ordering::Relaxed), 1, "one conn for 10 sends");
        assert_eq!(pool.idle_count(), 1);
    }

    #[test]
    fn disable_reuse_reconnects_every_time() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        pool.disable_reuse.store(true, Ordering::Relaxed);
        let addr = srv.addr.to_string();
        for i in 0..5 {
            pool.send(&addr, &[Frame::data(i, 0, vec![1])]).unwrap();
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        assert_eq!(pool.established.load(Ordering::Relaxed), 5);
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn idle_reclaim() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_millis(30));
        let addr = srv.addr.to_string();
        pool.send(&addr, &[Frame::data(1, 0, vec![1])]).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(pool.idle_count(), 1);
        std::thread::sleep(Duration::from_millis(60));
        pool.reap();
        assert_eq!(pool.idle_count(), 0);
        // next send re-establishes
        pool.send(&addr, &[Frame::data(2, 0, vec![2])]).unwrap();
        rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(pool.established.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn stale_pooled_connection_replaced_by_fresh_one() {
        // A raw server that reads one frame per connection and then kills
        // the socket — the pooled connection the client holds is dead on
        // its next checkout; send() must succeed via a fresh connection.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let mut got = Vec::new();
            for _ in 0..2 {
                let (mut s, _) = listener.accept().unwrap();
                got.push(read_frame(&mut s).unwrap().unwrap());
                // socket dropped here: server-side kill between sends
            }
            got
        });

        let pool = PeerPool::new(Duration::from_secs(30));
        pool.send(&addr, &[Frame::data(1, 0, vec![1; 64])]).unwrap();
        // Give the server's FIN time to reach our pooled socket.
        std::thread::sleep(Duration::from_millis(100));
        pool.send(&addr, &[Frame::data(2, 0, vec![2; 64])]).unwrap();

        let got = server.join().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].req_id, 1);
        assert_eq!(got[1].req_id, 2);
        assert_eq!(pool.established.load(Ordering::Relaxed), 2, "second send reconnected");
    }

    #[test]
    fn send_iter_survives_stale_pooled_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let mut bursts = Vec::new();
            for want in [1usize, 3] {
                let (mut s, _) = listener.accept().unwrap();
                let mut frames = Vec::new();
                for _ in 0..want {
                    frames.push(read_frame(&mut s).unwrap().unwrap());
                }
                bursts.push(frames);
            }
            bursts
        });

        let pool = PeerPool::new(Duration::from_secs(30));
        pool.send_iter(&addr, std::iter::once(Frame::data(1, 0, vec![1]))).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let frames = vec![
            Frame::data(2, 0, vec![2; 2048]),
            Frame::data(2, 1, vec![3; 2048]),
            Frame::sender_done(2, 2),
        ];
        pool.send_iter(&addr, frames.into_iter()).unwrap();
        let bursts = server.join().unwrap();
        assert_eq!(bursts[1].len(), 3);
        assert_eq!(bursts[1][2].ftype, frame::FrameType::SenderDone);
    }

    #[test]
    fn concurrent_senders_no_interleave() {
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let pool2 = Arc::clone(&pool);
        crate::util::threadpool::scoped_map(&(0..8u64).collect::<Vec<_>>(), 8, |_, &i| {
            pool2
                .send(&addr, &[Frame::data(i, 0, vec![i as u8; 1000]), Frame::sender_done(i, 1)])
                .unwrap();
        });
        let mut frames = Vec::new();
        for _ in 0..16 {
            frames.push(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        }
        // every data frame intact (crc verified per frame already) — with a
        // multiplexed mux, concurrent bursts interleave by frame, never
        // inside one
        for f in frames.iter().filter(|f| f.ftype == frame::FrameType::Data) {
            assert!(f.payload.iter().all(|&b| b == f.req_id as u8));
        }
    }

    #[test]
    fn many_concurrent_bursts_multiplex_one_connection() {
        // 32 senders share ONE multiplexed peer connection: every frame
        // arrives intact and SENDER_DONE fan-in completes for all bursts.
        let (srv, rx) = collector();
        let pool = PeerPool::new(Duration::from_secs(5));
        let addr = srv.addr.to_string();
        let pool2 = Arc::clone(&pool);
        crate::util::threadpool::scoped_map(&(0..32u64).collect::<Vec<_>>(), 16, |_, &i| {
            let frames = frame::chunk_frames(i, 0, vec![i as u8; 8192], 1 << 10);
            pool2.send(&addr, &frames).unwrap();
            pool2.send(&addr, &[Frame::sender_done(i, 1)]).unwrap();
        });
        let mut done = 0;
        let mut data_bytes: HashMap<u64, usize> = HashMap::new();
        while done < 32 {
            let f = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match f.ftype {
                frame::FrameType::SenderDone => done += 1,
                frame::FrameType::Data => {
                    let (_, bytes) = f.chunk_parts().unwrap();
                    assert!(bytes.iter().all(|&b| b == f.req_id as u8), "frame intact");
                    *data_bytes.entry(f.req_id).or_default() += bytes.len();
                }
                frame::FrameType::SoftErr => panic!("unexpected soft error"),
            }
        }
        assert_eq!(data_bytes.len(), 32);
        assert!(data_bytes.values().all(|&n| n == 8192), "{data_bytes:?}");
        assert_eq!(
            pool.established.load(Ordering::Relaxed),
            1,
            "all bursts multiplexed one connection"
        );
    }
}
