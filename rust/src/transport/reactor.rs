//! Readiness-driven connection reactor (the crate's answer to "one OS
//! thread per connection caps concurrency at thread count"). A small set of
//! reactor threads multiplexes every socket of a node — HTTP server
//! connections, P2P frame streams, and the peer pool's outbound writers —
//! over **epoll**, wrapped by hand via `std::os::fd` + three `extern "C"`
//! declarations (the zero-dependency rule: no tokio, no mio, no libc
//! crate; `libc` the *system library* links by default on Linux).
//!
//! Division of labor, and the backpressure invariant that falls out of it:
//!
//! * **Reactor threads own the sockets.** They are the only threads that
//!   `read`/`write`/`accept`, always in non-blocking mode, and they never
//!   run protocol handlers — an epoll wake-up only moves bytes between
//!   sockets and per-connection buffers and advances the connection's
//!   [`ConnProto`] state machine.
//! * **Worker threads own the blocking.** Handlers run on an elastic
//!   [`WorkerPool`] and communicate with the socket exclusively through a
//!   [`ConnIo`] handle: writes append to a bounded per-connection output
//!   buffer (blocking on the buffer's high-water mark, *not* on the
//!   socket), and the reactor arms `EPOLLOUT` only while that buffer is
//!   non-empty. A handler stalled on the `MemoryBudget` — or on a slow
//!   reader draining its output buffer — therefore parks holding **no**
//!   socket: *no thread ever parks while holding a socket*, which is what
//!   lets `reactor_threads = 2` serve thousands of keep-alive connections.
//!
//! Flow control is interest toggling, not thread state: a slow peer leaves
//! `EPOLLOUT` armed and the producer blocked on the buffer's condvar; a
//! protocol that cannot absorb more input (P2P frame queue over its bound)
//! calls [`ConnIo::pause_reads`], dropping `EPOLLIN` so TCP pushes back on
//! the sender.

use std::collections::{HashMap, VecDeque};
use std::fs::File;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
use std::os::raw::c_int;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::metrics::{Counter, Gauge, GetBatchMetrics};

// ------------------------------------------------------------------ epoll --

/// Hand-rolled epoll/eventfd bindings. The kernel ABI is stable; the
/// symbols come from the C library every Linux Rust binary already links.
mod sys {
    use std::os::raw::c_int;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EFD_NONBLOCK: c_int = 0o4000;
    pub const EFD_CLOEXEC: c_int = 0o2000000;

    /// `struct epoll_event`. Packed on x86_64 (kernel ABI quirk), naturally
    /// aligned everywhere else.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        pub fn eventfd(initval: u32, flags: c_int) -> c_int;
    }
}

struct Epoll {
    fd: OwnedFd,
}

impl Epoll {
    fn new() -> io::Result<Epoll> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: c_int, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        let rc = unsafe { sys::epoll_ctl(self.fd.as_raw_fd(), op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn add(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, events)
    }

    fn modify(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, events)
    }

    fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    fn wait(&self, events: &mut [sys::EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        let n = unsafe {
            sys::epoll_wait(
                self.fd.as_raw_fd(),
                events.as_mut_ptr(),
                events.len() as c_int,
                timeout_ms,
            )
        };
        if n < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(n as usize)
    }
}

/// eventfd-based cross-thread wake-up for one event loop.
struct Waker {
    file: File,
}

impl Waker {
    fn new() -> io::Result<Waker> {
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Waker { file: unsafe { File::from_raw_fd(fd) } })
    }

    fn fd(&self) -> RawFd {
        self.file.as_raw_fd()
    }

    fn wake(&self) {
        let _ = (&self.file).write(&1u64.to_ne_bytes());
    }

    fn drain(&self) {
        let mut buf = [0u8; 8];
        while matches!((&self.file).read(&mut buf), Ok(n) if n > 0) {}
    }
}

// ------------------------------------------------------------ worker pool --

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Elastic worker pool for protocol handlers. Unlike the fixed
/// `util::threadpool::ThreadPool`, this pool grows on demand: handlers are
/// allowed to block (memory-budget backpressure, nested intra-cluster HTTP
/// calls), so a fixed pool could deadlock a fan-out whose handlers wait on
/// each other. A blocked handler costs one parked thread — never a socket —
/// and idle workers above the minimum retire after a grace period.
///
/// Clones share one pool; shutdown is explicit (the owning reactor calls
/// it), so protocol handles can keep cheap clones without a cycle back to
/// the reactor.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    name: String,
    min: usize,
}

struct PoolInner {
    st: Mutex<PoolState>,
    cv: Condvar,
}

#[derive(Default)]
struct PoolState {
    jobs: VecDeque<Job>,
    idle: usize,
    threads: usize,
    stop: bool,
}

impl WorkerPool {
    pub fn new(min: usize, name: &str) -> WorkerPool {
        WorkerPool {
            inner: Arc::new(PoolInner { st: Mutex::new(PoolState::default()), cv: Condvar::new() }),
            name: name.to_string(),
            min: min.max(1),
        }
    }

    /// Enqueue a job; spawns a new worker when none is idle. Never blocks.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.inner.st.lock().unwrap();
        if st.stop {
            return;
        }
        st.jobs.push_back(Box::new(job));
        if st.idle == 0 {
            st.threads += 1;
            let seq = st.threads;
            drop(st);
            let inner = Arc::clone(&self.inner);
            let min = self.min;
            let spawned = std::thread::Builder::new()
                .name(format!("{}-worker-{seq}", self.name))
                .spawn(move || worker_loop(inner, min));
            if spawned.is_err() {
                let mut st = self.inner.st.lock().unwrap();
                st.threads -= 1;
                self.inner.cv.notify_one();
            }
        } else {
            self.inner.cv.notify_one();
        }
    }

    /// Live worker threads (tests/diagnostics).
    pub fn threads(&self) -> usize {
        self.inner.st.lock().unwrap().threads
    }

    /// Stop accepting work, drain already queued jobs, and join all workers.
    fn shutdown(&self) {
        let mut st = self.inner.st.lock().unwrap();
        st.stop = true;
        self.inner.cv.notify_all();
        while st.threads > 0 {
            st = self.inner.cv.wait(st).unwrap();
        }
    }
}

fn worker_loop(inner: Arc<PoolInner>, min: usize) {
    const IDLE_RETIRE: Duration = Duration::from_secs(20);
    let mut st = inner.st.lock().unwrap();
    loop {
        if let Some(job) = st.jobs.pop_front() {
            drop(st);
            // A panicking handler must not corrupt pool accounting (a lost
            // `threads -= 1` would hang shutdown forever).
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            st = inner.st.lock().unwrap();
            continue;
        }
        if st.stop {
            break;
        }
        st.idle += 1;
        let (guard, timeout) = inner.cv.wait_timeout(st, IDLE_RETIRE).unwrap();
        st = guard;
        st.idle -= 1;
        if timeout.timed_out() && st.jobs.is_empty() && !st.stop && st.threads > min {
            break;
        }
    }
    st.threads -= 1;
    inner.cv.notify_all();
}

// ------------------------------------------------------------- the reactor --

/// Per-reactor observability; the node mirrors these into its
/// `GetBatchMetrics` (`open_connections`, `reactor_wakeups_total`,
/// `accept_backlog_shed_total`) when one is attached.
#[derive(Default)]
pub struct ReactorStats {
    /// Connections currently registered across all loops of this reactor.
    pub open_connections: Gauge,
    /// High-water mark of `open_connections` over the reactor's lifetime.
    pub open_connections_peak: Gauge,
    /// epoll wake-ups across all reactor threads.
    pub wakeups: Counter,
    /// Accepted connections immediately shed because `max_connections`
    /// was reached.
    pub shed: Counter,
    /// High-water mark of any single connection's pending write buffer —
    /// the observable form of the bounded-buffering invariant.
    pub peak_outbuf: Gauge,
}

pub struct ReactorConfig {
    /// Event-loop threads; connections are distributed round-robin.
    pub threads: usize,
    /// Registered-connection cap; accepts beyond it are shed (counted).
    pub max_connections: usize,
    /// Worker threads kept alive when idle (the pool grows on demand).
    pub min_workers: usize,
    /// Per-connection pending-write high-water mark: `ConnIo::send` blocks
    /// above it until the reactor drains the socket.
    pub write_buf_limit: usize,
    /// Node metrics to mirror reactor counters into.
    pub metrics: Option<Arc<GetBatchMetrics>>,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            threads: 2,
            max_connections: 4096,
            min_workers: 4,
            write_buf_limit: 256 << 10,
            metrics: None,
        }
    }
}

/// Per-connection protocol state machine, driven entirely by reactor
/// threads. Implementations must never block: blocking work is handed to
/// the [`WorkerPool`], which talks back through the connection's
/// [`ConnIo`].
pub trait ConnProto: Send {
    /// Called once, on the loop thread, when the connection is registered.
    fn on_register(&mut self, io: &Arc<ConnIo>) {
        let _ = io;
    }

    /// New bytes arrived (or a [`ConnIo::kick`] fired): consume what you
    /// can from the front of `inbuf`. Returning `Err` closes the
    /// connection.
    fn on_data(&mut self, inbuf: &mut Vec<u8>, io: &Arc<ConnIo>) -> io::Result<()>;

    /// Peer closed its write side. Default: close immediately.
    fn on_eof(&mut self, io: &Arc<ConnIo>) {
        io.close();
    }

    /// The connection was released (socket closed, producers unblocked).
    fn on_close(&mut self) {}
}

/// Builds a [`ConnProto`] for each accepted connection of a listener.
pub type ProtoFactory = Arc<dyn Fn(SocketAddr) -> Box<dyn ConnProto> + Send + Sync>;

enum Op {
    Listen { listener: TcpListener, factory: ProtoFactory, token: u64 },
    Register { stream: TcpStream, proto: Box<dyn ConnProto>, io: Arc<ConnIo> },
    EnableWrite(u64),
    Interest(u64),
    Kick(u64),
    Close(u64),
}

struct LoopHandle {
    ops: Mutex<Vec<Op>>,
    waker: Waker,
    stop: AtomicBool,
}

impl LoopHandle {
    fn post(&self, op: Op) {
        self.ops.lock().unwrap().push(op);
        self.waker.wake();
    }
}

#[derive(Default)]
struct OutBuf {
    queue: VecDeque<Vec<u8>>,
    /// Consumed prefix of `queue[0]` (partial socket write).
    head_pos: usize,
    /// Pending (not yet written) bytes across the queue.
    bytes: usize,
    /// Cumulative bytes ever enqueued / written — watermarks for
    /// [`ConnIo::wait_flushed`].
    enqueued: u64,
    written: u64,
    close_after_flush: bool,
}

/// Handle through which worker threads (and protocol state machines) talk
/// to a reactor-owned socket. Cheap to clone via `Arc`; outlives the
/// connection (operations on a closed connection fail with `BrokenPipe`).
pub struct ConnIo {
    token: u64,
    lh: Arc<LoopHandle>,
    out: Mutex<OutBuf>,
    cv: Condvar,
    high_water: usize,
    read_paused: AtomicBool,
    closed: AtomicBool,
    stats: Arc<ReactorStats>,
}

impl ConnIo {
    /// Queue `data` for transmission; returns the `(start, end)` enqueue
    /// watermarks of this write (see [`ConnIo::wait_flushed`]).
    ///
    /// Blocks while the connection's pending-write buffer is above its
    /// high-water mark — the caller parks on a condvar holding no socket;
    /// the reactor drains the buffer as the peer reads. Must never be
    /// called from a reactor thread (protocol `on_*` hooks): a loop thread
    /// blocked here could not drain the very buffer it waits on.
    pub fn send_vec(&self, data: Vec<u8>) -> io::Result<(u64, u64)> {
        let len = data.len() as u64;
        let mut out = self.out.lock().unwrap();
        if len == 0 {
            return Ok((out.enqueued, out.enqueued));
        }
        while out.bytes > 0 && out.bytes + data.len() > self.high_water {
            if self.closed.load(Ordering::Acquire) {
                return Err(broken_pipe());
            }
            out = self.cv.wait(out).unwrap();
        }
        if self.closed.load(Ordering::Acquire) {
            return Err(broken_pipe());
        }
        let start = out.enqueued;
        let wake = out.bytes == 0;
        out.bytes += data.len();
        out.enqueued += len;
        out.queue.push_back(data);
        self.stats.peak_outbuf.set_max(out.bytes as i64);
        drop(out);
        if wake {
            self.lh.post(Op::EnableWrite(self.token));
        }
        Ok((start, start + len))
    }

    /// [`ConnIo::send_vec`] for borrowed bytes.
    pub fn send(&self, data: &[u8]) -> io::Result<()> {
        self.send_vec(data.to_vec()).map(|_| ())
    }

    /// Block until the socket has absorbed every byte up to enqueue
    /// watermark `upto`, or the connection died first.
    pub fn wait_flushed(&self, upto: u64) -> io::Result<()> {
        let mut out = self.out.lock().unwrap();
        while out.written < upto {
            if self.closed.load(Ordering::Acquire) {
                return Err(broken_pipe());
            }
            out = self.cv.wait(out).unwrap();
        }
        Ok(())
    }

    /// Close once the pending write buffer has drained (keep-alive `close`
    /// responses, graceful peer shutdown).
    pub fn close_after_flush(&self) {
        let mut out = self.out.lock().unwrap();
        if out.bytes == 0 {
            drop(out);
            self.close();
        } else {
            out.close_after_flush = true;
        }
    }

    /// Close now, discarding any undelivered output.
    pub fn close(&self) {
        self.lh.post(Op::Close(self.token));
    }

    /// Drop read interest: the kernel socket buffer fills and TCP pushes
    /// back on the peer — backpressure without a parked thread.
    pub fn pause_reads(&self) {
        if !self.read_paused.swap(true, Ordering::AcqRel) {
            self.lh.post(Op::Interest(self.token));
        }
    }

    /// Re-arm read interest after [`ConnIo::pause_reads`].
    pub fn resume_reads(&self) {
        if self.read_paused.swap(false, Ordering::AcqRel) {
            self.lh.post(Op::Interest(self.token));
        }
    }

    /// Re-run the protocol's `on_data` against already-buffered input (a
    /// worker finished a request; pipelined bytes may be waiting).
    pub fn kick(&self) {
        self.lh.post(Op::Kick(self.token));
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Pending (unwritten) output bytes.
    pub fn buffered(&self) -> usize {
        self.out.lock().unwrap().bytes
    }
}

fn broken_pipe() -> io::Error {
    io::Error::new(io::ErrorKind::BrokenPipe, "connection closed")
}

struct Shared {
    loops: Vec<Arc<LoopHandle>>,
    next_loop: AtomicUsize,
    next_token: AtomicU64,
    open: AtomicUsize,
    max_connections: usize,
    write_buf_limit: usize,
    stats: Arc<ReactorStats>,
    metrics: Option<Arc<GetBatchMetrics>>,
    pool: WorkerPool,
}

impl Shared {
    fn register_stream(
        self: &Arc<Self>,
        stream: TcpStream,
        proto: Box<dyn ConnProto>,
    ) -> io::Result<Arc<ConnIo>> {
        let _ = stream.set_nodelay(true);
        stream.set_nonblocking(true)?;
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let idx = self.next_loop.fetch_add(1, Ordering::Relaxed) % self.loops.len();
        let io = Arc::new(ConnIo {
            token,
            lh: Arc::clone(&self.loops[idx]),
            out: Mutex::new(OutBuf::default()),
            cv: Condvar::new(),
            high_water: self.write_buf_limit,
            read_paused: AtomicBool::new(false),
            closed: AtomicBool::new(false),
            stats: Arc::clone(&self.stats),
        });
        let open = self.open.fetch_add(1, Ordering::Relaxed) + 1;
        self.stats.open_connections.add(1);
        self.stats.open_connections_peak.set_max(open as i64);
        if let Some(m) = &self.metrics {
            m.open_connections.add(1);
        }
        io.lh.post(Op::Register { stream, proto, io: Arc::clone(&io) });
        Ok(io)
    }
}

/// A running reactor: `threads` event loops plus the shared worker pool.
/// Dropping it stops the loops, closes every connection, and joins both
/// loop and worker threads.
pub struct Reactor {
    shared: Arc<Shared>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Reactor {
    pub fn new(cfg: ReactorConfig, name: &str) -> io::Result<Arc<Reactor>> {
        let nloops = cfg.threads.max(1);
        let mut loops = Vec::with_capacity(nloops);
        for _ in 0..nloops {
            loops.push(Arc::new(LoopHandle {
                ops: Mutex::new(Vec::new()),
                waker: Waker::new()?,
                stop: AtomicBool::new(false),
            }));
        }
        let shared = Arc::new(Shared {
            loops,
            next_loop: AtomicUsize::new(0),
            next_token: AtomicU64::new(0),
            open: AtomicUsize::new(0),
            max_connections: cfg.max_connections.max(1),
            write_buf_limit: cfg.write_buf_limit.max(1),
            stats: Arc::new(ReactorStats::default()),
            metrics: cfg.metrics,
            pool: WorkerPool::new(cfg.min_workers, name),
        });
        let mut threads = Vec::with_capacity(nloops);
        for i in 0..nloops {
            let sh = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{name}-reactor-{i}"))
                    .spawn(move || run_loop(sh, i))?,
            );
        }
        Ok(Arc::new(Reactor { shared, threads: Mutex::new(threads) }))
    }

    pub fn stats(&self) -> &Arc<ReactorStats> {
        &self.shared.stats
    }

    /// Run a (possibly blocking) job on the reactor's worker pool.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.pool.execute(job);
    }

    /// Clonable handle to the reactor's worker pool — what protocol
    /// factories capture (holding the reactor itself would be a cycle).
    pub fn worker_pool(&self) -> WorkerPool {
        self.shared.pool.clone()
    }

    /// Register a listener; accepted connections get a fresh [`ConnProto`]
    /// from `factory` and are distributed round-robin across loops.
    pub fn listen(&self, listener: TcpListener, factory: ProtoFactory) -> io::Result<()> {
        listener.set_nonblocking(true)?;
        let token = self.shared.next_token.fetch_add(1, Ordering::Relaxed);
        self.shared.loops[0].post(Op::Listen { listener, factory, token });
        Ok(())
    }

    /// Register an already-connected (client-side) stream.
    pub fn register(&self, stream: TcpStream, proto: Box<dyn ConnProto>) -> io::Result<Arc<ConnIo>> {
        self.shared.register_stream(stream, proto)
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        for lh in &self.shared.loops {
            lh.stop.store(true, Ordering::Release);
            lh.waker.wake();
        }
        for t in self.threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        self.shared.pool.shutdown();
    }
}

// --------------------------------------------------------- the event loop --

const TOK_WAKER: u64 = u64::MAX;
const READ_CHUNK: usize = 64 << 10;
/// Reads per readiness event before yielding back to the loop (epoll is
/// level-triggered; an unfinished socket re-fires).
const MAX_READS_PER_EVENT: usize = 4;

struct Conn {
    stream: TcpStream,
    proto: Box<dyn ConnProto>,
    io: Arc<ConnIo>,
    inbuf: Vec<u8>,
    interest: u32,
    eof: bool,
    eof_delivered: bool,
}

struct ListenerState {
    listener: TcpListener,
    factory: ProtoFactory,
}

fn run_loop(shared: Arc<Shared>, me: usize) {
    let lh = Arc::clone(&shared.loops[me]);
    let ep = match Epoll::new() {
        Ok(e) => e,
        Err(_) => return,
    };
    if ep.add(lh.waker.fd(), TOK_WAKER, sys::EPOLLIN).is_err() {
        return;
    }
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut listeners: HashMap<u64, ListenerState> = HashMap::new();
    let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; 512];
    while !lh.stop.load(Ordering::Acquire) {
        let n = match ep.wait(&mut events, 500) {
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        };
        shared.stats.wakeups.inc();
        if let Some(m) = &shared.metrics {
            m.reactor_wakeups.inc();
        }
        let ops = std::mem::take(&mut *lh.ops.lock().unwrap());
        for op in ops {
            apply_op(&shared, &ep, &mut conns, &mut listeners, op);
        }
        for ev in events.iter().take(n) {
            let copied = *ev;
            let (evs, token) = (copied.events, copied.data);
            if token == TOK_WAKER {
                lh.waker.drain();
            } else if let Some(l) = listeners.get(&token) {
                accept_ready(&shared, l);
            } else if conns.contains_key(&token) {
                conn_event(&shared, &ep, &mut conns, token, evs);
            }
        }
    }
    // Shutdown: release every connection so producers blocked in
    // send/flush observe `closed` and error out, then drop pending ops
    // (a not-yet-processed Register must still be accounted for).
    for (_, conn) in conns.drain() {
        release_conn(&shared, conn);
    }
    let ops = std::mem::take(&mut *lh.ops.lock().unwrap());
    for op in ops {
        if let Op::Register { io, mut proto, .. } = op {
            mark_closed(&shared, &io);
            proto.on_close();
        }
    }
}

fn apply_op(
    shared: &Arc<Shared>,
    ep: &Epoll,
    conns: &mut HashMap<u64, Conn>,
    listeners: &mut HashMap<u64, ListenerState>,
    op: Op,
) {
    match op {
        Op::Listen { listener, factory, token } => {
            if ep.add(listener.as_raw_fd(), token, sys::EPOLLIN).is_ok() {
                listeners.insert(token, ListenerState { listener, factory });
            }
        }
        Op::Register { stream, proto, io } => {
            let token = io.token;
            let mut conn = Conn {
                stream,
                proto,
                io,
                inbuf: Vec::new(),
                interest: 0,
                eof: false,
                eof_delivered: false,
            };
            let want = conn_interest(&conn);
            if ep.add(conn.stream.as_raw_fd(), token, want).is_err() {
                release_conn(shared, conn);
                return;
            }
            conn.interest = want;
            let io = Arc::clone(&conn.io);
            conn.proto.on_register(&io);
            conns.insert(token, conn);
        }
        Op::EnableWrite(token) => drain_writes(shared, ep, conns, token),
        Op::Interest(token) => {
            if let Some(conn) = conns.get_mut(&token) {
                update_interest(ep, conn);
            }
            // A read resume can also unblock parsing of buffered input.
            feed_proto(shared, ep, conns, token);
        }
        Op::Kick(token) => feed_proto(shared, ep, conns, token),
        Op::Close(token) => close_conn(shared, ep, conns, token),
    }
}

fn conn_interest(conn: &Conn) -> u32 {
    let mut ev = sys::EPOLLRDHUP;
    if !conn.io.read_paused.load(Ordering::Relaxed) && !conn.eof {
        ev |= sys::EPOLLIN;
    }
    if conn.io.out.lock().unwrap().bytes > 0 {
        ev |= sys::EPOLLOUT;
    }
    ev
}

fn update_interest(ep: &Epoll, conn: &mut Conn) {
    let want = conn_interest(conn);
    if want != conn.interest && ep.modify(conn.stream.as_raw_fd(), conn.io.token, want).is_ok() {
        conn.interest = want;
    }
}

fn accept_ready(shared: &Arc<Shared>, l: &ListenerState) {
    loop {
        match l.listener.accept() {
            Ok((stream, peer)) => {
                if shared.open.load(Ordering::Relaxed) >= shared.max_connections {
                    shared.stats.shed.inc();
                    if let Some(m) = &shared.metrics {
                        m.accept_backlog_shed.inc();
                    }
                    continue; // `stream` drops: the accept is shed
                }
                let proto = (l.factory)(peer);
                let _ = shared.register_stream(stream, proto);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}

fn conn_event(
    shared: &Arc<Shared>,
    ep: &Epoll,
    conns: &mut HashMap<u64, Conn>,
    token: u64,
    evs: u32,
) {
    if evs & sys::EPOLLERR != 0 {
        close_conn(shared, ep, conns, token);
        return;
    }
    if evs & sys::EPOLLOUT != 0 {
        drain_writes(shared, ep, conns, token);
        if !conns.contains_key(&token) {
            return;
        }
    }
    if evs & (sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLHUP) != 0 {
        read_ready(shared, ep, conns, token);
    }
}

fn read_ready(shared: &Arc<Shared>, ep: &Epoll, conns: &mut HashMap<u64, Conn>, token: u64) {
    let mut dead = false;
    if let Some(conn) = conns.get_mut(&token) {
        if !conn.io.read_paused.load(Ordering::Relaxed) && !conn.eof {
            let mut buf = [0u8; READ_CHUNK];
            for _ in 0..MAX_READS_PER_EVENT {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.eof = true;
                        break;
                    }
                    Ok(n) => {
                        conn.inbuf.extend_from_slice(&buf[..n]);
                        if n < buf.len() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
        }
    } else {
        return;
    }
    if dead {
        close_conn(shared, ep, conns, token);
        return;
    }
    feed_proto(shared, ep, conns, token);
}

fn feed_proto(shared: &Arc<Shared>, ep: &Epoll, conns: &mut HashMap<u64, Conn>, token: u64) {
    let (err, deliver_eof) = match conns.get_mut(&token) {
        Some(conn) => {
            let io = Arc::clone(&conn.io);
            let err = conn.proto.on_data(&mut conn.inbuf, &io).is_err();
            let deliver = !err && conn.eof && !conn.eof_delivered;
            if deliver {
                conn.eof_delivered = true;
            }
            (err, deliver)
        }
        None => return,
    };
    if err {
        close_conn(shared, ep, conns, token);
        return;
    }
    if deliver_eof {
        if let Some(conn) = conns.get_mut(&token) {
            let io = Arc::clone(&conn.io);
            conn.proto.on_eof(&io);
        }
    }
    if let Some(conn) = conns.get_mut(&token) {
        update_interest(ep, conn);
    }
}

fn drain_writes(shared: &Arc<Shared>, ep: &Epoll, conns: &mut HashMap<u64, Conn>, token: u64) {
    let (dead, close_after) = match conns.get_mut(&token) {
        Some(conn) => {
            let mut dead = false;
            let mut out = conn.io.out.lock().unwrap();
            while out.bytes > 0 {
                let n = match conn.stream.write(&out.queue[0][out.head_pos..]) {
                    Ok(n) => n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                };
                out.head_pos += n;
                out.bytes -= n;
                out.written += n as u64;
                if out.head_pos == out.queue[0].len() {
                    out.queue.pop_front();
                    out.head_pos = 0;
                }
            }
            let close_after = !dead && out.bytes == 0 && out.close_after_flush;
            conn.io.cv.notify_all();
            drop(out);
            if !dead && !close_after {
                update_interest(ep, conn);
            }
            (dead, close_after)
        }
        None => return,
    };
    if dead || close_after {
        close_conn(shared, ep, conns, token);
    }
}

fn close_conn(shared: &Arc<Shared>, ep: &Epoll, conns: &mut HashMap<u64, Conn>, token: u64) {
    if let Some(conn) = conns.remove(&token) {
        let _ = ep.del(conn.stream.as_raw_fd());
        release_conn(shared, conn);
    }
}

fn release_conn(shared: &Arc<Shared>, mut conn: Conn) {
    mark_closed(shared, &conn.io);
    conn.proto.on_close();
}

fn mark_closed(shared: &Arc<Shared>, io: &Arc<ConnIo>) {
    io.closed.store(true, Ordering::Release);
    let guard = io.out.lock().unwrap();
    io.cv.notify_all();
    drop(guard);
    shared.open.fetch_sub(1, Ordering::Relaxed);
    shared.stats.open_connections.sub(1);
    if let Some(m) = &shared.metrics {
        m.open_connections.sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestCounter;

    /// Echo protocol: every received byte is queued straight back.
    struct Echo;

    impl ConnProto for Echo {
        fn on_data(&mut self, inbuf: &mut Vec<u8>, io: &Arc<ConnIo>) -> io::Result<()> {
            if !inbuf.is_empty() {
                let data = std::mem::take(inbuf);
                // Tiny payloads stay far below the high-water mark, so this
                // send cannot block the loop thread in tests.
                io.send_vec(data)?;
            }
            Ok(())
        }
        fn on_eof(&mut self, io: &Arc<ConnIo>) {
            io.close_after_flush();
        }
    }

    fn echo_reactor(threads: usize) -> (Arc<Reactor>, String) {
        let r = Reactor::new(
            ReactorConfig { threads, ..Default::default() },
            "echo-test",
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        r.listen(listener, Arc::new(|_| Box::new(Echo))).unwrap();
        (r, addr)
    }

    #[test]
    fn echo_roundtrip() {
        let (r, addr) = echo_reactor(1);
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"hello reactor").unwrap();
        let mut got = [0u8; 13];
        s.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"hello reactor");
        assert_eq!(r.stats().open_connections.get(), 1);
        drop(s);
        drop(r);
    }

    #[test]
    fn many_connections_few_threads() {
        let (r, addr) = echo_reactor(2);
        let conns: Vec<TcpStream> =
            (0..64).map(|_| TcpStream::connect(&addr).unwrap()).collect();
        for (i, mut s) in conns.into_iter().enumerate() {
            let msg = format!("conn-{i}");
            s.write_all(msg.as_bytes()).unwrap();
            let mut got = vec![0u8; msg.len()];
            s.read_exact(&mut got).unwrap();
            assert_eq!(got, msg.as_bytes());
        }
        assert!(r.stats().open_connections_peak.get() >= 64);
    }

    #[test]
    fn close_unblocks_pending_senders() {
        let (r, addr) = echo_reactor(1);
        let s = TcpStream::connect(&addr).unwrap();
        // Let the accept propagate, then drop the whole reactor while the
        // client connection is still registered.
        let mut tries = 0;
        while r.stats().open_connections.get() == 0 && tries < 200 {
            std::thread::sleep(Duration::from_millis(5));
            tries += 1;
        }
        drop(r);
        drop(s);
    }

    #[test]
    fn worker_pool_runs_jobs_and_retires() {
        let pool = WorkerPool::new(1, "wp-test");
        let done = Arc::new(TestCounter::new(0));
        for _ in 0..50 {
            let d = Arc::clone(&done);
            pool.execute(move || {
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        let mut tries = 0;
        while done.load(Ordering::Relaxed) < 50 && tries < 400 {
            std::thread::sleep(Duration::from_millis(5));
            tries += 1;
        }
        assert_eq!(done.load(Ordering::Relaxed), 50);
        pool.shutdown();
        assert_eq!(pool.threads(), 0);
    }

    #[test]
    fn shed_over_max_connections() {
        let r = Reactor::new(
            ReactorConfig { threads: 1, max_connections: 2, ..Default::default() },
            "shed-test",
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        r.listen(listener, Arc::new(|_| Box::new(Echo))).unwrap();
        let mut live = Vec::new();
        for _ in 0..2 {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(b"x").unwrap();
            let mut b = [0u8; 1];
            s.read_exact(&mut b).unwrap();
            live.push(s);
        }
        // Third connection: accepted then immediately shed — the peer
        // observes EOF instead of an echo.
        let mut s3 = TcpStream::connect(&addr).unwrap();
        s3.write_all(b"y").unwrap();
        let mut b = [0u8; 1];
        assert_eq!(s3.read(&mut b).unwrap_or(0), 0, "shed connection closes");
        assert!(r.stats().shed.get() >= 1);
    }
}
