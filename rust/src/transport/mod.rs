//! Intra-cluster P2P transport: persistent, pooled target-to-target
//! connections carrying the frame protocol (§2.3.1: "a shared pool of
//! persistent peer-to-peer connections that are reused across requests and
//! operations, with idle connections reclaimed after a configurable
//! timeout").

pub mod pool;
pub mod reactor;

pub use pool::{P2pServer, PeerPool};
pub use reactor::{ConnIo, ConnProto, Reactor, ReactorConfig, ReactorStats, WorkerPool};
