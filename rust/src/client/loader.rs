//! Data loaders: the three access configurations of §4.1 over one manifest.
//!
//! 1. **Sequential I/O** — fetch whole shards, buffer samples, serve batches
//!    from the buffer (WebDataset-style; approximate randomness via shard
//!    order shuffling + a shuffle buffer over interleaved shards).
//! 2. **Random access (GET)** — sample anywhere, one request per sample
//!    (optionally concurrent); batch completion is gated by the slowest GET.
//! 3. **Batched random access (GetBatch)** — sample anywhere, retrieve the
//!    whole batch in a single request.
//!
//! Sampling (shuffling, size-bucketing, batch formation) stays client-side;
//! only the data access path differs — exactly the separation §2.5 draws.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::batch::request::{BatchEntry, BatchRequest};
use crate::util::rng::{mix64, Rng};
use crate::util::threadpool::scoped_map;

use super::prefetch::PrefetchPlanner;
use super::sdk::{Client, ClientError};

/// One sample's storage coordinates.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SampleRef {
    pub bucket: String,
    /// Shard object holding this sample, or `None` for standalone objects.
    pub shard: Option<String>,
    pub name: String,
    pub size: u64,
}

impl SampleRef {
    pub fn to_entry(&self) -> BatchEntry {
        match &self.shard {
            Some(s) => BatchEntry::member(&self.bucket, s, &self.name),
            None => BatchEntry::obj(&self.bucket, &self.name),
        }
    }
}

/// Dataset manifest: what exists and where (the training job's view).
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub samples: Vec<SampleRef>,
}

impl Manifest {
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Distinct shards referenced by the manifest, in first-seen order.
    pub fn shards(&self) -> Vec<(String, String)> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for s in &self.samples {
            if let Some(sh) = &s.shard {
                if seen.insert((s.bucket.clone(), sh.clone())) {
                    out.push((s.bucket.clone(), sh.clone()));
                }
            }
        }
        out
    }
}

/// A retrieved sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub data: Vec<u8>,
}

/// Timing of one batch load — feeds the Table 2 reproduction.
#[derive(Debug, Clone)]
pub struct BatchTiming {
    /// Wall time to retrieve all samples of the batch.
    pub batch: Duration,
    /// Per-object latencies (individual request times for RandomGet;
    /// effective per-sample time for Sequential/GetBatch).
    pub per_object: Vec<Duration>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessMode {
    Sequential,
    RandomGet,
    GetBatch,
}

impl AccessMode {
    pub fn parse(s: &str) -> Option<AccessMode> {
        match s {
            "seq" | "sequential" => Some(AccessMode::Sequential),
            "get" | "random" | "random-get" => Some(AccessMode::RandomGet),
            "getbatch" | "batch" => Some(AccessMode::GetBatch),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            AccessMode::Sequential => "Sequential I/O",
            AccessMode::RandomGet => "Random GET",
            AccessMode::GetBatch => "GetBatch",
        }
    }
}

/// Deterministic epoch-wide shuffle plan — the epoch pipeline's determinism
/// contract. Same `(seed, epoch, n_samples, batch_size)` ⇒ the identical
/// batch sequence on every client, with no coordination: distributed loader
/// workers agree on the global order by construction, and the prefetch
/// planner can *predict* the future access sequence instead of guessing.
///
/// The permutation is a seeded Fisher–Yates over `0..n_samples`, keyed by
/// `mix64(seed ^ mix64(epoch + 1))` so consecutive epochs draw independent
/// permutations from one training seed.
#[derive(Debug, Clone)]
pub struct EpochPlan {
    batches: Vec<Vec<usize>>,
    pub seed: u64,
    pub epoch: u64,
}

impl EpochPlan {
    pub fn new(n_samples: usize, batch_size: usize, seed: u64, epoch: u64) -> EpochPlan {
        let mut order: Vec<usize> = (0..n_samples).collect();
        let mut rng = Rng::new(mix64(seed ^ mix64(epoch.wrapping_add(1))));
        rng.shuffle(&mut order);
        let batches = order.chunks(batch_size.max(1)).map(|c| c.to_vec()).collect();
        EpochPlan { batches, seed, epoch }
    }

    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }

    /// Manifest indices of batch `i`, in serving order.
    pub fn batch(&self, i: usize) -> Option<&[usize]> {
        self.batches.get(i).map(|b| b.as_slice())
    }

    /// Batch indices owned by loader worker `rank` of `world`: `i ≡ rank
    /// (mod world)`. Every rank recomputes the same plan locally (the
    /// shuffle is seeded), so the split needs zero coordination, and the
    /// rank slices partition `0..n_batches()` exactly — the groundwork for
    /// distributed epoch sharding.
    pub fn rank_batches(&self, rank: usize, world: usize) -> Vec<usize> {
        let world = world.max(1);
        (rank..self.n_batches()).step_by(world).collect()
    }
}

/// Size-stratified sampler ("dynamic bucketing" à la Lhotse): manifest
/// indices are grouped into `n_buckets` by sample size; each batch draws
/// from a single bucket so padded batches stay dense.
pub struct BucketSampler {
    buckets: Vec<Vec<usize>>,
    rng: Rng,
}

impl BucketSampler {
    pub fn new(manifest: &Manifest, n_buckets: usize, seed: u64) -> BucketSampler {
        let mut idx: Vec<usize> = (0..manifest.len()).collect();
        idx.sort_by_key(|&i| manifest.samples[i].size);
        let n = idx.len().max(1);
        let per = n.div_ceil(n_buckets.max(1));
        let buckets: Vec<Vec<usize>> = idx.chunks(per).map(|c| c.to_vec()).collect();
        BucketSampler { buckets, rng: Rng::new(seed) }
    }

    /// Sample a batch of `k` indices from one random bucket (with
    /// replacement across batches, without within a batch).
    pub fn sample(&mut self, k: usize) -> Vec<usize> {
        let b = &self.buckets[self.rng.usize_below(self.buckets.len())];
        let k = k.min(b.len());
        let picks = self.rng.sample_indices(b.len(), k);
        picks.into_iter().map(|i| b[i]).collect()
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }
}

/// The data loader. One instance models one loader worker of §4.
pub struct DataLoader {
    client: Client,
    manifest: Manifest,
    pub mode: AccessMode,
    pub batch_size: usize,
    /// Concurrent GETs per batch in RandomGet mode (loader worker threads).
    pub get_concurrency: usize,
    /// Continue-on-error for GetBatch mode.
    pub coer: bool,
    /// Colocation hint for GetBatch mode.
    pub coloc: bool,
    sampler: BucketSampler,
    // Sequential-mode state: a shuffle buffer over interleaved shards.
    seq_buffer: Vec<Sample>,
    seq_shard_order: Vec<(String, String)>,
    seq_next_shard: usize,
    rng: Rng,
    // Epoch-pipeline state: the active deterministic plan, the demand
    // cursor, and the prefetch watermark (first batch index not yet handed
    // to the planner — guarantees each future batch is scheduled once).
    seed: u64,
    epoch_plan: Option<EpochPlan>,
    epoch_cursor: usize,
    pf_next: usize,
    prefetch: Option<Arc<PrefetchPlanner>>,
}

impl DataLoader {
    pub fn new(client: Client, manifest: Manifest, mode: AccessMode, batch_size: usize, seed: u64) -> DataLoader {
        let sampler = BucketSampler::new(&manifest, 4, seed ^ 0xB0C4);
        let mut rng = Rng::new(seed);
        let mut seq_shard_order = manifest.shards();
        rng.shuffle(&mut seq_shard_order);
        DataLoader {
            client,
            manifest,
            mode,
            batch_size,
            get_concurrency: 16,
            coer: false,
            coloc: false,
            sampler,
            seq_buffer: Vec::new(),
            seq_shard_order,
            seq_next_shard: 0,
            rng,
            seed,
            epoch_plan: None,
            epoch_cursor: 0,
            pf_next: 0,
            prefetch: None,
        }
    }

    /// Attach a prefetch planner: while batch N of an epoch streams, the
    /// planner warms the objects of batches N+1..N+`horizon` into the
    /// cluster's cache tier (`horizon` = sanitized `prefetch_batches`).
    pub fn attach_prefetch(&mut self, planner: Arc<PrefetchPlanner>) {
        self.prefetch = Some(planner);
    }

    /// Install the deterministic plan for `epoch` and rewind the cursor.
    /// Every loader sharing `(manifest, batch_size, seed)` that calls this
    /// with the same `epoch` will serve byte-identical batch sequences.
    pub fn begin_epoch(&mut self, epoch: u64) {
        self.epoch_plan =
            Some(EpochPlan::new(self.manifest.len(), self.batch_size, self.seed, epoch));
        self.epoch_cursor = 0;
        self.pf_next = 1; // batch 0 is always demand-fetched
        if let Some(p) = &self.prefetch {
            p.reset();
        }
    }

    pub fn epoch_plan(&self) -> Option<&EpochPlan> {
        self.epoch_plan.as_ref()
    }

    fn refs_of_batch(&self, i: usize) -> Vec<SampleRef> {
        self.epoch_plan
            .as_ref()
            .and_then(|p| p.batch(i))
            .map(|idxs| idxs.iter().map(|&s| self.manifest.samples[s].clone()).collect())
            .unwrap_or_default()
    }

    /// Serve the next batch of the active epoch (`begin_epoch` first);
    /// `Ok(None)` once the epoch is exhausted. Order of operations is the
    /// planner's pipeline: mark the current batch demand-in-flight, hand
    /// the *future* window to the prefetch workers, then fetch — so the
    /// cache warms for batch N+1 while batch N streams.
    pub fn next_epoch_batch(&mut self) -> Result<Option<(Vec<Sample>, BatchTiming)>, ClientError> {
        let n_batches = match &self.epoch_plan {
            Some(p) => p.n_batches(),
            None => return Ok(None),
        };
        if self.epoch_cursor >= n_batches {
            return Ok(None);
        }
        let cur = self.epoch_cursor;
        let refs = self.refs_of_batch(cur);
        if let Some(planner) = self.prefetch.clone() {
            planner.mark_demand(&refs);
            let last = (cur + planner.horizon()).min(n_batches.saturating_sub(1));
            let start = self.pf_next.max(cur + 1);
            for i in start..=last {
                let future = self.refs_of_batch(i);
                planner.schedule(&future);
            }
            self.pf_next = self.pf_next.max(last + 1);
        }
        let result = self.fetch_refs(&refs);
        if let Some(planner) = &self.prefetch {
            planner.unmark_demand(&refs);
        }
        self.epoch_cursor += 1;
        result.map(Some)
    }

    /// Fetch exactly `refs` (plan order) via the loader's access mode.
    /// Output names are normalized to the manifest's sample names so the
    /// served byte sequence is mode-independent — the determinism contract
    /// holds across Sequential, RandomGet, and GetBatch.
    fn fetch_refs(&self, refs: &[SampleRef]) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        match self.mode {
            AccessMode::Sequential => self.fetch_refs_sequential(refs),
            AccessMode::RandomGet => self.fetch_refs_random(refs),
            AccessMode::GetBatch => self.fetch_refs_getbatch(refs),
        }
    }

    fn fetch_refs_random(&self, refs: &[SampleRef]) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        let t0 = Instant::now();
        let client = &self.client;
        let results: Vec<Result<(Sample, Duration), ClientError>> =
            scoped_map(refs, self.get_concurrency, |_, r| {
                let t = Instant::now();
                let data = match &r.shard {
                    Some(sh) => client.get_member(&r.bucket, sh, &r.name)?,
                    None => client.get(&r.bucket, &r.name)?,
                };
                Ok((Sample { name: r.name.clone(), data }, t.elapsed()))
            });
        let batch = t0.elapsed();
        let mut samples = Vec::with_capacity(refs.len());
        let mut per_object = Vec::with_capacity(refs.len());
        for r in results {
            let (s, d) = r?;
            samples.push(s);
            per_object.push(d);
        }
        Ok((samples, BatchTiming { batch, per_object }))
    }

    fn fetch_refs_getbatch(&self, refs: &[SampleRef]) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        let entries: Vec<BatchEntry> = refs.iter().map(|r| r.to_entry()).collect();
        let req = BatchRequest::new(entries).continue_on_err(self.coer).colocation(self.coloc);
        let t0 = Instant::now();
        let items = self.client.get_batch_collect(&req)?;
        let batch = t0.elapsed();
        // Delivery is ordered (§2.3.1): item i is refs[i]. Rename from the
        // wire's "shard/member" output names to the manifest names.
        let mut samples = Vec::with_capacity(refs.len());
        for (r, it) in refs.iter().zip(items) {
            match it {
                crate::batch::reader::BatchItem::Ok { data, .. } => {
                    samples.push(Sample { name: r.name.clone(), data })
                }
                crate::batch::reader::BatchItem::Missing { name } => {
                    return Err(ClientError::Status {
                        status: 404,
                        msg: format!("missing in batch: {name}"),
                    })
                }
            }
        }
        let k = samples.len();
        let per = if k > 0 { batch / k as u32 } else { batch };
        Ok((samples, BatchTiming { batch, per_object: vec![per; k] }))
    }

    fn fetch_refs_sequential(&self, refs: &[SampleRef]) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        let t0 = Instant::now();
        // Sequential I/O's unit of transfer is the shard: one whole-shard
        // GET per distinct shard of the batch, member extraction client-side.
        let mut shard_members: HashMap<(String, String), HashMap<String, Vec<u8>>> =
            HashMap::new();
        for r in refs {
            if let Some(sh) = &r.shard {
                let key = (r.bucket.clone(), sh.clone());
                if !shard_members.contains_key(&key) {
                    let bytes = self.client.get(&r.bucket, sh)?;
                    let members = crate::tar::read_archive(&bytes)
                        .map_err(ClientError::Tar)?
                        .into_iter()
                        .map(|e| (e.name, e.data))
                        .collect();
                    shard_members.insert(key, members);
                }
            }
        }
        let mut samples = Vec::with_capacity(refs.len());
        for r in refs {
            let data = match &r.shard {
                Some(sh) => shard_members
                    .get(&(r.bucket.clone(), sh.clone()))
                    .and_then(|m| m.get(&r.name))
                    .cloned()
                    .ok_or_else(|| ClientError::Status {
                        status: 404,
                        msg: format!("member {} not in shard {sh}", r.name),
                    })?,
                None => self.client.get(&r.bucket, &r.name)?,
            };
            samples.push(Sample { name: r.name.clone(), data });
        }
        let batch = t0.elapsed();
        let k = samples.len();
        let per = if k > 0 { batch / k as u32 } else { batch };
        Ok((samples, BatchTiming { batch, per_object: vec![per; k] }))
    }

    /// Load the next batch, returning samples + timing.
    pub fn next_batch(&mut self) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        match self.mode {
            AccessMode::Sequential => self.next_sequential(),
            AccessMode::RandomGet => self.next_random_get(),
            AccessMode::GetBatch => self.next_getbatch(),
        }
    }

    // -- sequential shard I/O ----------------------------------------------
    fn refill_seq_buffer(&mut self) -> Result<Duration, ClientError> {
        let mut dl_time = Duration::ZERO;
        // Interleave two shards per refill to improve randomness (§1, Fig 1a).
        for _ in 0..2 {
            if self.seq_shard_order.is_empty() {
                break;
            }
            let (bucket, shard) = self.seq_shard_order[self.seq_next_shard % self.seq_shard_order.len()].clone();
            self.seq_next_shard += 1;
            let t0 = Instant::now();
            let bytes = self.client.get(&bucket, &shard)?;
            dl_time += t0.elapsed();
            for e in crate::tar::read_archive(&bytes)
                .map_err(ClientError::Tar)?
            {
                self.seq_buffer.push(Sample { name: e.name, data: e.data });
            }
        }
        // Shuffle buffer: the approximate-randomness mechanism.
        let n = self.seq_buffer.len();
        for i in (1..n).rev() {
            let j = self.rng.usize_below(i + 1);
            self.seq_buffer.swap(i, j);
        }
        Ok(dl_time)
    }

    fn next_sequential(&mut self) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        let t0 = Instant::now();
        while self.seq_buffer.len() < self.batch_size {
            self.refill_seq_buffer()?;
            if self.seq_shard_order.is_empty() {
                break;
            }
        }
        let k = self.batch_size.min(self.seq_buffer.len());
        let samples: Vec<Sample> = self.seq_buffer.drain(..k).collect();
        let batch = t0.elapsed();
        // Per-object: amortized read-from-open-stream time (the paper notes
        // this is not directly comparable to per-request latencies).
        let per = if k > 0 { batch / k as u32 } else { batch };
        Ok((samples, BatchTiming { batch, per_object: vec![per; k] }))
    }

    // -- random access: one GET per sample ----------------------------------
    fn next_random_get(&mut self) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        let picks = self.sampler.sample(self.batch_size);
        let refs: Vec<SampleRef> = picks.iter().map(|&i| self.manifest.samples[i].clone()).collect();
        let t0 = Instant::now();
        let client = &self.client;
        let results: Vec<Result<(Sample, Duration), ClientError>> =
            scoped_map(&refs, self.get_concurrency, |_, r| {
                let t = Instant::now();
                let data = match &r.shard {
                    Some(sh) => client.get_member(&r.bucket, sh, &r.name)?,
                    None => client.get(&r.bucket, &r.name)?,
                };
                Ok((Sample { name: r.name.clone(), data }, t.elapsed()))
            });
        let batch = t0.elapsed();
        let mut samples = Vec::with_capacity(refs.len());
        let mut per_object = Vec::with_capacity(refs.len());
        for r in results {
            let (s, d) = r?;
            samples.push(s);
            per_object.push(d);
        }
        Ok((samples, BatchTiming { batch, per_object }))
    }

    // -- batched random access: one GetBatch per batch -----------------------
    fn next_getbatch(&mut self) -> Result<(Vec<Sample>, BatchTiming), ClientError> {
        let picks = self.sampler.sample(self.batch_size);
        let entries: Vec<BatchEntry> =
            picks.iter().map(|&i| self.manifest.samples[i].to_entry()).collect();
        let req = BatchRequest::new(entries).continue_on_err(self.coer).colocation(self.coloc);
        let t0 = Instant::now();
        let items = self.client.get_batch_collect(&req)?;
        let batch = t0.elapsed();
        let k = items.len();
        let samples = items
            .into_iter()
            .filter_map(|it| match it {
                crate::batch::reader::BatchItem::Ok { name, data } => Some(Sample { name, data }),
                crate::batch::reader::BatchItem::Missing { .. } => None,
            })
            .collect();
        let per = if k > 0 { batch / k as u32 } else { batch };
        Ok((samples, BatchTiming { batch, per_object: vec![per; k] }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::Cluster;
    use crate::config::ClusterConfig;
    use crate::tar::{write_archive, Entry};

    /// Stage a sharded synthetic dataset: `n_shards` shards × `per_shard`
    /// members with varying sizes.
    pub fn stage(c: &Cluster, n_shards: usize, per_shard: usize) -> Manifest {
        let mut manifest = Manifest::default();
        for s in 0..n_shards {
            let entries: Vec<Entry> = (0..per_shard)
                .map(|i| Entry {
                    name: format!("utt-{s:03}-{i:03}.wav"),
                    data: vec![(s * per_shard + i) as u8; 100 + (i % 7) * 200],
                })
                .collect();
            let shard_name = format!("shard-{s:05}.tar");
            c.put_direct("audio", &shard_name, &write_archive(&entries).unwrap()).unwrap();
            for e in &entries {
                manifest.samples.push(SampleRef {
                    bucket: "audio".into(),
                    shard: Some(shard_name.clone()),
                    name: e.name.clone(),
                    size: e.data.len() as u64,
                });
            }
        }
        manifest
    }

    fn cluster() -> Cluster {
        Cluster::start(ClusterConfig { targets: 3, http_workers: 4, ..Default::default() }).unwrap()
    }

    #[test]
    fn all_three_modes_deliver_batches() {
        let c = cluster();
        let manifest = stage(&c, 6, 10);
        for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
            let cl = Client::new(&c.proxy_addr());
            let mut dl = DataLoader::new(cl, manifest.clone(), mode, 8, 42);
            for step in 0..3 {
                let (samples, timing) = dl.next_batch().unwrap();
                assert_eq!(samples.len(), 8, "{mode:?} step {step}");
                assert!(samples.iter().all(|s| !s.data.is_empty()));
                assert!(timing.batch > Duration::ZERO);
                assert_eq!(timing.per_object.len(), 8);
            }
        }
    }

    #[test]
    fn bucket_sampler_stratifies_by_size() {
        let c = cluster();
        let manifest = stage(&c, 4, 12);
        let mut s = BucketSampler::new(&manifest, 4, 7);
        for _ in 0..20 {
            let batch = s.sample(6);
            let sizes: Vec<u64> = batch.iter().map(|&i| manifest.samples[i].size).collect();
            let spread = sizes.iter().max().unwrap() - sizes.iter().min().unwrap();
            // within one size bucket the spread is bounded (sizes are
            // 100..1300 in 7 steps of 200 → bucket spread < full range)
            assert!(spread < 1200, "sizes={sizes:?}");
        }
    }

    #[test]
    fn getbatch_loader_uses_one_request_per_batch() {
        let c = cluster();
        let manifest = stage(&c, 4, 8);
        let cl = Client::new(&c.proxy_addr());
        let mut dl = DataLoader::new(cl.clone(), manifest, AccessMode::GetBatch, 16, 1);
        dl.next_batch().unwrap();
        dl.next_batch().unwrap();
        let total_dt: f64 = c
            .targets
            .iter()
            .map(|t| {
                let text = cl.metrics(&t.info.http_addr).unwrap();
                crate::metrics::GetBatchMetrics::parse(&text)["ais_getbatch_dt_requests_total"]
            })
            .sum();
        assert_eq!(total_dt, 2.0, "exactly one DT execution per batch");
    }

    #[test]
    fn sequential_mode_reads_whole_shards() {
        let c = cluster();
        let manifest = stage(&c, 3, 10);
        let cl = Client::new(&c.proxy_addr());
        let mut dl = DataLoader::new(cl, manifest, AccessMode::Sequential, 5, 3);
        let (s1, _) = dl.next_batch().unwrap();
        let (s2, _) = dl.next_batch().unwrap();
        // 2 shards interleaved = 20 samples buffered; two batches of 5 come
        // from the buffer without re-download
        assert_eq!(s1.len() + s2.len(), 10);
        let names: std::collections::HashSet<_> =
            s1.iter().chain(&s2).map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 10, "no duplicates from the shuffle buffer");
    }

    #[test]
    fn manifest_shards_unique() {
        let c = cluster();
        let m = stage(&c, 5, 4);
        assert_eq!(m.shards().len(), 5);
        assert_eq!(m.len(), 20);
    }

    #[test]
    fn epoch_plan_is_a_permutation() {
        let p = EpochPlan::new(103, 8, 9, 4);
        assert_eq!(p.n_batches(), 13);
        let mut seen: Vec<usize> =
            (0..p.n_batches()).flat_map(|i| p.batch(i).unwrap().to_vec()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..103).collect::<Vec<_>>(), "every sample exactly once");
        // Same inputs ⇒ the identical plan, independent of construction site.
        let q = EpochPlan::new(103, 8, 9, 4);
        for i in 0..p.n_batches() {
            assert_eq!(p.batch(i), q.batch(i));
        }
        assert!(p.batch(13).is_none());
    }

    /// Satellite: the determinism regression. Two loaders with the same
    /// seed produce byte-identical epoch batch sequences in **all three**
    /// access modes; a different seed produces a different permutation.
    #[test]
    fn epoch_sequence_deterministic_across_modes_and_loaders() {
        let c = cluster();
        let manifest = stage(&c, 4, 8); // 32 samples, batch 5 ⇒ 7 batches
        let mut canonical: Option<Vec<Vec<(String, Vec<u8>)>>> = None;
        for mode in [AccessMode::Sequential, AccessMode::RandomGet, AccessMode::GetBatch] {
            for run in 0..2 {
                let cl = Client::new(&c.proxy_addr());
                let mut dl = DataLoader::new(cl, manifest.clone(), mode, 5, 1234);
                dl.begin_epoch(0);
                let mut seq = Vec::new();
                while let Some((samples, timing)) = dl.next_epoch_batch().unwrap() {
                    assert_eq!(timing.per_object.len(), samples.len());
                    seq.push(
                        samples.into_iter().map(|s| (s.name, s.data)).collect::<Vec<_>>(),
                    );
                }
                assert_eq!(seq.len(), 7, "{mode:?} run {run}");
                match &canonical {
                    None => canonical = Some(seq),
                    Some(c0) => assert_eq!(&seq, c0, "{mode:?} run {run} diverges"),
                }
            }
        }
        // Different seed (or epoch) ⇒ different permutation.
        let flat = |p: &EpochPlan| {
            (0..p.n_batches()).flat_map(|i| p.batch(i).unwrap().to_vec()).collect::<Vec<_>>()
        };
        let base = EpochPlan::new(32, 5, 1234, 0);
        assert_ne!(flat(&base), flat(&EpochPlan::new(32, 5, 4321, 0)), "seed");
        assert_ne!(flat(&base), flat(&EpochPlan::new(32, 5, 1234, 1)), "epoch");
    }
}
