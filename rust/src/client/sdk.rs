//! Client SDK (§2.5): batch retrieval as a single logical operation.
//! Sampling stays caller-side; the SDK only moves data. Mirrors the AIStore
//! Python SDK's `client.batch(...)` + ordered iteration pattern (Listing 1).

use std::io;
use std::time::{Duration, Instant};

use crate::batch::reader::{BatchItem, BatchReader};
use crate::batch::request::BatchRequest;
use crate::proto::http::{BodyReader, HttpClient};
use crate::proto::wire::{self, paths};

/// Handle to a cluster via one gateway address.
#[derive(Clone)]
pub struct Client {
    http: HttpClient,
    proxy: String,
    /// Multi-tenant QoS identity sent as `x-getbatch-tenant` on batch
    /// requests; `None` means the cluster's default tenant.
    tenant: Option<String>,
    /// Priority class (`interactive` / `batch` / `bulk`) sent as
    /// `x-getbatch-priority`; `None` means the cluster default.
    priority: Option<String>,
}

#[derive(Debug)]
pub enum ClientError {
    Status { status: u16, msg: String },
    Io(io::Error),
    Tar(crate::tar::TarError),
}

crate::impl_error! {
    ClientError {
        display {
            ClientError::Status { status, msg } => "http {status}: {msg}",
            ClientError::Io(e) => "io: {e}",
            ClientError::Tar(e) => "tar: {e}",
        }
        source {
            ClientError::Io(e) => e,
            ClientError::Tar(e) => e,
        }
        from {
            io::Error => Io,
            crate::tar::TarError => Tar,
        }
    }
}

/// Per-call latency instrumentation: the paper's measurement definition —
/// "total time from when the client issues a request until all requested
/// bytes are received" (§4.2.1).
#[derive(Debug, Clone, Copy)]
pub struct FetchStats {
    pub total: Duration,
    /// Time to first byte of payload (streaming benefit).
    pub ttfb: Duration,
    pub bytes: u64,
    pub items: u32,
}

impl Client {
    pub fn new(proxy_addr: &str) -> Client {
        Client {
            http: HttpClient::new(true),
            proxy: proxy_addr.to_string(),
            tenant: None,
            priority: None,
        }
    }

    /// Per-request connection mode (no keep-alive) — the cold-connection
    /// baseline for ablations.
    pub fn without_reuse(proxy_addr: &str) -> Client {
        Client {
            http: HttpClient::new(false),
            proxy: proxy_addr.to_string(),
            tenant: None,
            priority: None,
        }
    }

    /// Inject artificial RTT per request hop (models datacenter distance).
    pub fn with_rtt(mut self, rtt: Duration) -> Client {
        self.http = self.http.with_rtt(rtt);
        self
    }

    /// Identify this client's batch traffic as `tenant` (fair-share
    /// admission groups by this identity).
    pub fn with_tenant(mut self, tenant: &str) -> Client {
        self.tenant = Some(tenant.to_string());
        self
    }

    /// Priority class for this client's batch traffic: `interactive`,
    /// `batch`, or `bulk` (load shedding drops lowest class first).
    pub fn with_priority(mut self, priority: &str) -> Client {
        self.priority = Some(priority.to_string());
        self
    }

    pub fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), ClientError> {
        let resp = self.http.put(&self.proxy, &wire::object_path(bucket, obj), data)?;
        if resp.status != 200 {
            return Err(status_err(resp));
        }
        let _ = resp.into_bytes();
        Ok(())
    }

    /// Single-object GET (the paper's baseline: one request per sample).
    pub fn get(&self, bucket: &str, obj: &str) -> Result<Vec<u8>, ClientError> {
        let resp = self.http.get(&self.proxy, &wire::object_path(bucket, obj))?;
        if resp.status != 200 {
            return Err(status_err(resp));
        }
        Ok(resp.into_bytes()?)
    }

    /// GET one member out of a TAR shard (random access baseline over
    /// sharded datasets — AIStore's archive API).
    pub fn get_member(&self, bucket: &str, shard: &str, member: &str) -> Result<Vec<u8>, ClientError> {
        let pq = format!("{}?archpath={member}", wire::object_path(bucket, shard));
        let resp = self.http.get(&self.proxy, &pq)?;
        if resp.status != 200 {
            return Err(status_err(resp));
        }
        Ok(resp.into_bytes()?)
    }

    /// Issue a GetBatch request; returns the ordered streaming reader.
    pub fn get_batch(&self, req: &BatchRequest) -> Result<BatchReader<BodyReader>, ClientError> {
        let mut pq = paths::BATCH.to_string();
        if req.opts.colocation {
            pq.push_str(&format!("?{}=true", wire::QPARAM_COLOC));
        }
        // QoS identity headers (preserved across the 307 redirect to the
        // DT's stream endpoint); legacy clients simply send none.
        let mut headers: Vec<(&str, &str)> = Vec::new();
        if let Some(t) = &self.tenant {
            headers.push((wire::HDR_TENANT, t.as_str()));
        }
        if let Some(p) = &self.priority {
            headers.push((wire::HDR_PRIORITY, p.as_str()));
        }
        let resp =
            self.http.request_with_headers("GET", &self.proxy, &pq, &headers, &req.to_body())?;
        if resp.status != 200 {
            return Err(status_err(resp));
        }
        Ok(BatchReader::new(resp.body))
    }

    /// GetBatch, fully collected, with client-observed latency stats.
    pub fn get_batch_timed(&self, req: &BatchRequest) -> Result<(Vec<BatchItem>, FetchStats), ClientError> {
        let t0 = Instant::now();
        let mut reader = self.get_batch(req)?;
        let mut items = Vec::with_capacity(req.entries.len());
        let mut ttfb = None;
        let mut bytes = 0u64;
        while let Some(item) = reader.next_item()? {
            if ttfb.is_none() {
                ttfb = Some(t0.elapsed());
            }
            bytes += item.data().map(|d| d.len() as u64).unwrap_or(0);
            items.push(item);
        }
        let total = t0.elapsed();
        let stats =
            FetchStats { total, ttfb: ttfb.unwrap_or(total), bytes, items: items.len() as u32 };
        Ok((items, stats))
    }

    /// Convenience: collect without stats.
    pub fn get_batch_collect(&self, req: &BatchRequest) -> Result<Vec<BatchItem>, ClientError> {
        Ok(self.get_batch(req)?.collect_all()?)
    }

    /// Ask the cluster to warm an object's chunks into the cache tier of
    /// its HRW owner target ahead of a predicted read (the epoch batch
    /// planner's transport). `horizon` is observability only — it surfaces
    /// the planner's configured `prefetch_batches` on the serving node's
    /// gauge. Returns the number of cache chunks admitted (0 when the
    /// bucket is uncached or the object was already warm).
    pub fn prefetch(&self, bucket: &str, obj: &str, horizon: usize) -> Result<u64, ClientError> {
        let pq = format!(
            "{}?bucket={bucket}&obj={obj}&horizon={horizon}",
            paths::PREFETCH
        );
        let resp = self.http.request("POST", &self.proxy, &pq, &[])?;
        if resp.status != 200 {
            return Err(status_err(resp));
        }
        let body = resp.into_bytes()?;
        Ok(String::from_utf8_lossy(&body).trim().parse().unwrap_or(0))
    }

    /// Scrape a node's Prometheus exposition.
    pub fn metrics(&self, node_addr: &str) -> Result<String, ClientError> {
        let resp = self.http.get(node_addr, paths::METRICS)?;
        if resp.status != 200 {
            return Err(status_err(resp));
        }
        Ok(String::from_utf8_lossy(&resp.into_bytes()?).into_owned())
    }

    pub fn proxy_addr(&self) -> &str {
        &self.proxy
    }
}

fn status_err(resp: crate::proto::http::ClientResponse) -> ClientError {
    let status = resp.status;
    let msg = resp
        .into_bytes()
        .ok()
        .map(|b| String::from_utf8_lossy(&b).into_owned())
        .unwrap_or_default();
    ClientError::Status { status, msg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::request::BatchEntry;
    use crate::cluster::node::Cluster;
    use crate::config::ClusterConfig;

    fn cluster() -> Cluster {
        Cluster::start(ClusterConfig { targets: 3, http_workers: 4, ..Default::default() }).unwrap()
    }

    #[test]
    fn sdk_object_roundtrip() {
        let c = cluster();
        let cl = Client::new(&c.proxy_addr());
        cl.put("b", "k", b"v").unwrap();
        assert_eq!(cl.get("b", "k").unwrap(), b"v");
        match cl.get("b", "absent") {
            Err(ClientError::Status { status: 404, .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sdk_member_get() {
        let c = cluster();
        let cl = Client::new(&c.proxy_addr());
        let shard = crate::tar::write_archive(&[
            crate::tar::Entry { name: "a".into(), data: vec![1; 5] },
            crate::tar::Entry { name: "b".into(), data: vec![2; 9] },
        ])
        .unwrap();
        cl.put("bk", "s.tar", &shard).unwrap();
        assert_eq!(cl.get_member("bk", "s.tar", "b").unwrap(), vec![2; 9]);
    }

    #[test]
    fn sdk_batch_with_stats() {
        let c = cluster();
        let cl = Client::new(&c.proxy_addr());
        for i in 0..16 {
            cl.put("b", &format!("o{i}"), &vec![i as u8; 1000]).unwrap();
        }
        let req =
            BatchRequest::new((0..16).map(|i| BatchEntry::obj("b", &format!("o{i}"))).collect());
        let (items, stats) = cl.get_batch_timed(&req).unwrap();
        assert_eq!(items.len(), 16);
        assert_eq!(stats.items, 16);
        assert_eq!(stats.bytes, 16_000);
        assert!(stats.ttfb <= stats.total);
    }

    #[test]
    fn sdk_batch_multi_bucket_join() {
        // §2.2: one request spanning buckets — composite samples without
        // client-side joins.
        let c = cluster();
        let cl = Client::new(&c.proxy_addr());
        cl.put("features", "x", b"feat").unwrap();
        cl.put("labels", "x", b"lab").unwrap();
        let req = BatchRequest::new(vec![
            BatchEntry::obj("features", "x"),
            BatchEntry::obj("labels", "x"),
        ]);
        let items = cl.get_batch_collect(&req).unwrap();
        assert_eq!(items[0].data().unwrap(), b"feat");
        assert_eq!(items[1].data().unwrap(), b"lab");
    }

    #[test]
    fn sdk_metrics_scrape() {
        let c = cluster();
        let cl = Client::new(&c.proxy_addr());
        cl.put("b", "o", b"x").unwrap();
        let req = BatchRequest::new(vec![BatchEntry::obj("b", "o")]);
        cl.get_batch_collect(&req).unwrap();
        // some target acted as DT
        let total_dt: f64 = c
            .targets
            .iter()
            .map(|t| {
                let text = cl.metrics(&t.info.http_addr).unwrap();
                crate::metrics::GetBatchMetrics::parse(&text)["ais_getbatch_dt_requests_total"]
            })
            .sum();
        assert_eq!(total_dt, 1.0);
    }
}
