//! The epoch batch planner's prefetch engine: background workers that warm
//! future batches' objects into the cluster's cache tier while the current
//! batch streams to the trainer (the compute/IO-overlap win the WPI
//! cloud-storage study quantifies).
//!
//! The planner is deliberately dumb about *what* to prefetch — the
//! deterministic [`EpochPlan`](super::loader::EpochPlan) already knows the
//! future access sequence, so the loader hands it the exact objects of
//! batches N+1..N+`prefetch_batches`. What the planner owns is *how*:
//!
//! - a small worker pool (the `readahead_workers` pattern from the store's
//!   page-cache warmers) issues `POST /v1/prefetch` calls off the demand
//!   path, so a slow prefetch can never delay the batch being served;
//! - object-level dedup: each object is issued at most once per epoch, and
//!   objects currently held by an in-flight *demand* read are skipped —
//!   the demand fill is already warming them;
//! - failures are dropped on the floor (a missed prefetch costs the warm
//!   hit, never correctness — the demand read just fills cold).
//!
//! Memory: prefetched chunks land in the target-side chunk cache and
//! reserve against `cache_bytes` only (pin-aware admission, see
//! `store::cache`) — never against `dt_buffer_bytes`.

use std::collections::HashSet;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::threadpool::ThreadPool;

use super::loader::SampleRef;
use super::sdk::Client;

/// One object's prefetch coordinates: `(bucket, object)`, where the object
/// is the shard archive for sharded samples (members share the shard's
/// chunks, so warming the shard warms every member).
type ObjKey = (String, String);

fn key_of(r: &SampleRef) -> ObjKey {
    match &r.shard {
        Some(s) => (r.bucket.clone(), s.clone()),
        None => (r.bucket.clone(), r.name.clone()),
    }
}

#[derive(Default)]
struct PlannerState {
    /// Objects already issued this epoch (prefetch is idempotent
    /// server-side, but re-issuing is pure waste).
    issued: HashSet<ObjKey>,
    /// Objects currently held by an in-flight demand read of the loader —
    /// their demand fill is already warming the cache.
    demand: HashSet<ObjKey>,
    /// Prefetch calls handed to the pool and not yet completed.
    inflight: usize,
}

/// Background prefetch scheduler, shared between a loader and its worker
/// pool. Construct once per training job and attach with
/// [`DataLoader::attach_prefetch`](super::loader::DataLoader::attach_prefetch).
pub struct PrefetchPlanner {
    client: Client,
    /// Batches ahead the loader schedules (`prefetch_batches`, sanitized).
    horizon: usize,
    pool: ThreadPool,
    state: Mutex<PlannerState>,
    idle: Condvar,
    /// Prefetch calls issued / calls that failed (observability; the
    /// cluster-side counters are the source of truth for fills).
    pub issued: crate::metrics::Counter,
    pub failed: crate::metrics::Counter,
}

impl PrefetchPlanner {
    /// `horizon` = how many future batches to warm (0 disables scheduling
    /// entirely); `workers` = background call concurrency.
    pub fn new(client: Client, horizon: usize, workers: usize) -> std::sync::Arc<PrefetchPlanner> {
        std::sync::Arc::new(PrefetchPlanner {
            client,
            horizon,
            pool: ThreadPool::new(workers.max(1), "prefetch"),
            state: Mutex::new(PlannerState::default()),
            idle: Condvar::new(),
            issued: Default::default(),
            failed: Default::default(),
        })
    }

    pub fn horizon(&self) -> usize {
        self.horizon
    }

    /// Forget the epoch's dedup state (call between epochs: the next epoch
    /// legitimately re-touches the same objects).
    pub fn reset(&self) {
        self.state.lock().unwrap().issued.clear();
    }

    /// Queue prefetch calls for every not-yet-issued, not-in-demand object
    /// of `refs`. Returns the number of objects actually queued.
    pub fn schedule(self: &std::sync::Arc<Self>, refs: &[SampleRef]) -> usize {
        if self.horizon == 0 || refs.is_empty() {
            return 0;
        }
        let mut fresh: Vec<ObjKey> = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            for r in refs {
                let k = key_of(r);
                if st.demand.contains(&k) || !st.issued.insert(k.clone()) {
                    continue;
                }
                fresh.push(k);
            }
            st.inflight += fresh.len();
        }
        let n = fresh.len();
        for (bucket, obj) in fresh {
            let me = std::sync::Arc::clone(self);
            self.pool.execute(move || {
                me.issued.inc();
                if me.client.prefetch(&bucket, &obj, me.horizon).is_err() {
                    me.failed.inc();
                }
                let mut st = me.state.lock().unwrap();
                st.inflight -= 1;
                if st.inflight == 0 {
                    me.idle.notify_all();
                }
            });
        }
        n
    }

    /// Mark the current batch's objects as demand-in-flight (the loader
    /// brackets its fetch with mark/unmark so `schedule` won't duplicate
    /// work the demand path is doing right now).
    pub fn mark_demand(&self, refs: &[SampleRef]) {
        let mut st = self.state.lock().unwrap();
        for r in refs {
            st.demand.insert(key_of(r));
        }
    }

    pub fn unmark_demand(&self, refs: &[SampleRef]) {
        let mut st = self.state.lock().unwrap();
        for r in refs {
            st.demand.remove(&key_of(r));
        }
    }

    /// Prefetch calls queued or running.
    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().inflight
    }

    /// Block until every queued prefetch completed (tests and epoch
    /// boundaries); `false` on timeout.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        while st.inflight > 0 {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            let (next, res) = self.idle.wait_timeout(st, left).unwrap();
            st = next;
            if res.timed_out() && st.inflight > 0 {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sref(bucket: &str, shard: Option<&str>, name: &str) -> SampleRef {
        SampleRef {
            bucket: bucket.into(),
            shard: shard.map(|s| s.to_string()),
            name: name.into(),
            size: 1,
        }
    }

    #[test]
    fn schedule_dedupes_objects_and_demand() {
        // No live cluster needed: the planner's dedup decisions happen
        // before any call is queued, and a failed call (nothing listens on
        // the address) only bumps `failed`.
        let p = PrefetchPlanner::new(Client::new("127.0.0.1:1"), 2, 2);
        let a = sref("b", Some("s-1.tar"), "m-0");
        let a2 = sref("b", Some("s-1.tar"), "m-1"); // same shard
        let c = sref("b", None, "obj-1");
        assert_eq!(p.schedule(&[a.clone(), a2.clone(), c.clone()]), 2, "shard counted once");
        assert_eq!(p.schedule(&[a2.clone()]), 0, "already issued this epoch");
        let d = sref("b", None, "obj-2");
        p.mark_demand(&[d.clone()]);
        assert_eq!(p.schedule(&[d.clone()]), 0, "demand-in-flight object skipped");
        p.unmark_demand(&[d.clone()]);
        assert_eq!(p.schedule(&[d.clone()]), 1);
        assert!(p.wait_idle(Duration::from_secs(10)), "pool drains");
        assert_eq!(p.pending(), 0);
        assert_eq!(p.issued.get(), 3);
        assert_eq!(p.failed.get(), 3, "no cluster behind the address");
        // New epoch: the same objects schedule again.
        p.reset();
        assert_eq!(p.schedule(&[a]), 1);
        assert!(p.wait_idle(Duration::from_secs(10)));
    }

    #[test]
    fn zero_horizon_schedules_nothing() {
        let p = PrefetchPlanner::new(Client::new("127.0.0.1:1"), 0, 1);
        assert_eq!(p.schedule(&[sref("b", None, "o")]), 0);
        assert_eq!(p.pending(), 0);
        assert!(p.wait_idle(Duration::from_millis(10)));
    }
}
