//! Client side: the SDK (GET/PUT/GetBatch with streaming iteration) and the
//! three data-loader flavors the paper evaluates (§4.1) — sequential shard
//! I/O, per-sample random GET, and batched random access via GetBatch.

pub mod sdk;
pub mod loader;
pub mod prefetch;

pub use sdk::Client;
pub use loader::{AccessMode, DataLoader, EpochPlan, Manifest, Sample};
pub use prefetch::PrefetchPlanner;
