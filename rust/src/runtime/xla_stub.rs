//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The sandbox image has no XLA shared libraries, so the runtime layer
//! compiles against this API-compatible stub instead: every entry point
//! that would touch PJRT returns an error. `Runtime::load` therefore fails
//! cleanly and the HLO-execution tests/examples skip (they already guard on
//! `artifacts_dir()`); nothing else in the crate depends on XLA.
//!
//! Swapping the real bindings back in is a one-line change in
//! `runtime/pjrt.rs` (`use super::xla_stub as xla;` → `use xla;`).

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla unavailable: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!("{what} requires the real PJRT bindings (offline stub build)")))
}

/// Host-side literal (tensor) handle.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::decompose_tuple")
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_actionable_errors() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla unavailable"), "{e}");
        let lit = Literal::scalar(3i32);
        assert!(lit.to_vec::<f32>().is_err());
    }
}
