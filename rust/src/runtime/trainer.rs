//! Training loop driver: data loader → collate HLO → train-step HLO.
//! The §4 end-to-end analog: same model/hyperparameters, only the data
//! access method differs between runs.

use std::path::Path;
use std::time::Instant;

use crate::util::error as anyhow;
use anyhow::Result;

use crate::client::loader::DataLoader;
use crate::util::stats::{LatencyRow, Samples};

use super::pjrt::{tokens_from_samples, Runtime};

/// Per-run report: the loss curve plus the data-stall latency profile.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub mode: &'static str,
    pub losses: Vec<f32>,
    /// Data-loading latency per step (ms) — the stall the paper ties to GPU
    /// idle cycles.
    pub load_ms: LatencyRow,
    /// Compute (train-step execution) per step (ms).
    pub step_ms: LatencyRow,
    pub total_secs: f64,
}

/// Train for `steps` steps pulling batches through `loader`.
pub fn train(rt: &Runtime, loader: &mut DataLoader, steps: usize, seed: i32) -> Result<TrainReport> {
    let t_start = Instant::now();
    let mut params = rt.init_params(seed)?;
    let mut losses = Vec::with_capacity(steps);
    let mut load_lat = Samples::new();
    let mut step_lat = Samples::new();

    for _ in 0..steps {
        let t0 = Instant::now();
        let (samples, _timing) = loader.next_batch()?;
        let payloads: Vec<Vec<u8>> = samples.into_iter().map(|s| s.data).collect();
        let (flat, offsets) = tokens_from_samples(&rt.meta, &payloads);
        load_lat.add_duration(t0.elapsed());

        let t1 = Instant::now();
        let (batch, mask) = rt.collate(&flat, &offsets)?;
        let (new_params, loss) = rt.train_step(params, batch, mask)?;
        step_lat.add_duration(t1.elapsed());
        params = new_params;
        losses.push(loss);
    }

    Ok(TrainReport {
        mode: loader.mode.name(),
        losses,
        load_ms: load_lat.row(),
        step_ms: step_lat.row(),
        total_secs: t_start.elapsed().as_secs_f64(),
    })
}

/// Load artifacts from the conventional location, probing upwards so
/// examples work from any working directory in the repo.
pub fn artifacts_dir() -> Result<std::path::PathBuf> {
    for base in [".", "..", "../.."] {
        let p = Path::new(base).join("artifacts");
        if p.join("meta.json").is_file() {
            return Ok(p);
        }
    }
    anyhow::bail!("artifacts/ not found — run `make artifacts` first")
}

/// Smoothed final loss (mean of the last k) for convergence assertions.
pub fn final_loss(losses: &[f32], k: usize) -> f32 {
    let k = k.min(losses.len()).max(1);
    let tail = &losses[losses.len() - k..];
    tail.iter().sum::<f32>() / k as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_loss_mean() {
        let l = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(final_loss(&l, 2), 1.5);
        assert_eq!(final_loss(&l, 100), 3.0);
        assert_eq!(final_loss(&l[..1], 3), 5.0);
    }
}
