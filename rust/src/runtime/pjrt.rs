//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! ``python/compile/aot.py`` and execute them on the CPU PJRT client via the
//! `xla` crate. This is the only place the training path touches XLA —
//! python never runs at request time.

use std::path::Path;
use std::sync::Mutex;

use crate::util::error as anyhow;
use anyhow::{anyhow, Context, Result};

use super::xla_stub as xla;
use crate::util::json::Value;

/// Model metadata emitted next to the artifacts (shapes, arity, config).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_param_tensors: usize,
    pub n_params: u64,
    pub token_capacity: usize,
    pub pad_id: i32,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json — run `make artifacts`", dir.display()))?;
        let v = Value::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        Ok(ModelMeta {
            vocab: v.u64_field("vocab").context("vocab")? as usize,
            seq_len: v.u64_field("seq_len").context("seq_len")? as usize,
            batch: v.u64_field("batch").context("batch")? as usize,
            n_param_tensors: v.u64_field("n_param_tensors").context("n_param_tensors")? as usize,
            n_params: v.u64_field("n_params").context("n_params")?,
            token_capacity: v.u64_field("token_capacity").context("token_capacity")? as usize,
            pad_id: v.u64_field("pad_id").unwrap_or(0) as i32,
        })
    }
}

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU client with the three model programs.
pub struct Runtime {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    init: Executable,
    collate: Executable,
    train_step: Executable,
    /// Serializes execute calls (the CPU client is not thread-safe for our
    /// usage pattern; training is single-stream anyway).
    lock: Mutex<()>,
}

fn load_exe(client: &xla::PjRtClient, path: &Path) -> Result<Executable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("bad path"))?,
    )
    .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
    Ok(Executable { exe })
}

impl Runtime {
    /// Load `init.hlo.txt`, `collate.hlo.txt`, `train_step.hlo.txt` from
    /// `dir` and compile them once.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let init = load_exe(&client, &dir.join("init.hlo.txt"))?;
        let collate = load_exe(&client, &dir.join("collate.hlo.txt"))?;
        let train_step = load_exe(&client, &dir.join("train_step.hlo.txt"))?;
        Ok(Runtime { meta, client, init, collate, train_step, lock: Mutex::new(()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run(&self, exe: &Executable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let _g = self.lock.lock().unwrap();
        let mut result = exe
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let tuple = result.decompose_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        Ok(tuple)
    }

    /// Initialize parameters from a seed → flat param tensor list.
    pub fn init_params(&self, seed: i32) -> Result<Vec<xla::Literal>> {
        let seed_lit = xla::Literal::scalar(seed);
        self.run(&self.init, &[seed_lit])
    }

    /// Collate a flat token buffer + offsets into (batch, mask) literals.
    /// `flat` must have exactly `meta.token_capacity` elements and
    /// `offsets` exactly `meta.batch + 1`.
    pub fn collate(&self, flat: &[i32], offsets: &[i32]) -> Result<(xla::Literal, xla::Literal)> {
        anyhow::ensure!(flat.len() == self.meta.token_capacity, "flat buffer size");
        anyhow::ensure!(offsets.len() == self.meta.batch + 1, "offsets size");
        let flat_lit = xla::Literal::vec1(flat);
        let off_lit = xla::Literal::vec1(offsets);
        let mut out = self.run(&self.collate, &[flat_lit, off_lit])?;
        anyhow::ensure!(out.len() == 2, "collate arity");
        let mask = out.pop().unwrap();
        let batch = out.pop().unwrap();
        Ok((batch, mask))
    }

    /// One SGD step: params + (batch, mask) → (new params, loss).
    pub fn train_step(
        &self,
        params: Vec<xla::Literal>,
        batch: xla::Literal,
        mask: xla::Literal,
    ) -> Result<(Vec<xla::Literal>, f32)> {
        anyhow::ensure!(params.len() == self.meta.n_param_tensors, "param arity");
        let mut args = params;
        args.push(batch);
        args.push(mask);
        let mut out = self.run(&self.train_step, &args)?;
        anyhow::ensure!(out.len() == self.meta.n_param_tensors + 1, "train_step arity");
        let loss_lit = out.pop().unwrap();
        let loss = loss_lit.to_vec::<f32>().map_err(|e| anyhow!("loss: {e}"))?[0];
        Ok((out, loss))
    }
}

/// Build the (flat, offsets) collate inputs from raw per-sample byte
/// payloads fetched by the loader: byte-level tokenization (vocab 256),
/// truncated/padded to the artifact's static capacity.
pub fn tokens_from_samples(
    meta: &ModelMeta,
    samples: &[Vec<u8>],
) -> (Vec<i32>, Vec<i32>) {
    let mut flat = Vec::with_capacity(meta.token_capacity);
    let mut offsets = Vec::with_capacity(meta.batch + 1);
    offsets.push(0i32);
    for i in 0..meta.batch {
        let data: &[u8] = samples.get(i).map(|v| v.as_slice()).unwrap_or(&[]);
        let room = meta.token_capacity - flat.len();
        let take = data.len().min(room).min(meta.seq_len);
        flat.extend(data[..take].iter().map(|&b| b as i32));
        offsets.push(flat.len() as i32);
    }
    flat.resize(meta.token_capacity, 0);
    (flat, offsets)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Execution tests live in rust/tests/runtime_hlo.rs (they need the
    // artifacts built); here we cover the pure helpers.

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab: 256,
            seq_len: 8,
            batch: 3,
            n_param_tensors: 25,
            n_params: 1,
            token_capacity: 48,
            pad_id: 0,
        }
    }

    #[test]
    fn tokenizer_packs_and_offsets() {
        let m = meta();
        let samples = vec![vec![1u8, 2, 3], vec![], vec![9; 20]];
        let (flat, off) = tokens_from_samples(&m, &samples);
        assert_eq!(flat.len(), m.token_capacity);
        assert_eq!(off, vec![0, 3, 3, 11]); // 20 truncated to seq_len=8
        assert_eq!(&flat[..3], &[1, 2, 3]);
        assert_eq!(&flat[3..11], &[9i32; 8][..]);
        assert_eq!(flat[11], 0); // padded tail
    }

    #[test]
    fn tokenizer_respects_capacity() {
        let m = meta();
        let samples = vec![vec![7u8; 100], vec![8; 100], vec![9; 100]];
        let (flat, off) = tokens_from_samples(&m, &samples);
        assert_eq!(flat.len(), m.token_capacity);
        assert!(*off.last().unwrap() as usize <= m.token_capacity);
        // every sample truncated to seq_len
        assert_eq!(off[1] - off[0], 8);
    }

    #[test]
    fn meta_parse_errors_are_actionable() {
        let dir = std::env::temp_dir().join(format!("gbmeta-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = ModelMeta::load(&dir).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
