//! PJRT runtime: loads AOT HLO artifacts and runs the training step.
pub mod pjrt;
pub mod trainer;
