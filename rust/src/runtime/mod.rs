//! PJRT runtime: loads AOT HLO artifacts and runs the training step.
//! `xla_stub` replaces the real PJRT bindings in the offline build.
pub mod pjrt;
pub mod trainer;
pub mod xla_stub;
