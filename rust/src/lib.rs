//! # GetBatch — distributed multi-object retrieval for ML data loading
//!
//! Reproduction of *GetBatch: Distributed Multi-Object Retrieval for ML Data
//! Loading* (Aizman, Gaikwad, Żelasko — CS.DC 2026).
//!
//! The crate implements an AIStore-like distributed object store in which
//! batch retrieval is a first-class primitive: a client submits one request
//! naming N objects (standalone or TAR-shard members, spread over many
//! nodes); the cluster assembles them — one *Designated Target* (DT)
//! coordinates, all other nodes stream locally-owned items to it — and the
//! DT emits a single TAR response in strict request order.
//!
//! Layer map (see DESIGN.md):
//! - L3 (this crate): cluster, gateway, DT, senders, transport, client SDK,
//!   data loaders, discrete-event simulator, benchmarking harness.
//! - L2/L1 (python, build-time only): JAX transformer train step + Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//! - `runtime`: loads those HLO artifacts through PJRT (CPU) and runs them
//!   from the training hot path — python never executes at request time.

pub mod util;
pub mod proto;
pub mod tar;
pub mod store;
pub mod cluster;
pub mod gateway;
pub mod dt;
pub mod sender;
pub mod transport;
pub mod batch;
pub mod client;
pub mod config;
pub mod metrics;
pub mod sim;
pub mod runtime;
pub mod aisloader;
pub mod testutil;

pub use batch::request::{BatchEntry, BatchOpts, BatchRequest, OutputFormat};
pub use batch::reader::{BatchItem, BatchReader};
pub use client::sdk::Client;
pub use cluster::node::{Cluster, ClusterSpec};
pub use config::{ClusterConfig, GetBatchConfig};
