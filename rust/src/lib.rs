//! # GetBatch — distributed multi-object retrieval for ML data loading
//!
//! Reproduction of *GetBatch: Distributed Multi-Object Retrieval for ML Data
//! Loading* (Aizman, Gaikwad, Żelasko — CS.DC 2026).
//!
//! The crate implements an AIStore-like distributed object store in which
//! batch retrieval is a first-class primitive: a client submits one request
//! naming N objects (standalone or TAR-shard members, spread over many
//! nodes); the cluster assembles them — one *Designated Target* (DT)
//! coordinates, all other nodes stream locally-owned items to it — and the
//! DT emits a single TAR response in strict request order.
//!
//! **Start with `docs/ARCHITECTURE.md`** (repository root) for the
//! end-to-end batch lifecycle — register → admission → senders → DT order
//! buffer → assembler → GFN recovery — with the module map and the
//! memory-bound invariants; the README's "Configuration reference" table
//! covers every `GetBatchConfig` knob, and `EXPERIMENTS.md` records the
//! bench protocol.
//!
//! The data path is *chunked streaming with enforced backpressure, end to
//! end* — the read side streams just like the emit side:
//!
//! 1. **Read** — every producer of entry bytes opens a
//!    [`store::EntryReader`] (`ObjectStore::open_entry` for whole objects,
//!    a range-bounded reader over the member span for shard extraction)
//!    and pulls `chunk_bytes` pieces; no call path materializes a full
//!    entry. The store is *tiered*: `ObjectStore` is a bucket → backend
//!    router over the `store::Backend` trait — local mountpaths
//!    (`store::local`), remote nodes over HTTP Range (`store::remote`,
//!    serving each bucket from a health-tracked *endpoint set* — circuit
//!    breaker + half-open probing in `store::health` — with transparent
//!    failover that resumes a ranged stream mid-entry on the next healthy
//!    endpoint), and a read-through LRU chunk cache with sequential
//!    read-ahead (`store::cache`) composable in front of either.
//! 2. **Send** — senders cut chunk frames (`proto::frame` FIRST/LAST
//!    flags) straight off the reader, so sender residency is O(chunk), not
//!    O(object).
//! 3. **Buffer** — the DT's reorder buffer (`dt::order`) admits producer
//!    bytes against a node-wide resident-memory budget
//!    (`dt::admission::MemoryBudget` — block, don't just meter; blocked
//!    producers stall their socket, which TCP turns into sender
//!    backpressure).
//! 4. **Emit** — the assembly loop (`dt::exec`) starts streaming the
//!    head-of-line entry into the TAR before its last chunk arrives.
//! 5. **Recover** — GFN recovery fetches neighbor copies in HTTP *Range*
//!    chunks (`proto::http` 206 + `content-range`), each reserved against
//!    the same DT budget; a sender that dies mid-entry is repaired by a
//!    CRC-verified byte-identical splice. When the neighbor stores a
//!    PUT-time CRC-32 sidecar, the splice skips the prefix re-download:
//!    the ranged fetch resumes at the splice offset and the combined
//!    entry CRC is checked against the stored hash. Sender fan-in
//!    completion (SENDER_DONE + DT-local done) triggers recovery early
//!    instead of burning the sender-wait timeout.
//!
//! Two knobs bound memory end to end: `chunk_bytes` caps any single
//! producer-side buffer (sender, HTTP object handler, DT-local read,
//! recovery chunk), and `dt_buffer_bytes` caps the bytes resident across a
//! target's reorder buffers. See the README's "streaming read path" section
//! for the full walk-through.
//!
//! Layer map (module → role):
//! - `util` — JSON / PRNG / stats / HRW / threadpool / clock / CRC-32 /
//!   anyhow-style errors (the offline build has no external crates).
//! - `proto` — minimal HTTP/1.1 (+ chunked transfer), the chunked P2P frame
//!   protocol, control-plane wire messages.
//! - `store` — the tiered store: the `Backend` trait, the `ObjectStore`
//!   bucket router, local mountpath / remote HTTP / cached tiers, endpoint
//!   health tracking + failover for the remote tier, the streaming
//!   `EntryReader` seam, PUT-time CRC-32 sidecars, and TAR-shard member
//!   extraction (range-bounded readers on any tier).
//! - `tar` — ustar codec: whole-entry and streamed-entry writers, readers.
//! - `cluster` — smap, HRW placement, the in-process node runtime.
//! - `gateway` — proxy: object redirect + three-phase GetBatch flow.
//! - `dt` — Designated Target: reorder buffer, memory budget/admission,
//!   ordered streaming assembly, GFN recovery.
//! - `sender` / `transport` — chunked entry push over pooled, stale-probed
//!   peer connections.
//! - `batch` / `client` — request model, ordered reader, SDK, data loaders.
//! - `sim` — discrete-event cluster simulator (paper-scale tables).
//! - `runtime` — PJRT-side training step (stubbed offline; python/ holds
//!   the AOT pipeline that produces `artifacts/*.hlo.txt`).
//! - `aisloader` / `testutil` — load generator, fixtures, property tests.

pub mod util;
pub mod proto;
pub mod tar;
pub mod store;
pub mod cluster;
pub mod gateway;
pub mod dt;
pub mod sender;
pub mod transport;
pub mod batch;
pub mod client;
pub mod config;
pub mod metrics;
pub mod sim;
pub mod runtime;
pub mod aisloader;
pub mod testutil;

pub use batch::request::{BatchEntry, BatchOpts, BatchRequest, OutputFormat};
pub use batch::reader::{BatchItem, BatchReader};
pub use client::sdk::Client;
pub use cluster::node::{Cluster, ClusterSpec};
pub use config::{ClusterConfig, GetBatchConfig};
