//! AISLoader analog (§3.1): a multi-worker closed-loop load generator for
//! the live cluster. Stages a uniform-size dataset, then drives GET or
//! GetBatch workers for a steady-state window and reports sustained
//! throughput + latency percentiles — the rows of Table 1.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::batch::request::{BatchEntry, BatchRequest};
use crate::client::sdk::Client;
use crate::cluster::node::Cluster;
use crate::util::rng::Rng;
use crate::util::stats::{LatencyRow, Samples, Throughput};

/// One benchmark configuration.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub object_size: u64,
    /// None → individual GET per object; Some(k) → GetBatch of k entries.
    pub batch: Option<usize>,
    pub workers: usize,
    pub duration: Duration,
    /// Number of distinct objects staged (sampling domain).
    pub num_objects: usize,
    pub seed: u64,
    /// Colocation hint on GetBatch requests.
    pub coloc: bool,
    /// Disable client connection reuse (cold-connection GET baseline).
    pub no_reuse: bool,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            object_size: 10 << 10,
            batch: None,
            workers: 8,
            duration: Duration::from_secs(2),
            num_objects: 512,
            seed: 1,
            coloc: false,
            no_reuse: false,
        }
    }
}

/// Result of one configuration run.
#[derive(Debug, Clone)]
pub struct LoadResult {
    pub label: String,
    pub throughput: Throughput,
    pub request_ms: LatencyRow,
    pub errors: u64,
}

/// Stage `num_objects` uniform objects of `object_size` under bucket `b`.
/// Direct-put (placement-faithful) to keep staging off the benchmark clock.
pub fn stage_uniform(cluster: &Cluster, bucket: &str, spec: &LoadSpec) {
    let mut rng = Rng::new(spec.seed ^ 0x5742);
    let mut buf = vec![0u8; spec.object_size as usize];
    for i in 0..spec.num_objects {
        rng.fill_bytes(&mut buf);
        cluster.put_direct(bucket, &format!("obj-{i:06}"), &buf).expect("stage");
    }
}

/// Run one configuration against a staged cluster. Workers run closed-loop
/// until the wall-clock window elapses.
pub fn run(cluster: &Cluster, bucket: &str, spec: &LoadSpec) -> LoadResult {
    let stop = Arc::new(AtomicBool::new(false));
    let bytes = Arc::new(AtomicU64::new(0));
    let ops = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let lat = Arc::new(Mutex::new(Samples::new()));
    let proxy = cluster.proxy_addr();

    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..spec.workers {
            let stop = Arc::clone(&stop);
            let bytes = Arc::clone(&bytes);
            let ops = Arc::clone(&ops);
            let errors = Arc::clone(&errors);
            let lat = Arc::clone(&lat);
            let proxy = proxy.clone();
            let spec = spec.clone();
            let bucket = bucket.to_string();
            s.spawn(move || {
                let client = if spec.no_reuse {
                    Client::without_reuse(&proxy)
                } else {
                    Client::new(&proxy)
                };
                let mut rng = Rng::new(spec.seed ^ (w as u64 + 1) * 0x9E37);
                let mut local = Samples::new();
                while !stop.load(Ordering::Relaxed) {
                    let t = Instant::now();
                    match spec.batch {
                        None => {
                            let i = rng.usize_below(spec.num_objects);
                            match client.get(&bucket, &format!("obj-{i:06}")) {
                                Ok(data) => {
                                    bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
                                    ops.fetch_add(1, Ordering::Relaxed);
                                    local.add_duration(t.elapsed());
                                }
                                Err(_) => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        Some(k) => {
                            let entries: Vec<BatchEntry> = (0..k)
                                .map(|_| {
                                    let i = rng.usize_below(spec.num_objects);
                                    BatchEntry::obj(&bucket, &format!("obj-{i:06}"))
                                })
                                .collect();
                            let req = BatchRequest::new(entries).colocation(spec.coloc);
                            match client.get_batch_timed(&req) {
                                Ok((items, stats)) => {
                                    bytes.fetch_add(stats.bytes, Ordering::Relaxed);
                                    ops.fetch_add(items.len() as u64, Ordering::Relaxed);
                                    local.add_duration(stats.total);
                                }
                                Err(_) => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                }
                lat.lock().unwrap().merge(&local);
            });
        }
        std::thread::sleep(spec.duration);
        stop.store(true, Ordering::Relaxed);
    });
    let secs = t0.elapsed().as_secs_f64();

    let label = match spec.batch {
        None => format!("GET {}", crate::util::bytes::fmt_size(spec.object_size)),
        Some(k) => format!("GetBatch({k}) {}", crate::util::bytes::fmt_size(spec.object_size)),
    };
    let mut lat = lat.lock().unwrap();
    LoadResult {
        label,
        throughput: Throughput {
            bytes: bytes.load(Ordering::Relaxed),
            ops: ops.load(Ordering::Relaxed),
            secs,
        },
        request_ms: lat.row(),
        errors: errors.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn aisloader_get_vs_getbatch_smoke() {
        let cluster = Cluster::start(ClusterConfig {
            targets: 2,
            http_workers: 8,
            ..Default::default()
        })
        .unwrap();
        let spec = LoadSpec {
            object_size: 4 << 10,
            workers: 4,
            duration: Duration::from_millis(600),
            num_objects: 64,
            ..Default::default()
        };
        stage_uniform(&cluster, "bench", &spec);

        let get = run(&cluster, "bench", &spec);
        assert!(get.throughput.ops > 0, "GET made progress");
        assert_eq!(get.errors, 0);

        let batched = run(&cluster, "bench", &LoadSpec { batch: Some(16), ..spec.clone() });
        assert!(batched.throughput.ops > 0);
        assert_eq!(batched.errors, 0);
        // Structural check: batching collapses request count — ops per
        // *request* is 16× GET's. (Throughput superiority is asserted in the
        // release-mode benches, not in a debug unit test.)
        assert!(batched.throughput.ops >= 16);
        assert!(batched.request_ms.n * 16 <= batched.throughput.ops as usize + 16);
    }
}
