//! POSIX ustar TAR codec, from scratch.
//!
//! TAR is load-bearing twice in GetBatch: (1) datasets are stored as *shards*
//! — TAR archives of samples — from which senders extract individual members
//! (§2.2); (2) the DT's response is itself a TAR stream, with entries in
//! strict request order (§2.2, "default: uncompressed TAR archives").
//!
//! Implemented: ustar headers with prefix-field long names, streaming writer
//! (append entries as payloads arrive), full-archive reader, and an
//! incremental reader that consumes entries from any `Read` — the client SDK
//! iterates GetBatch responses with it. Missing entries (continue-on-error
//! mode) are encoded as zero-length members under `MISSING_PREFIX`,
//! preserving positional correspondence.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};

pub const BLOCK: usize = 512;

/// Placeholder prefix for entries that could not be retrieved when
/// continue-on-error is enabled (§2.4.2).
pub const MISSING_PREFIX: &str = "__404__/";

/// One archive member.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub name: String,
    pub data: Vec<u8>,
}

/// Metadata of a member found while scanning (offset points at the payload,
/// so shard indices can pread members directly).
#[derive(Debug, Clone, PartialEq)]
pub struct MemberInfo {
    pub name: String,
    pub offset: u64,
    pub size: u64,
}

#[derive(Debug)]
pub enum TarError {
    Io(io::Error),
    NameTooLong(String),
    BadChecksum(u64),
    BadField(&'static str),
    /// Streaming-entry misuse: payload bytes don't match the declared size.
    EntrySize { expected: u64, got: u64 },
}

crate::impl_error! {
    TarError {
        display {
            TarError::Io(e) => "io: {e}",
            TarError::NameTooLong(n) => "name too long for ustar: {n}",
            TarError::BadChecksum(b) => "bad header checksum at block {b}",
            TarError::BadField(w) => "corrupt header field: {w}",
            TarError::EntrySize { expected, got } =>
                "streamed entry size mismatch: expected {expected}, got {got}",
        }
        source {
            TarError::Io(e) => e,
        }
        from {
            io::Error => Io,
        }
    }
}

// ---------------------------------------------------------------- header --

fn octal(buf: &mut [u8], val: u64) {
    // NUL-terminated octal, left-padded with zeros (ustar convention).
    let s = format!("{:0width$o}\0", val, width = buf.len() - 1);
    buf.copy_from_slice(s.as_bytes());
}

fn parse_octal(b: &[u8]) -> Result<u64, TarError> {
    let s: Vec<u8> =
        b.iter().copied().take_while(|&c| c != 0 && c != b' ').skip_while(|&c| c == b' ').collect();
    if s.is_empty() {
        return Ok(0);
    }
    let txt = std::str::from_utf8(&s).map_err(|_| TarError::BadField("octal"))?;
    u64::from_str_radix(txt.trim(), 8).map_err(|_| TarError::BadField("octal"))
}

/// Build a 512-byte ustar header for a regular file.
pub fn make_header(name: &str, size: u64) -> Result<[u8; BLOCK], TarError> {
    let mut h = [0u8; BLOCK];
    // Split long names across name (100) + prefix (155) at a '/' boundary.
    let (prefix, base) = if name.len() <= 100 {
        ("", name)
    } else {
        let split = name[..name.len().min(156)]
            .rfind('/')
            .filter(|&i| name.len() - i - 1 <= 100 && i <= 155)
            .ok_or_else(|| TarError::NameTooLong(name.to_string()))?;
        (&name[..split], &name[split + 1..])
    };
    h[..base.len()].copy_from_slice(base.as_bytes());
    octal(&mut h[100..108], 0o644); // mode
    octal(&mut h[108..116], 0); // uid
    octal(&mut h[116..124], 0); // gid
    octal(&mut h[124..136], size);
    octal(&mut h[136..148], 0); // mtime
    h[148..156].copy_from_slice(b"        "); // chksum placeholder = spaces
    h[156] = b'0'; // typeflag: regular file
    h[257..263].copy_from_slice(b"ustar\0");
    h[263..265].copy_from_slice(b"00");
    h[345..345 + prefix.len()].copy_from_slice(prefix.as_bytes());
    let sum: u64 = h.iter().map(|&b| b as u64).sum();
    let chk = format!("{:06o}\0 ", sum);
    h[148..156].copy_from_slice(chk.as_bytes());
    Ok(h)
}

fn header_name(h: &[u8; BLOCK]) -> Result<String, TarError> {
    let take = |b: &[u8]| -> Result<String, TarError> {
        let end = b.iter().position(|&c| c == 0).unwrap_or(b.len());
        String::from_utf8(b[..end].to_vec()).map_err(|_| TarError::BadField("name"))
    };
    let base = take(&h[..100])?;
    let prefix = take(&h[345..500])?;
    Ok(if prefix.is_empty() { base } else { format!("{prefix}/{base}") })
}

fn verify_checksum(h: &[u8; BLOCK], block_no: u64) -> Result<(), TarError> {
    let stored = parse_octal(&h[148..156])?;
    let mut sum: u64 = 0;
    for (i, &b) in h.iter().enumerate() {
        sum += if (148..156).contains(&i) { b' ' as u64 } else { b as u64 };
    }
    if sum != stored {
        return Err(TarError::BadChecksum(block_no));
    }
    Ok(())
}

#[inline]
pub fn padded_len(size: u64) -> u64 {
    size.div_ceil(BLOCK as u64) * BLOCK as u64
}

// ---------------------------------------------------------------- writer --

/// Streaming TAR writer over any `Write`. The DT uses this to emit the
/// response stream incrementally (streaming mode) or into a buffer.
///
/// Two granularities:
/// * `append`/`append_from` — one whole entry per call;
/// * `begin_entry` / `write_chunk` / `end_entry` — an entry whose payload
///   arrives in pieces (the DT's chunked head-of-line streaming: the header
///   needs the total size, which the first chunk frame declares, but the
///   payload bytes flow through as they arrive).
pub struct TarWriter<W: Write> {
    w: W,
    bytes_written: u64,
    finished: bool,
    /// Open streamed entry: (bytes still expected, declared size).
    open: Option<(u64, u64)>,
}

impl<W: Write> TarWriter<W> {
    pub fn new(w: W) -> TarWriter<W> {
        TarWriter { w, bytes_written: 0, finished: false, open: None }
    }

    fn check_closed(&self) -> Result<(), TarError> {
        if let Some((remaining, size)) = self.open {
            return Err(TarError::EntrySize { expected: size, got: size - remaining });
        }
        Ok(())
    }

    pub fn append(&mut self, name: &str, data: &[u8]) -> Result<(), TarError> {
        self.append_from(name, data.len() as u64, &mut io::Cursor::new(data))
    }

    /// Append an entry streaming its payload from `r` (exactly `size` bytes).
    pub fn append_from<R: Read>(&mut self, name: &str, size: u64, r: &mut R) -> Result<(), TarError> {
        self.check_closed()?;
        let h = make_header(name, size)?;
        self.w.write_all(&h)?;
        let copied = io::copy(&mut r.take(size), &mut self.w)?;
        if copied != size {
            return Err(TarError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("payload short: {copied}/{size}"),
            )));
        }
        let pad = (padded_len(size) - size) as usize;
        if pad > 0 {
            self.w.write_all(&[0u8; BLOCK][..pad])?;
        }
        self.bytes_written += BLOCK as u64 + padded_len(size);
        Ok(())
    }

    /// Open a streamed entry: emits the header now; payload follows via
    /// `write_chunk` and must total exactly `size` bytes before
    /// `end_entry`.
    pub fn begin_entry(&mut self, name: &str, size: u64) -> Result<(), TarError> {
        self.check_closed()?;
        let h = make_header(name, size)?;
        self.w.write_all(&h)?;
        self.bytes_written += BLOCK as u64;
        self.open = Some((size, size));
        Ok(())
    }

    /// Write the next piece of the open streamed entry's payload.
    pub fn write_chunk(&mut self, data: &[u8]) -> Result<(), TarError> {
        let (remaining, size) = self.open.ok_or(TarError::EntrySize { expected: 0, got: 0 })?;
        if data.len() as u64 > remaining {
            return Err(TarError::EntrySize {
                expected: size,
                got: size - remaining + data.len() as u64,
            });
        }
        self.w.write_all(data)?;
        self.bytes_written += data.len() as u64;
        self.open = Some((remaining - data.len() as u64, size));
        Ok(())
    }

    /// Close the open streamed entry: verifies the payload ran to its
    /// declared size and writes the block padding. (No flush here — the
    /// chunked HTTP writer already emits at its own granularity, and a
    /// per-entry flush would shrink wire chunks for small-object batches.)
    pub fn end_entry(&mut self) -> Result<(), TarError> {
        let (remaining, size) = self.open.ok_or(TarError::EntrySize { expected: 0, got: 0 })?;
        if remaining != 0 {
            return Err(TarError::EntrySize { expected: size, got: size - remaining });
        }
        let pad = (padded_len(size) - size) as usize;
        if pad > 0 {
            self.w.write_all(&[0u8; BLOCK][..pad])?;
            self.bytes_written += pad as u64;
        }
        self.open = None;
        Ok(())
    }

    /// Append the continue-on-error placeholder for a missing entry.
    pub fn append_missing(&mut self, name: &str) -> Result<(), TarError> {
        self.append(&format!("{MISSING_PREFIX}{name}"), &[])
    }

    /// Write the end-of-archive marker (two zero blocks) and flush.
    pub fn finish(&mut self) -> Result<(), TarError> {
        self.check_closed()?;
        if !self.finished {
            self.w.write_all(&[0u8; BLOCK * 2])?;
            self.w.flush()?;
            self.bytes_written += 2 * BLOCK as u64;
            self.finished = true;
        }
        Ok(())
    }

    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    pub fn into_inner(mut self) -> Result<W, TarError> {
        self.finish()?;
        Ok(self.w)
    }
}

/// Serialize entries to a TAR byte vector (shard construction helper).
pub fn write_archive(entries: &[Entry]) -> Result<Vec<u8>, TarError> {
    let mut w = TarWriter::new(Vec::new());
    for e in entries {
        w.append(&e.name, &e.data)?;
    }
    w.into_inner()
}

// ---------------------------------------------------------------- reader --

/// Incremental entry reader over any `Read` — yields entries one at a time;
/// used by the client SDK to iterate a GetBatch response stream without
/// buffering the whole archive.
pub struct TarReader<R: Read> {
    r: R,
    block_no: u64,
    done: bool,
}

impl<R: Read> TarReader<R> {
    pub fn new(r: R) -> TarReader<R> {
        TarReader { r, block_no: 0, done: false }
    }

    fn read_block(&mut self, buf: &mut [u8; BLOCK]) -> Result<bool, TarError> {
        let mut filled = 0;
        while filled < BLOCK {
            let n = self.r.read(&mut buf[filled..])?;
            if n == 0 {
                if filled == 0 {
                    return Ok(false); // clean EOF
                }
                return Err(TarError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "truncated block",
                )));
            }
            filled += n;
        }
        self.block_no += 1;
        Ok(true)
    }

    /// Next entry, or `None` at end of archive.
    pub fn next_entry(&mut self) -> Result<Option<Entry>, TarError> {
        if self.done {
            return Ok(None);
        }
        let mut h = [0u8; BLOCK];
        loop {
            if !self.read_block(&mut h)? {
                self.done = true;
                return Ok(None);
            }
            if h.iter().all(|&b| b == 0) {
                // End marker (first of two zero blocks); tolerate missing 2nd.
                self.done = true;
                return Ok(None);
            }
            verify_checksum(&h, self.block_no - 1)?;
            let typeflag = h[156];
            let size = parse_octal(&h[124..136])?;
            let name = header_name(&h)?;
            // Skip non-regular members (dirs etc.) — shards hold files only.
            if typeflag != b'0' && typeflag != 0 {
                let mut skip = padded_len(size);
                let mut buf = [0u8; BLOCK];
                while skip > 0 {
                    if !self.read_block(&mut buf)? {
                        return Err(TarError::Io(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            "truncated skip",
                        )));
                    }
                    skip -= BLOCK as u64;
                }
                continue;
            }
            let mut data = vec![0u8; size as usize];
            self.r.read_exact(&mut data)?;
            let pad = (padded_len(size) - size) as usize;
            if pad > 0 {
                let mut padbuf = [0u8; BLOCK];
                self.r.read_exact(&mut padbuf[..pad])?;
            }
            self.block_no += padded_len(size) / BLOCK as u64;
            return Ok(Some(Entry { name, data }));
        }
    }
}

impl<R: Read> Iterator for TarReader<R> {
    type Item = Result<Entry, TarError>;
    fn next(&mut self) -> Option<Self::Item> {
        self.next_entry().transpose()
    }
}

/// Parse a full in-memory archive.
pub fn read_archive(bytes: &[u8]) -> Result<Vec<Entry>, TarError> {
    TarReader::new(io::Cursor::new(bytes)).collect()
}

/// Scan an archive and return member metadata (payload offsets) — the shard
/// index senders use to pread individual members without re-parsing.
pub fn scan_members<R: Read>(r: R) -> Result<Vec<MemberInfo>, TarError> {
    let mut out = Vec::new();
    let mut rd = CountingReader { r, pos: 0 };
    let mut tr = TarReader::new(&mut rd);
    // We re-implement the walk to capture offsets without copying payloads.
    loop {
        let mut h = [0u8; BLOCK];
        if !tr.read_block(&mut h)? {
            break;
        }
        if h.iter().all(|&b| b == 0) {
            break;
        }
        verify_checksum(&h, 0)?;
        let size = parse_octal(&h[124..136])?;
        let name = header_name(&h)?;
        let offset = tr.r.pos;
        out.push(MemberInfo { name, offset, size });
        // skip payload + padding
        let mut to_skip = padded_len(size);
        let mut buf = [0u8; 4096];
        while to_skip > 0 {
            let n = tr.r.read(&mut buf[..to_skip.min(4096) as usize])?;
            if n == 0 {
                return Err(TarError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "truncated member",
                )));
            }
            to_skip -= n as u64;
        }
    }
    Ok(out)
}

struct CountingReader<R: Read> {
    r: R,
    pos: u64,
}

impl<R: Read> Read for CountingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.r.read(buf)?;
        self.pos += n as u64;
        Ok(n)
    }
}

/// Index an archive into name → (offset, size).
pub fn index_members(bytes: &[u8]) -> Result<BTreeMap<String, (u64, u64)>, TarError> {
    Ok(scan_members(io::Cursor::new(bytes))?
        .into_iter()
        .map(|m| (m.name, (m.offset, m.size)))
        .collect())
}

/// Is this entry a continue-on-error placeholder?
pub fn is_missing(name: &str) -> bool {
    name.starts_with(MISSING_PREFIX)
}

/// Original name of a placeholder entry.
pub fn missing_original(name: &str) -> Option<&str> {
    name.strip_prefix(MISSING_PREFIX)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, len: usize, fill: u8) -> Entry {
        Entry { name: name.to_string(), data: vec![fill; len] }
    }

    #[test]
    fn roundtrip_basic() {
        let entries = vec![entry("a.bin", 10, 1), entry("dir/b.bin", 512, 2), entry("c", 0, 0)];
        let bytes = write_archive(&entries).unwrap();
        assert_eq!(bytes.len() % BLOCK, 0);
        let back = read_archive(&bytes).unwrap();
        assert_eq!(back, entries);
    }

    #[test]
    fn roundtrip_block_boundaries() {
        for len in [0, 1, 511, 512, 513, 1024, 1025] {
            let e = vec![entry("x", len, 7)];
            let back = read_archive(&write_archive(&e).unwrap()).unwrap();
            assert_eq!(back, e, "len={len}");
        }
    }

    #[test]
    fn long_names_via_prefix() {
        let name = format!("{}/{}", "d".repeat(120), "f".repeat(80));
        let e = vec![Entry { name: name.clone(), data: vec![9; 33] }];
        let back = read_archive(&write_archive(&e).unwrap()).unwrap();
        assert_eq!(back[0].name, name);
    }

    #[test]
    fn name_too_long_rejected() {
        let name = "x".repeat(200); // no '/' to split on
        assert!(matches!(
            make_header(&name, 0),
            Err(TarError::NameTooLong(_))
        ));
    }

    #[test]
    fn checksum_detects_corruption() {
        let bytes = write_archive(&[entry("a", 4, 3)]).unwrap();
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(matches!(read_archive(&bad), Err(TarError::BadChecksum(_))));
    }

    #[test]
    fn truncated_payload_detected() {
        let bytes = write_archive(&[entry("a", 600, 3)]).unwrap();
        let cut = &bytes[..BLOCK + 100];
        assert!(read_archive(cut).is_err());
    }

    #[test]
    fn member_index_preads() {
        let entries = vec![entry("s/0.wav", 100, 1), entry("s/1.wav", 700, 2), entry("s/2.wav", 5, 3)];
        let bytes = write_archive(&entries).unwrap();
        let idx = index_members(&bytes).unwrap();
        assert_eq!(idx.len(), 3);
        for e in &entries {
            let (off, size) = idx[&e.name];
            assert_eq!(size as usize, e.data.len());
            let slice = &bytes[off as usize..(off + size) as usize];
            assert_eq!(slice, &e.data[..]);
        }
    }

    #[test]
    fn missing_placeholder() {
        let mut w = TarWriter::new(Vec::new());
        w.append("ok.bin", &[1, 2]).unwrap();
        w.append_missing("lost.bin").unwrap();
        let bytes = w.into_inner().unwrap();
        let back = read_archive(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert!(!is_missing(&back[0].name));
        assert!(is_missing(&back[1].name));
        assert_eq!(missing_original(&back[1].name), Some("lost.bin"));
        assert!(back[1].data.is_empty());
    }

    #[test]
    fn streaming_reader_incremental() {
        let entries = vec![entry("a", 513, 1), entry("b", 3, 2)];
        let bytes = write_archive(&entries).unwrap();
        let mut rd = TarReader::new(io::Cursor::new(&bytes));
        assert_eq!(rd.next_entry().unwrap().unwrap().name, "a");
        assert_eq!(rd.next_entry().unwrap().unwrap().name, "b");
        assert!(rd.next_entry().unwrap().is_none());
        assert!(rd.next_entry().unwrap().is_none()); // idempotent
    }

    #[test]
    fn streamed_entry_chunks_equal_whole_append() {
        // begin/write_chunk/end must produce byte-identical output to a
        // single append of the same payload.
        let payload: Vec<u8> = (0..1500u32).map(|i| (i % 251) as u8).collect();
        let mut whole = TarWriter::new(Vec::new());
        whole.append("e", &payload).unwrap();
        let whole = whole.into_inner().unwrap();

        let mut streamed = TarWriter::new(Vec::new());
        streamed.begin_entry("e", payload.len() as u64).unwrap();
        for chunk in payload.chunks(64) {
            streamed.write_chunk(chunk).unwrap();
        }
        streamed.end_entry().unwrap();
        let streamed = streamed.into_inner().unwrap();
        assert_eq!(whole, streamed);
    }

    #[test]
    fn streamed_entry_size_violations_rejected() {
        let mut w = TarWriter::new(Vec::new());
        w.begin_entry("x", 4).unwrap();
        w.write_chunk(&[1, 2]).unwrap();
        // overflow
        assert!(matches!(w.write_chunk(&[3, 4, 5]), Err(TarError::EntrySize { .. })));
        // short close
        assert!(matches!(w.end_entry(), Err(TarError::EntrySize { expected: 4, got: 2 })));
        // appending while an entry is open is a misuse
        assert!(matches!(w.append("y", &[]), Err(TarError::EntrySize { .. })));
        // completing it cleanly works
        w.write_chunk(&[3, 4]).unwrap();
        w.end_entry().unwrap();
        let bytes = w.into_inner().unwrap();
        let back = read_archive(&bytes).unwrap();
        assert_eq!(back[0].data, vec![1, 2, 3, 4]);
    }

    #[test]
    fn append_from_reader_short_payload_errors() {
        let mut w = TarWriter::new(Vec::new());
        let mut short = io::Cursor::new(vec![0u8; 5]);
        assert!(w.append_from("x", 10, &mut short).is_err());
    }

    #[test]
    fn gnu_tar_compat_read() {
        // Archive produced by this writer should be readable after
        // re-serializing entries in a different order (no hidden state).
        let e1 = vec![entry("q", 42, 9)];
        let b1 = write_archive(&e1).unwrap();
        let e2 = read_archive(&b1).unwrap();
        let b2 = write_archive(&e2).unwrap();
        assert_eq!(b1, b2);
    }
}
