//! Workload drivers over the simulated cluster.
//!
//! - `run_synthetic` (§3): closed-loop uniform-size workers — regenerates
//!   Table 1 / Figure 3 (sustained GiB/s per configuration).
//! - `run_training` (§4.2): bursty synchronous loaders with log-normal
//!   "audio-like" sample sizes — regenerates Table 2 (batch & per-object
//!   latency percentiles) for the three access methods.
//!
//! Both drivers are event-driven: GetBatch executions are split into their
//! §2.3.1 phases (register → fan-in → ordered stream out) and interleaved
//! in global virtual-time order, so one request's long tail never blocks
//! another's early resource acquisitions (see sim/cluster.rs).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::client::loader::AccessMode;
use crate::util::rng::Rng;
use crate::util::stats::{LatencyRow, Samples, Throughput};

use super::cluster::{BatchPhase1, SimCluster};
use super::model::CostModel;

/// Result of one synthetic configuration run.
#[derive(Debug, Clone)]
pub struct SyntheticResult {
    pub label: String,
    pub throughput: Throughput,
    pub batch_latency_ms: LatencyRow,
}

enum Phase {
    Issue,
    FanIn(Box<BatchPhase1>),
    Out(Box<BatchPhase1>, u64),
}

struct Ev {
    t: u64,
    worker: usize,
    phase: Phase,
}

/// Closed-loop synthetic benchmark: `workers` clients issue back-to-back
/// requests for `sim_seconds` of virtual time (§3.1: 80 workers, steady
/// state). `batch` = None → individual GET per object.
pub fn run_synthetic(
    m: &CostModel,
    workers: usize,
    object_size: u64,
    batch: Option<usize>,
    sim_seconds: f64,
    seed: u64,
) -> SyntheticResult {
    let mut cluster = SimCluster::new(m.clone(), seed);
    let horizon = (sim_seconds * 1e9) as u64;
    let mut lat = Samples::new();
    let mut bytes = 0u64;
    let mut ops = 0u64;
    let mut issue_at: Vec<u64> = vec![0; workers]; // per-worker request start
    let mut heap: BinaryHeap<Reverse<(u64, usize, u8)>> = BinaryHeap::new();
    let mut pending: Vec<Option<Ev>> = Vec::new();

    // Simple indexed event store: heap carries (time, idx, tiebreak).
    let push = |heap: &mut BinaryHeap<Reverse<(u64, usize, u8)>>,
                    pending: &mut Vec<Option<Ev>>,
                    ev: Ev| {
        let idx = pending.len();
        heap.push(Reverse((ev.t, idx, 0)));
        pending.push(Some(ev));
    };

    for w in 0..workers {
        push(&mut heap, &mut pending, Ev { t: 0, worker: w, phase: Phase::Issue });
    }

    while let Some(Reverse((t, idx, _))) = heap.pop() {
        let ev = pending[idx].take().expect("event once");
        match ev.phase {
            Phase::Issue => {
                if t >= horizon {
                    continue;
                }
                issue_at[ev.worker] = t;
                match batch {
                    None => {
                        let done = cluster.sim_get(t, object_size);
                        lat.add((done - t) as f64 / 1e6);
                        bytes += object_size;
                        ops += 1;
                        push(&mut heap, &mut pending, Ev { t: done, worker: ev.worker, phase: Phase::Issue });
                    }
                    Some(k) => {
                        let p1 = cluster.gb_register(t, k);
                        let t_reg = p1.t_reg;
                        push(
                            &mut heap,
                            &mut pending,
                            Ev { t: t_reg, worker: ev.worker, phase: Phase::FanIn(Box::new(p1)) },
                        );
                    }
                }
            }
            Phase::FanIn(p1) => {
                let last_arrival = cluster.gb_fanin(&p1, object_size);
                push(
                    &mut heap,
                    &mut pending,
                    Ev { t: last_arrival, worker: ev.worker, phase: Phase::Out(p1, last_arrival) },
                );
            }
            Phase::Out(p1, last_arrival) => {
                let k = batch.unwrap() as u64;
                let done = cluster.gb_stream_out(&p1, k * object_size, last_arrival);
                let t0 = issue_at[ev.worker];
                lat.add((done - t0) as f64 / 1e6);
                bytes += k * object_size;
                ops += k;
                push(&mut heap, &mut pending, Ev { t: done, worker: ev.worker, phase: Phase::Issue });
            }
        }
    }

    let label = match batch {
        None => format!("GET {}", crate::util::bytes::fmt_size(object_size)),
        Some(k) => format!("GetBatch({k}) {}", crate::util::bytes::fmt_size(object_size)),
    };
    SyntheticResult {
        label,
        throughput: Throughput { bytes, ops, secs: sim_seconds },
        batch_latency_ms: lat.row(),
    }
}

/// Result of one training-trace configuration.
#[derive(Debug, Clone)]
pub struct TrainingResult {
    pub mode: AccessMode,
    pub batch_ms: LatencyRow,
    pub per_object_ms: LatencyRow,
}

/// Training-workload latency study (§4.2.1): `loaders` data-loader workers
/// (4 A100 nodes × 64 = 256 in the paper) against the 16-node cluster.
/// Bursty: each loader computes for `step_ms` between loads (synchronous
/// training), so I/O queues are not continuously saturated.
///
/// Sample sizes are log-normal (median ~90 KiB — speech segments); a batch
/// draws `batch_size` samples.
pub fn run_training(
    m: &CostModel,
    mode: AccessMode,
    loaders: usize,
    batch_size: usize,
    steps_per_loader: usize,
    step_ms: f64,
    seed: u64,
) -> TrainingResult {
    let mut cluster = SimCluster::new(m.clone(), seed);
    let mut rng = Rng::new(seed ^ 0x7EA1);
    let mut batch_lat = Samples::new();
    let mut obj_lat = Samples::new();

    // Loader state machines. A loader worker prefetches CONC samples at a
    // time in RandomGet mode (typical DataLoader worker with a small
    // prefetch depth); batches are fetched sample-by-sample otherwise.
    const CONC: usize = 2;
    struct Loader {
        issue_t: u64,
        remaining_steps: usize,
        // RandomGet in-flight bookkeeping
        samples_left: usize,
        inflight: usize,
        batch_done_at: u64,
    }
    let mut states: Vec<Loader> = (0..loaders)
        .map(|i| Loader {
            issue_t: (i as u64) * 1_000_000,
            remaining_steps: steps_per_loader,
            samples_left: 0,
            inflight: 0,
            batch_done_at: 0,
        })
        .collect();

    // events: (time, loader, kind) kind 0=issue batch, 1=slot free (RandomGet)
    let mut heap: BinaryHeap<Reverse<(u64, usize, u8)>> = BinaryHeap::new();
    for (i, s) in states.iter().enumerate() {
        heap.push(Reverse((s.issue_t, i, 0)));
    }

    let sample_size = |rng: &mut Rng| -> u64 {
        rng.lognormal(90.0 * 1024.0, 0.7).max(2048.0) as u64
    };

    while let Some(Reverse((t, w, kind))) = heap.pop() {
        match kind {
            0 => {
                // issue one training step's batch
                if states[w].remaining_steps == 0 {
                    continue;
                }
                states[w].remaining_steps -= 1;
                states[w].issue_t = t;
                match mode {
                    AccessMode::RandomGet => {
                        states[w].samples_left = batch_size;
                        states[w].inflight = 0;
                        states[w].batch_done_at = t;
                        // kick CONC fetch slots
                        for _ in 0..CONC.min(batch_size) {
                            let s = sample_size(&mut rng);
                            let done = cluster.sim_get(t, s);
                            obj_lat.add((done - t) as f64 / 1e6);
                            states[w].samples_left -= 1;
                            states[w].inflight += 1;
                            heap.push(Reverse((done, w, 1)));
                        }
                    }
                    AccessMode::GetBatch => {
                        let sizes: Vec<u64> = (0..batch_size).map(|_| sample_size(&mut rng)).collect();
                        let mean = sizes.iter().sum::<u64>() / sizes.len() as u64;
                        let p1 = cluster.gb_register(t, batch_size);
                        let last = cluster.gb_fanin(&p1, mean);
                        let done = cluster.gb_stream_out(&p1, sizes.iter().sum(), last);
                        let per = (done - t) as f64 / 1e6 / batch_size as f64;
                        for _ in 0..batch_size {
                            obj_lat.add(per);
                        }
                        batch_lat.add((done - t) as f64 / 1e6);
                        heap.push(Reverse((done + (step_ms * 1e6) as u64, w, 0)));
                    }
                    AccessMode::Sequential => {
                        // one shard read covers the batch: a single large
                        // object streamed from one open connection.
                        let total: u64 = (0..batch_size).map(|_| sample_size(&mut rng)).sum();
                        let done = cluster.sim_get(t, total);
                        let per = (done - t) as f64 / 1e6 / batch_size as f64;
                        for _ in 0..batch_size {
                            obj_lat.add(per);
                        }
                        batch_lat.add((done - t) as f64 / 1e6);
                        heap.push(Reverse((done + (step_ms * 1e6) as u64, w, 0)));
                    }
                }
            }
            _ => {
                // RandomGet: a fetch slot completed at time t
                states[w].inflight -= 1;
                states[w].batch_done_at = states[w].batch_done_at.max(t);
                if states[w].samples_left > 0 {
                    let s = sample_size(&mut rng);
                    let done = cluster.sim_get(t, s);
                    obj_lat.add((done - t) as f64 / 1e6);
                    states[w].samples_left -= 1;
                    states[w].inflight += 1;
                    heap.push(Reverse((done, w, 1)));
                } else if states[w].inflight == 0 {
                    // batch complete: the slowest sample gates the step (§4.2.2)
                    let t0 = states[w].issue_t;
                    batch_lat.add((states[w].batch_done_at - t0) as f64 / 1e6);
                    heap.push(Reverse((states[w].batch_done_at + (step_ms * 1e6) as u64, w, 0)));
                }
            }
        }
    }
    TrainingResult { mode, batch_ms: batch_lat.row(), per_object_ms: obj_lat.row() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_small_objects() {
        let m = CostModel::oci_16node();
        let get = run_synthetic(&m, 80, 10 << 10, None, 2.0, 1);
        let b128 = run_synthetic(&m, 80, 10 << 10, Some(128), 2.0, 2);
        let speedup = b128.throughput.gib_per_sec() / get.throughput.gib_per_sec();
        assert!(speedup > 5.0, "10KiB batch128 speedup {speedup:.1} (paper: 15x)");
    }

    #[test]
    fn table1_shape_large_objects_converge() {
        let m = CostModel::oci_16node();
        let get = run_synthetic(&m, 80, 1 << 20, None, 2.0, 3);
        let b128 = run_synthetic(&m, 80, 1 << 20, Some(128), 2.0, 4);
        let speedup = b128.throughput.gib_per_sec() / get.throughput.gib_per_sec();
        assert!(speedup < 4.0, "1MiB speedup should be small, got {speedup:.1}");
        assert!(speedup > 0.8, "1MiB GetBatch should not lose, got {speedup:.1}");
    }

    #[test]
    fn batch_size_monotone() {
        let m = CostModel::oci_16node();
        let t32 = run_synthetic(&m, 80, 10 << 10, Some(32), 1.5, 5).throughput.gib_per_sec();
        let t128 = run_synthetic(&m, 80, 10 << 10, Some(128), 1.5, 6).throughput.gib_per_sec();
        assert!(t128 > t32, "t32={t32:.2} t128={t128:.2}");
    }

    /// Satellite: smoke-test both drivers under `cargo test` with tiny
    /// configs, locking the output *shape* (labels, sample counts, and the
    /// percentile invariants) so a refactor can't silently change what the
    /// experiment binaries print.
    #[test]
    fn smoke_tiny_configs_lock_output_shape() {
        let m = CostModel::oci_16node();
        // Synthetic, batched: ops arrive k-at-a-time and bytes = ops × size.
        let syn = run_synthetic(&m, 2, 64 << 10, Some(4), 0.1, 11);
        assert_eq!(syn.label, "GetBatch(4) 64KiB");
        assert!(syn.throughput.ops > 0 && syn.throughput.ops % 4 == 0);
        assert_eq!(syn.throughput.bytes, syn.throughput.ops * (64 << 10));
        assert_eq!(syn.batch_latency_ms.n as u64, syn.throughput.ops / 4);
        assert!(syn.batch_latency_ms.p50 > 0.0);
        assert!(syn.batch_latency_ms.p50 <= syn.batch_latency_ms.p99);
        // Synthetic, per-object GET baseline.
        let get = run_synthetic(&m, 1, 4096, None, 0.05, 12);
        assert_eq!(get.label, "GET 4KiB");
        assert!(get.throughput.ops > 0);
        assert_eq!(get.throughput.bytes, get.throughput.ops * 4096);
        // Training: every mode yields exactly loaders×steps batch samples
        // and loaders×steps×batch_size per-object samples.
        for (mode, seed) in
            [(AccessMode::Sequential, 13), (AccessMode::RandomGet, 14), (AccessMode::GetBatch, 15)]
        {
            let r = run_training(&m, mode, 2, 4, 3, 1.0, seed);
            assert_eq!(r.mode, mode);
            assert_eq!(r.batch_ms.n, 6, "{mode:?}");
            assert_eq!(r.per_object_ms.n, 24, "{mode:?}");
            assert!(r.batch_ms.p50 > 0.0 && r.batch_ms.p99 >= r.batch_ms.p50, "{mode:?}");
        }
    }

    #[test]
    fn table2_ordering_of_methods() {
        let m = CostModel::oci_16node();
        let seq = run_training(&m, AccessMode::Sequential, 64, 64, 6, 100.0, 7);
        let get = run_training(&m, AccessMode::RandomGet, 64, 64, 6, 100.0, 8);
        let gb = run_training(&m, AccessMode::GetBatch, 64, 64, 6, 100.0, 9);
        // medians: sequential < getbatch < random-get
        assert!(seq.batch_ms.p50 < gb.batch_ms.p50, "seq {} gb {}", seq.batch_ms.p50, gb.batch_ms.p50);
        assert!(gb.batch_ms.p50 < get.batch_ms.p50, "gb {} get {}", gb.batch_ms.p50, get.batch_ms.p50);
        // tails: GetBatch well below RandomGet at P95/P99
        assert!(gb.batch_ms.p95 < get.batch_ms.p95);
        assert!(gb.per_object_ms.p99 < get.per_object_ms.p99);
        // absolute tail (§4.2.2): GetBatch's worst stalls shorter than GET's
        assert!(gb.batch_ms.p99 < get.batch_ms.p99);
    }
}
