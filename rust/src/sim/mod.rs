//! Discrete-event cluster simulator (paper-scale experiments). See event.rs
//! for the event core and scale.rs for the time-virtualized million-client
//! harness that replays simulated clients against the *real* admission,
//! order-buffer, and cache code.
pub mod event;
pub mod model;
pub mod cluster;
pub mod workload;
pub mod scale;
