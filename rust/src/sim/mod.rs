//! Discrete-event cluster simulator (paper-scale experiments). See event.rs.
pub mod event;
pub mod model;
pub mod cluster;
pub mod workload;
