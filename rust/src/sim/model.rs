//! Cost model: the paper's OCI testbed (§3 cluster configuration) expressed
//! as service-time constants. Absolute values are calibrated so the
//! *baseline* GET column of Table 1 lands near the paper's numbers; the
//! GetBatch columns then emerge from the execution model, not from fitting.

/// All times in ns, bandwidths in bytes/s.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub nodes: usize,
    pub disks_per_node: usize,
    /// Per-IO latency of one NVMe read (queue + seek + firmware).
    pub disk_io_ns: u64,
    /// Per-disk sequential read bandwidth.
    pub disk_bw: f64,
    /// Node NIC bandwidth (100 Gbps).
    pub nic_bw: f64,
    /// Effective single-TCP-stream bandwidth (window/congestion bound).
    pub stream_bw: f64,
    /// One network round trip (client↔cluster or target↔target).
    pub rtt_ns: u64,
    /// Control-plane cost of one independent GET: connection handling,
    /// HTTP parse, request scheduling at proxy + target.
    pub per_request_cpu_ns: u64,
    /// Per-entry cost inside a GetBatch at a *sender* (no connection setup,
    /// no HTTP parse — just read scheduling + framing).
    pub batch_entry_cpu_ns: u64,
    /// Per-entry cost at the DT (ordering + TAR serialization).
    pub dt_entry_cpu_ns: u64,
    /// Fixed cost of one GetBatch execution (register + broadcast + state).
    pub batch_fixed_cpu_ns: u64,
    /// CPU worker slots per node.
    pub cpu_slots: usize,
    /// Heavy-tail service noise: fraction of ops hit by a straggler factor.
    pub straggler_p: f64,
    pub straggler_mult: f64,
}

impl CostModel {
    /// The §3 testbed: 16 × BM.DenseIO.E5.128 (128 OCPU, 12 NVMe, 100 Gbps).
    pub fn oci_16node() -> CostModel {
        CostModel {
            nodes: 16,
            disks_per_node: 12,
            disk_io_ns: 80_000,            // 80 µs NVMe read latency
            disk_bw: 3.0e9,                // 3 GB/s per drive
            nic_bw: 12.5e9,                // 100 Gbps
            stream_bw: 0.55e9,             // single TCP stream ceiling
            rtt_ns: 250_000,               // 0.25 ms intra-AZ RTT
            per_request_cpu_ns: 500_000,   // ≈0.5 ms per independent GET
            batch_entry_cpu_ns: 50_000,    // 50 µs per batched entry (sender)
            dt_entry_cpu_ns: 60_000,       // 60 µs per entry at the DT (ordering + TAR)
            batch_fixed_cpu_ns: 2_000_000, // register + broadcast
            cpu_slots: 256,            // 128 OCPU / SMT
            straggler_p: 0.02,
            straggler_mult: 8.0,
        }
    }

    /// Disk service time for reading `bytes` in one IO chain.
    pub fn disk_ns(&self, bytes: u64) -> u64 {
        self.disk_io_ns + (bytes as f64 / self.disk_bw * 1e9) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oci_constants_sane() {
        let m = CostModel::oci_16node();
        assert_eq!(m.nodes, 16);
        assert_eq!(m.nodes * m.disks_per_node, 192); // the paper's 192 NVMe
        assert!(m.stream_bw < m.nic_bw);
        assert!(
            m.batch_entry_cpu_ns <= m.per_request_cpu_ns / 10,
            "batching must amortize an order of magnitude of per-request cost"
        );
    }

    #[test]
    fn disk_time_scales_with_size() {
        let m = CostModel::oci_16node();
        assert!(m.disk_ns(1 << 20) > m.disk_ns(10 << 10));
        // 10 KiB read is latency-dominated
        assert!(m.disk_ns(10 << 10) < 2 * m.disk_io_ns);
    }
}
