//! Simulated cluster execution of GET and GetBatch at paper scale.
//!
//! Each node owns: a disk array (c-slot resource), a NIC (pipe), and a CPU
//! pool (c-slot resource). A request's latency is the composition of its
//! resource acquisitions; throughput and tails emerge from contention among
//! the closed-loop workers (sim/workload.rs).
//!
//! The execution model mirrors §2.3.1 exactly:
//!   GET       = RTT + proxy/target per-request CPU + disk + stream out
//!   GetBatch  = fixed register/broadcast + per-sender (entry cpu + disk +
//!               p2p NIC hop) + DT per-entry serialization + one ordered
//!               stream out over the DT's NIC
//! with entries spread over nodes by uniform placement.

use crate::util::rng::Rng;

use super::event::{Pipe, Resource};
use super::model::CostModel;

pub struct SimNode {
    pub disks: Resource,
    /// Full-duplex NIC: independent transmit and receive pipes (100 Gbps each).
    pub nic_tx: Pipe,
    pub nic_rx: Pipe,
    pub cpu: Resource,
}

/// Result of GetBatch phase 1 (registration + placement).
pub struct BatchPhase1 {
    pub dt: usize,
    pub t_reg: u64,
    pub counts: Vec<u32>,
}

pub struct SimCluster {
    pub m: CostModel,
    pub nodes: Vec<SimNode>,
    rng: Rng,
}

impl SimCluster {
    pub fn new(m: CostModel, seed: u64) -> SimCluster {
        let nodes = (0..m.nodes)
            .map(|_| SimNode {
                disks: Resource::new(m.disks_per_node),
                nic_tx: Pipe::new(m.nic_bw),
                nic_rx: Pipe::new(m.nic_bw),
                cpu: Resource::new(m.cpu_slots),
            })
            .collect();
        SimCluster { m, nodes, rng: Rng::new(seed) }
    }

    fn straggle(&mut self, service: u64) -> u64 {
        if self.rng.bool(self.m.straggler_p) {
            (service as f64 * self.m.straggler_mult) as u64
        } else {
            // ±20% service-time noise
            (service as f64 * (0.8 + 0.4 * self.rng.f64())) as u64
        }
    }

    /// One independent GET of `bytes` from a uniformly random target.
    pub fn sim_get(&mut self, t0: u64, bytes: u64) -> u64 {
        let tgt = self.rng.usize_below(self.nodes.len());
        // request travels: client → proxy → (redirect) → target
        let t = t0 + self.m.rtt_ns; // proxy hop + redirect (amortized RTT)
        let cpu = self.straggle(self.m.per_request_cpu_ns);
        let t = self.nodes[tgt].cpu.acquire(t, cpu);
        let disk = self.m.disk_ns(bytes);
        let disk = self.straggle(disk);
        let t = self.nodes[tgt].disks.acquire(t, disk);
        // response: bounded by node NIC share and the single stream
        let t = self.nodes[tgt].nic_tx.transfer(t, bytes);
        let stream = (bytes as f64 / self.m.stream_bw * 1e9) as u64;
        t.max(t0 + self.m.rtt_ns + stream) + self.m.rtt_ns / 2
    }

    /// One GetBatch of `k` entries × `bytes` each. Placement: entries spread
    /// uniformly over nodes (HRW-uniform); DT chosen pseudo-randomly.
    /// Returns completion time of the last ordered byte at the client.
    ///
    /// NOTE: atomic execution of the whole chain is only accurate when the
    /// chain is short relative to inter-arrival spacing; the workload
    /// drivers use the phase-split API below with an event heap so long
    /// chains interleave correctly in virtual time.
    pub fn sim_getbatch(&mut self, t0: u64, k: usize, bytes: u64) -> u64 {
        let p1 = self.gb_register(t0, k);
        let last_arrival = self.gb_fanin(&p1, bytes);
        self.gb_stream_out(&p1, k as u64 * bytes, last_arrival)
    }

    /// Phase 1 (§2.3.1): proxy → DT registration + broadcast.
    pub fn gb_register(&mut self, t0: u64, k: usize) -> BatchPhase1 {
        let n = self.nodes.len();
        let dt = self.rng.usize_below(n);
        let fixed = self.straggle(self.m.batch_fixed_cpu_ns);
        let t_reg = self.nodes[dt].cpu.acquire(t0 + self.m.rtt_ns, fixed);
        let mut counts = vec![0u32; n];
        for _ in 0..k {
            counts[self.rng.usize_below(n)] += 1;
        }
        BatchPhase1 { dt, t_reg, counts }
    }

    /// Phase 2 (§2.3.1): senders resolve + push concurrently; each entry
    /// costs CPU + disk (c-slot resources); each sender's payload crosses
    /// its NIC once as one pooled-connection burst (persistent P2P, no
    /// per-entry connection setup). Returns the fan-in completion time.
    pub fn gb_fanin(&mut self, p1: &BatchPhase1, bytes: u64) -> u64 {
        let BatchPhase1 { dt, t_reg, counts } = p1;
        let (dt, t_reg) = (*dt, *t_reg);
        let mut last_arrival = t_reg;
        for s in 0..self.nodes.len() {
            if counts[s] == 0 {
                continue;
            }
            let t_s = t_reg + if s == dt { 0 } else { self.m.rtt_ns / 2 };
            let mut node_done = t_s;
            for _ in 0..counts[s] {
                let cpu = self.straggle(self.m.batch_entry_cpu_ns);
                let t = self.nodes[s].cpu.acquire(t_s, cpu);
                let disk = self.straggle(self.m.disk_ns(bytes));
                let t = self.nodes[s].disks.acquire(t, disk);
                node_done = node_done.max(t);
            }
            if s != dt {
                // burst the node's share over its NIC into the DT NIC
                let sent = self.nodes[s].nic_tx.transfer(node_done, counts[s] as u64 * bytes);
                let recv = self.nodes[dt].nic_rx.transfer(sent, counts[s] as u64 * bytes);
                last_arrival = last_arrival.max(recv);
            } else {
                last_arrival = last_arrival.max(node_done);
            }
        }
        last_arrival
    }

    /// Phase 3: the DT serializes the TAR stream — inherently sequential
    /// per request (this *is* the serialization point of §5.2) — then ships
    /// one response, bounded by its NIC share and the single-stream
    /// ceiling. Streaming overlaps fan-in with emission, so completion is
    /// the max of the fan-in critical path and the stream time.
    pub fn gb_stream_out(&mut self, p1: &BatchPhase1, total: u64, last_arrival: u64) -> u64 {
        // TAR serialization is sequential per request (k entries x per-entry
        // cost); it starts once entries begin arriving — approximated as the
        // midpoint of the fan-in window — and its tail lands after fan-in.
        let k: u64 = p1.counts.iter().map(|&c| c as u64).sum();
        let ser_start = p1.t_reg + (last_arrival - p1.t_reg) / 2;
        let ser = self.nodes[p1.dt].cpu.acquire(ser_start, self.m.dt_entry_cpu_ns * k);
        // The response transfer overlaps fan-in (streaming): it *starts* at
        // t_reg; executing it at the Out event keeps global time order, and
        // a past arrival cannot block other requests' earlier ops.
        let nic_out = self.nodes[p1.dt].nic_tx.transfer(p1.t_reg, total);
        let stream_floor = p1.t_reg + (total as f64 / self.m.stream_bw * 1e9) as u64;
        ser.max(nic_out).max(stream_floor).max(last_arrival) + self.m.rtt_ns / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::oci_16node()
    }

    #[test]
    fn get_latency_unloaded_is_overhead_dominated_for_small() {
        let mut c = SimCluster::new(model(), 1);
        let t = c.sim_get(0, 10 << 10);
        // ~1.5 ms: rtt + per-request cpu + tiny disk/transfer
        assert!(t > 800_000 && t < 20_000_000, "t={t}");
    }

    #[test]
    fn getbatch_amortizes_for_small_objects() {
        // mean latency per object must be far lower via GetBatch
        let mut c = SimCluster::new(model(), 2);
        let mut t_get = 0u64;
        for _ in 0..64 {
            t_get += c.sim_get(0, 10 << 10);
        }
        let per_get = t_get / 64;
        let mut c2 = SimCluster::new(model(), 3);
        let batch_done = c2.sim_getbatch(0, 64, 10 << 10);
        let per_batched = batch_done / 64;
        assert!(per_batched * 3 < per_get, "batched {per_batched} vs get {per_get}");
    }

    #[test]
    fn large_objects_converge() {
        // at 1 MiB the advantage should shrink to low single digits
        let mut c = SimCluster::new(model(), 4);
        let get_one = c.sim_get(0, 1 << 20);
        let mut c2 = SimCluster::new(model(), 5);
        let batch = c2.sim_getbatch(0, 32, 1 << 20);
        let per_batched = batch / 32;
        let ratio = get_one as f64 / per_batched as f64;
        assert!(ratio < 8.0, "ratio={ratio}");
    }

    #[test]
    fn contention_raises_latency() {
        let mut c = SimCluster::new(model(), 6);
        let mut worst = 0;
        for _ in 0..400 {
            worst = worst.max(c.sim_getbatch(0, 128, 100 << 10));
        }
        let mut c2 = SimCluster::new(model(), 6);
        let unloaded = c2.sim_getbatch(0, 128, 100 << 10);
        assert!(worst > unloaded * 2, "worst={worst} unloaded={unloaded}");
    }
}
