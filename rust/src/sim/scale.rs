//! Million-client scale harness: a discrete-event driver over the **real**
//! data-plane code, not a model of it.
//!
//! Where `sim/cluster.rs` + `sim/model.rs` simulate the cluster with cost
//! equations (paper-scale throughput figures), this module replays very
//! large seeded client populations against the *actual*
//! [`crate::dt::admission`] gate, [`MemoryBudget`], [`OrderBuffer`] and
//! [`ChunkCache`]/[`CachedBackend`] implementations, time-virtualized via
//! [`VirtualClock`] so that millions of registrations — patience windows,
//! coherence graces and all — elapse in CI seconds. What is modeled is
//! only the *environment*: client arrival times, sender network pacing
//! (a delivery with no budget room is rescheduled later, exactly how TCP
//! backpressure defers a real sender), and consumer pacing. Every
//! admission decision, byte reservation, eviction and pin transition is
//! made by production code.
//!
//! Invariants the harness checks (see [`ScaleReport`] and
//! `rust/tests/sim_scale.rs`):
//!
//! * peak DT-resident bytes ≤ `dt_buffer_bytes`, unconditionally;
//! * cache occupancy ≤ `cache_bytes` at every observation point;
//! * no registration waits past a bounded virtual delay (fairness);
//! * same seed ⇒ byte-identical event trace (deterministic replay),
//!   folded into [`ScaleReport::trace_hash`].

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BTreeMap, BinaryHeap, HashMap};
use std::io;
use std::sync::Arc;
use std::time::Duration;

use crate::client::loader::EpochPlan;
use crate::config::GetBatchConfig;
use crate::dt::admission::{Admission, Admit, MemoryBudget, Priority, TenantLedger};
use crate::dt::order::{OrderBuffer, SlotWait};
use crate::metrics::GetBatchMetrics;
use crate::store::{Backend, CachedBackend, ChunkCache, ChunkSource, EntryReader, StoreError};
use crate::util::clock::VirtualClock;
use crate::util::rng::{mix64, Rng};

/// How a population of clients picks the objects it asks for.
#[derive(Debug, Clone)]
pub enum WorkloadMix {
    /// Every client draws uniformly from the object universe — the
    /// small-object storm (the paper's 15× claim lives here: tiny objects,
    /// enormous request rate, cache mostly cold).
    UniformStorm,
    /// Zipf-skewed draws: a few hot shards absorb most reads, so the cache
    /// and its LRU/pin behavior carry the load. `exponent_centi` is the
    /// Zipf exponent × 100 (integer so the config stays `Eq`-friendly);
    /// 110 ⇒ s = 1.10.
    ZipfHotShards { exponent_centi: u32 },
    /// Clients replay batches of seeded [`EpochPlan`]s (the PR 8 shuffle):
    /// client c of epoch e reads exactly the samples of one plan batch, in
    /// plan order — the training-fleet access pattern.
    EpochReplay { n_samples: usize, batch_size: usize, epochs: u64 },
}

/// Scale-run parameters. All times are virtual nanoseconds.
#[derive(Debug, Clone)]
pub struct ScaleConfig {
    pub clients: u64,
    pub seed: u64,
    pub mix: WorkloadMix,
    /// Object universe size (`EpochReplay` overrides this with
    /// `n_samples`).
    pub n_objects: usize,
    /// Entries (objects) per client batch (`EpochReplay` uses the plan's
    /// batch size instead).
    pub entries_per_client: usize,
    /// Per-object sizes are seeded-uniform in `min_obj_bytes..=max_obj_bytes`.
    pub min_obj_bytes: u64,
    pub max_obj_bytes: u64,
    /// Real knobs, fed to the real components.
    pub dt_buffer_bytes: u64,
    pub chunk_bytes: u64,
    pub mem_critical_bytes: u64,
    pub cache_bytes: u64,
    pub readahead_chunks: usize,
    pub patience: Duration,
    /// Environment model: mean client inter-arrival gap.
    pub arrival_gap_ns: u64,
    /// Sender pacing between an admitted client's entry deliveries.
    pub deliver_gap_ns: u64,
    /// A delivery finding no budget room retries after this long (TCP
    /// backpressure stand-in).
    pub backpressure_ns: u64,
    /// Consumer takes one in-order entry every `consume_ns`.
    pub consume_ns: u64,
    /// Consumer re-poll gap while its next slot is not ready.
    pub poll_ns: u64,
    /// A 429'd client re-registers after this long.
    pub retry_ns: u64,
    /// Fairness bound: the harness panics (naming the seed) if any
    /// registration waits longer than this from first attempt to admission.
    pub starvation_bound_ns: u64,
}

impl ScaleConfig {
    /// Uniform small-object storm at population `clients`.
    pub fn storm(clients: u64, seed: u64) -> ScaleConfig {
        ScaleConfig {
            clients,
            seed,
            mix: WorkloadMix::UniformStorm,
            n_objects: 4096,
            entries_per_client: 2,
            min_obj_bytes: 1 << 10,
            max_obj_bytes: 4 << 10,
            dt_buffer_bytes: 4 << 20,
            chunk_bytes: 4 << 10,
            mem_critical_bytes: 2 << 20,
            cache_bytes: 1 << 20,
            readahead_chunks: 1,
            patience: Duration::from_millis(50),
            arrival_gap_ns: 2_000,
            deliver_gap_ns: 50_000,
            backpressure_ns: 100_000,
            consume_ns: 200_000,
            poll_ns: 100_000,
            retry_ns: 1_000_000,
            starvation_bound_ns: 10_000_000_000, // 10 virtual seconds
        }
    }

    /// Zipf-skewed hot-shard mix: bigger universe, hot head, cache under
    /// real LRU/pin pressure.
    pub fn zipf(clients: u64, seed: u64) -> ScaleConfig {
        ScaleConfig {
            mix: WorkloadMix::ZipfHotShards { exponent_centi: 110 },
            n_objects: 16384,
            entries_per_client: 3,
            min_obj_bytes: 2 << 10,
            max_obj_bytes: 16 << 10,
            cache_bytes: 4 << 20,
            ..ScaleConfig::storm(clients, seed)
        }
    }

    /// Epoch-shuffle replay over PR 8 plans: every client consumes one
    /// plan batch of a shared deterministic shuffle.
    pub fn epoch_replay(clients: u64, seed: u64) -> ScaleConfig {
        ScaleConfig {
            mix: WorkloadMix::EpochReplay { n_samples: 4096, batch_size: 8, epochs: 3 },
            min_obj_bytes: 1 << 10,
            max_obj_bytes: 8 << 10,
            cache_bytes: 8 << 20,
            ..ScaleConfig::storm(clients, seed)
        }
    }
}

/// What one scale run did and the invariant evidence it gathered.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScaleReport {
    pub clients: u64,
    /// Clients that registered, delivered, and drained every entry.
    pub completed: u64,
    /// 429s issued by the real admission gate (re-registrations retry).
    pub rejected: u64,
    /// Deliveries deferred because the budget had no room (backpressure).
    pub backpressured: u64,
    /// High-water mark of DT-resident bytes, from the real budget.
    pub peak_resident: u64,
    pub dt_buffer_bytes: u64,
    /// Highest cache occupancy observed at any delivery.
    pub cache_peak: u64,
    pub cache_bytes: u64,
    /// Patience-expiry force admissions (must be 0: backpressure defers
    /// senders before patience ever runs out).
    pub overruns: u64,
    /// Longest first-attempt → admission wait (virtual ns).
    pub max_admission_wait_ns: u64,
    /// Virtual instant the last event ran at.
    pub virtual_ns: u64,
    /// Total events dispatched.
    pub events: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Seeded fold of every (time, kind, client, outcome) tuple — equal
    /// across runs iff the event traces are identical.
    pub trace_hash: u64,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum EvKind {
    /// (Re-)attempt registration at the admission gate.
    Arrive,
    /// Entry `i`'s payload reaches the DT (sender side).
    Deliver(u32),
    /// Consumer tries to take its next in-order entry.
    Drain,
    /// The client abandons its execution (multi-tenant hog reap; never
    /// scheduled by single-tenant scale runs).
    Abort,
}

/// Heap entry; min-ordered by `(at, seq)` so dispatch order — and thus the
/// whole run — is a pure function of the seed. `seq` breaks time ties in
/// schedule order; no iteration order of any map ever decides anything.
struct Ev {
    at: u64,
    seq: u64,
    client: u32,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> CmpOrdering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// Deterministic in-memory object universe: object `o<i>` has a seeded
/// size and procedurally generated bytes, so a million clients can read
/// through the real cache without staging gigabytes on disk.
struct MemBackend {
    sizes: Vec<u64>,
    seed: u64,
}

impl MemBackend {
    fn new(n_objects: usize, min_bytes: u64, max_bytes: u64, seed: u64) -> MemBackend {
        let span = max_bytes.saturating_sub(min_bytes) + 1;
        let sizes = (0..n_objects as u64)
            .map(|i| min_bytes + mix64(seed ^ mix64(i + 1)) % span)
            .collect();
        MemBackend { sizes, seed }
    }

    fn idx(&self, obj: &str) -> Result<usize, StoreError> {
        obj.strip_prefix('o')
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&i| i < self.sizes.len())
            .ok_or_else(|| StoreError::NotFound(format!("sim object {obj}")))
    }

    fn source(&self, i: usize, base: u64, len: u64) -> Box<dyn ChunkSource> {
        Box::new(MemSource { seed: mix64(self.seed ^ ((i as u64) << 1)), base, len })
    }
}

struct MemSource {
    seed: u64,
    base: u64,
    len: u64,
}

impl ChunkSource for MemSource {
    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize> {
        if pos >= self.len {
            return Ok(0);
        }
        let n = ((self.len - pos) as usize).min(buf.len());
        for (k, b) in buf[..n].iter_mut().enumerate() {
            let p = self.base + pos + k as u64;
            *b = (self.seed ^ p) as u8;
        }
        Ok(n)
    }
    fn observed_version(&self) -> Option<u64> {
        Some(1)
    }
}

impl Backend for MemBackend {
    fn open_entry(&self, _bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let i = self.idx(obj)?;
        let len = self.sizes[i];
        Ok(EntryReader::from_source(self.source(i, 0, len), len))
    }
    fn open_entry_range(
        &self,
        _bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let i = self.idx(obj)?;
        if offset + len > self.sizes[i] {
            return Err(StoreError::NotFound(format!("range past end of {obj}")));
        }
        Ok(EntryReader::from_source(self.source(i, offset, len), len))
    }
    fn put(&self, _bucket: &str, obj: &str, _data: &[u8]) -> Result<(), StoreError> {
        Err(StoreError::Io(io::Error::new(
            io::ErrorKind::Unsupported,
            format!("sim backend is read-only ({obj})"),
        )))
    }
    fn exists(&self, _bucket: &str, obj: &str) -> bool {
        self.idx(obj).is_ok()
    }
    fn size(&self, _bucket: &str, obj: &str) -> Result<u64, StoreError> {
        Ok(self.sizes[self.idx(obj)?])
    }
    fn delete(&self, _bucket: &str, obj: &str) -> Result<(), StoreError> {
        Err(StoreError::Io(io::Error::new(
            io::ErrorKind::Unsupported,
            format!("sim backend is read-only ({obj})"),
        )))
    }
    fn list(&self, _bucket: &str) -> Result<Vec<String>, StoreError> {
        Ok((0..self.sizes.len()).map(|i| format!("o{i}")).collect())
    }
    fn content_crc(&self, _bucket: &str, _obj: &str) -> Option<u32> {
        None
    }
    fn content_version(&self, _bucket: &str, _obj: &str) -> Option<u64> {
        Some(1)
    }
}

/// Per-client view of the workload: which objects, in which order.
struct Workload {
    mix: WorkloadMix,
    sizes: Vec<u64>,
    /// Zipf cumulative weights (fixed-point), empty otherwise.
    zipf_cum: Vec<u64>,
    /// Precomputed epoch plans, empty otherwise.
    plans: Vec<EpochPlan>,
    entries_per_client: usize,
    seed: u64,
}

impl Workload {
    fn new(cfg: &ScaleConfig, sizes: Vec<u64>) -> Workload {
        let mut zipf_cum = Vec::new();
        let mut plans = Vec::new();
        match &cfg.mix {
            WorkloadMix::UniformStorm => {}
            WorkloadMix::ZipfHotShards { exponent_centi } => {
                // Integer cumulative table built once from f64 weights:
                // sampling itself stays integer-only.
                let s = *exponent_centi as f64 / 100.0;
                let mut acc = 0u64;
                for i in 0..sizes.len() {
                    let w = (1e9 / ((i + 1) as f64).powf(s)) as u64;
                    acc += w.max(1);
                    zipf_cum.push(acc);
                }
            }
            WorkloadMix::EpochReplay { n_samples, batch_size, epochs } => {
                for e in 0..*epochs {
                    plans.push(EpochPlan::new(*n_samples, *batch_size, cfg.seed, e));
                }
            }
        }
        Workload {
            mix: cfg.mix.clone(),
            sizes,
            zipf_cum,
            plans,
            entries_per_client: cfg.entries_per_client.max(1),
            seed: cfg.seed,
        }
    }

    /// The (object index, bytes) list client `c` will request — a pure
    /// function of (seed, c).
    fn entries(&self, c: u64) -> Vec<(u32, u64)> {
        let mut rng = Rng::new(mix64(self.seed ^ mix64(c.wrapping_add(0x5eed))));
        match &self.mix {
            WorkloadMix::UniformStorm => (0..self.entries_per_client)
                .map(|_| {
                    let i = rng.usize_below(self.sizes.len());
                    (i as u32, self.sizes[i])
                })
                .collect(),
            WorkloadMix::ZipfHotShards { .. } => (0..self.entries_per_client)
                .map(|_| {
                    let total = *self.zipf_cum.last().expect("nonempty universe");
                    let r = rng.below(total);
                    let i = self.zipf_cum.partition_point(|&cum| cum <= r);
                    (i as u32, self.sizes[i])
                })
                .collect(),
            WorkloadMix::EpochReplay { .. } => {
                let plan = &self.plans[(c % self.plans.len() as u64) as usize];
                let b = ((c / self.plans.len() as u64) % plan.n_batches() as u64) as usize;
                plan.batch(b)
                    .expect("batch index in range")
                    .iter()
                    .map(|&i| (i as u32, self.sizes[i]))
                    .collect()
            }
        }
    }
}

/// An admitted client mid-flight.
struct Live {
    buf: Arc<OrderBuffer>,
    entries: Vec<(u32, u64)>,
    next_take: u32,
}

/// Run one seeded scale scenario to completion and report the evidence.
///
/// Panics (naming the seed) if any registration starves past
/// `starvation_bound_ns` or any invariant breaks mid-run — a panic is a
/// test failure with a reproducible seed attached.
pub fn run_scale(cfg: &ScaleConfig) -> ScaleReport {
    let clock = VirtualClock::new();
    let metrics = GetBatchMetrics::new();
    let budget = MemoryBudget::with_clock(
        cfg.dt_buffer_bytes,
        cfg.chunk_bytes,
        cfg.patience,
        Some(Arc::clone(&metrics)),
        clock.clone(),
    );
    let gcfg = GetBatchConfig {
        mem_critical_bytes: cfg.mem_critical_bytes,
        dt_buffer_bytes: cfg.dt_buffer_bytes,
        chunk_bytes: cfg.chunk_bytes as usize,
        cache_bytes: cfg.cache_bytes,
        ..Default::default()
    };
    let adm = Admission::new(gcfg, Arc::clone(&metrics), clock.clone());
    let cache = Arc::new(ChunkCache::with_clock(
        cfg.cache_bytes,
        cfg.chunk_bytes as usize,
        None,
        clock.clone(),
    ));
    let (n_objects, min_b, max_b) = match &cfg.mix {
        WorkloadMix::EpochReplay { n_samples, .. } => {
            (*n_samples, cfg.min_obj_bytes, cfg.max_obj_bytes)
        }
        _ => (cfg.n_objects, cfg.min_obj_bytes, cfg.max_obj_bytes),
    };
    let backend = Arc::new(MemBackend::new(n_objects, min_b, max_b, cfg.seed));
    let sizes = backend.sizes.clone();
    let cached = CachedBackend::new(
        Arc::clone(&backend) as Arc<dyn Backend>,
        Arc::clone(&cache),
        cfg.readahead_chunks,
        // Objects never change mid-run; a long grace keeps warm opens off
        // the (virtual) revalidation path, like a healthy production node.
        Duration::from_secs(3600),
    );
    let workload = Workload::new(cfg, sizes);

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq: u64 = 0;
    let mut arrivals = Rng::new(mix64(cfg.seed ^ 0xA221_7A1)); // arrival jitter
    let mut at = 0u64;
    for c in 0..cfg.clients {
        at += 1 + arrivals.below(cfg.arrival_gap_ns.max(1) * 2); // mean ≈ gap
        heap.push(Ev { at, seq, client: c as u32, kind: EvKind::Arrive });
        seq += 1;
    }

    let mut live: HashMap<u32, Live> = HashMap::new();
    let mut first_try: HashMap<u32, u64> = HashMap::new();
    let mut report = ScaleReport {
        clients: cfg.clients,
        completed: 0,
        rejected: 0,
        backpressured: 0,
        peak_resident: 0,
        dt_buffer_bytes: cfg.dt_buffer_bytes,
        cache_peak: 0,
        cache_bytes: cfg.cache_bytes,
        overruns: 0,
        max_admission_wait_ns: 0,
        virtual_ns: 0,
        events: 0,
        cache_hits: 0,
        cache_misses: 0,
        trace_hash: mix64(cfg.seed),
    };
    let fold = |h: &mut u64, x: u64| *h = mix64(*h ^ x);

    while let Some(ev) = heap.pop() {
        clock.advance_to(ev.at);
        report.events += 1;
        report.virtual_ns = ev.at;
        let cid = ev.client as u64;
        match ev.kind {
            EvKind::Arrive => {
                let t0 = *first_try.entry(ev.client).or_insert(ev.at);
                match adm.check_register() {
                    Admit::Ok => {
                        let wait = ev.at - t0;
                        report.max_admission_wait_ns = report.max_admission_wait_ns.max(wait);
                        first_try.remove(&ev.client);
                        let entries = workload.entries(cid);
                        let buf = Arc::new(OrderBuffer::with_budget(
                            entries.len(),
                            Arc::clone(&budget),
                        ));
                        for (i, _) in entries.iter().enumerate() {
                            heap.push(Ev {
                                at: ev.at + (i as u64 + 1) * cfg.deliver_gap_ns,
                                seq,
                                client: ev.client,
                                kind: EvKind::Deliver(i as u32),
                            });
                            seq += 1;
                        }
                        heap.push(Ev {
                            at: ev.at + cfg.consume_ns,
                            seq,
                            client: ev.client,
                            kind: EvKind::Drain,
                        });
                        seq += 1;
                        live.insert(ev.client, Live { buf, entries, next_take: 0 });
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 1);
                    }
                    Admit::RejectMemory { .. } | Admit::RejectOverrun { .. } => {
                        report.rejected += 1;
                        if ev.at - t0 > cfg.starvation_bound_ns {
                            panic!(
                                "client {cid} starved: first try {t0} ns, still rejected at \
                                 {} ns (bound {} ns, seed {})",
                                ev.at, cfg.starvation_bound_ns, cfg.seed
                            );
                        }
                        heap.push(Ev {
                            at: ev.at + cfg.retry_ns,
                            seq,
                            client: ev.client,
                            kind: EvKind::Arrive,
                        });
                        seq += 1;
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 2);
                    }
                }
            }
            EvKind::Deliver(i) => {
                let l = live.get(&ev.client).expect("deliver for a live client");
                let (obj, bytes) = l.entries[i as usize];
                if !budget.has_room(bytes) {
                    // The real-world analogue: the DT's socket window is
                    // closed, the sender's chunk sits in flight until TCP
                    // opens it again. Defer, never force.
                    report.backpressured += 1;
                    heap.push(Ev {
                        at: ev.at + cfg.backpressure_ns,
                        seq,
                        client: ev.client,
                        kind: EvKind::Deliver(i),
                    });
                    seq += 1;
                    fold(&mut report.trace_hash, ev.at);
                    fold(&mut report.trace_hash, (cid << 3) | 4);
                } else {
                    let data = cached
                        .open_entry("sim", &format!("o{obj}"))
                        .and_then(|r| r.read_all())
                        .unwrap_or_else(|e| {
                            panic!("sim object o{obj} unreadable: {e} (seed {})", cfg.seed)
                        });
                    assert_eq!(data.len() as u64, bytes, "size oracle (seed {})", cfg.seed);
                    l.buf.fill(i, data);
                    let resident = cache.resident_bytes();
                    assert!(
                        resident <= cfg.cache_bytes,
                        "cache occupancy {resident} exceeds {} (seed {})",
                        cfg.cache_bytes,
                        cfg.seed
                    );
                    report.cache_peak = report.cache_peak.max(resident);
                    fold(&mut report.trace_hash, ev.at);
                    fold(&mut report.trace_hash, (cid << 3) | 3);
                }
            }
            EvKind::Drain => {
                let l = live.get_mut(&ev.client).expect("drain for a live client");
                // Duration::ZERO never parks: the slot is either ready now
                // or the consumer re-polls at a later virtual instant.
                match l.buf.wait_take(l.next_take, Duration::ZERO) {
                    SlotWait::Ready(data) => {
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 5);
                        fold(&mut report.trace_hash, data.len() as u64);
                        l.next_take += 1;
                        if l.next_take as usize == l.entries.len() {
                            let l = live.remove(&ev.client).expect("still live");
                            l.buf.close();
                            report.completed += 1;
                        } else {
                            heap.push(Ev {
                                at: ev.at + cfg.consume_ns,
                                seq,
                                client: ev.client,
                                kind: EvKind::Drain,
                            });
                            seq += 1;
                        }
                    }
                    SlotWait::TimedOut => {
                        heap.push(Ev {
                            at: ev.at + cfg.poll_ns,
                            seq,
                            client: ev.client,
                            kind: EvKind::Drain,
                        });
                        seq += 1;
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 6);
                    }
                    SlotWait::Failed(e) => {
                        panic!("slot failed in sim: {e:?} (seed {})", cfg.seed)
                    }
                }
            }
            EvKind::Abort => unreachable!("single-tenant scale runs schedule no aborts"),
        }
        let peak = budget.peak();
        assert!(
            peak <= cfg.dt_buffer_bytes,
            "resident peak {peak} exceeds dt_buffer_bytes {} (seed {})",
            cfg.dt_buffer_bytes,
            cfg.seed
        );
    }

    assert_eq!(
        report.completed, cfg.clients,
        "every client must finish (seed {})",
        cfg.seed
    );
    assert!(live.is_empty() && first_try.is_empty(), "no client left behind");
    report.peak_resident = budget.peak();
    report.overruns = budget.overruns();
    report.cache_hits = cache.hits.get();
    report.cache_misses = cache.misses.get();
    // Fold the end-state counters so two "identical" traces with different
    // cache behavior can't hash equal.
    fold(&mut report.trace_hash, report.peak_resident);
    fold(&mut report.trace_hash, report.cache_peak);
    fold(&mut report.trace_hash, report.cache_hits);
    fold(&mut report.trace_hash, report.cache_misses);
    fold(&mut report.trace_hash, report.rejected);
    fold(&mut report.trace_hash, report.backpressured);
    fold(&mut report.trace_hash, report.events);
    report
}

// ------------------------------------------------------------- multi-tenant --

/// Parameters for [`run_multi_tenant`]: one misbehaving "hog" tenant —
/// oversized bulk-class batches it registers and then never drains —
/// replayed against a steady population of well-behaved interactive
/// clients. All times are virtual nanoseconds.
#[derive(Debug, Clone)]
pub struct MultiTenantConfig {
    pub steady_clients: u64,
    /// Hog batch registrations (each is a separate execution; the tenant
    /// ledger caps their *combined* residency).
    pub hog_batches: u64,
    pub seed: u64,
    pub dt_buffer_bytes: u64,
    pub chunk_bytes: u64,
    pub mem_critical_bytes: u64,
    pub patience: Duration,
    pub steady_entry_bytes: u64,
    pub entries_per_client: usize,
    /// Oversized hog entries (per-entry bytes and count per batch).
    pub hog_entry_bytes: u64,
    pub hog_entries: usize,
    /// Mean steady client inter-arrival gap.
    pub arrival_gap_ns: u64,
    /// First hog registration instant (after the steady stream is active).
    pub hog_start_ns: u64,
    /// Gap between successive hog batch registrations.
    pub hog_gap_ns: u64,
    pub deliver_gap_ns: u64,
    pub backpressure_ns: u64,
    pub consume_ns: u64,
    pub poll_ns: u64,
    pub retry_ns: u64,
    /// The hog abandons an admitted execution (or gives up on a rejected
    /// one) after this long — it never drains a byte.
    pub hog_abort_ns: u64,
    /// Fairness bound for *steady* clients only; the harness panics
    /// (naming the seed) if a steady registration waits longer.
    pub starvation_bound_ns: u64,
}

impl MultiTenantConfig {
    /// Canonical hog-vs-steady scenario: a 1 MiB budget split between one
    /// bulk hog (16 × 64 KiB per batch, never drained) and `steady_clients`
    /// interactive clients with small promptly-drained batches.
    pub fn hog_vs_steady(steady_clients: u64, seed: u64) -> MultiTenantConfig {
        MultiTenantConfig {
            steady_clients,
            hog_batches: 2,
            seed,
            dt_buffer_bytes: 1 << 20,
            chunk_bytes: 4 << 10,
            mem_critical_bytes: 768 << 10,
            patience: Duration::from_millis(50),
            steady_entry_bytes: 4 << 10,
            entries_per_client: 2,
            hog_entry_bytes: 64 << 10,
            hog_entries: 16,
            arrival_gap_ns: 20_000,
            hog_start_ns: 2_000_000,
            hog_gap_ns: 10_000_000,
            deliver_gap_ns: 50_000,
            backpressure_ns: 100_000,
            consume_ns: 200_000,
            poll_ns: 100_000,
            retry_ns: 1_000_000,
            hog_abort_ns: 50_000_000,
            starvation_bound_ns: 10_000_000_000,
        }
    }
}

/// Evidence from one multi-tenant run (see [`run_multi_tenant`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiTenantReport {
    pub steady_clients: u64,
    pub steady_completed: u64,
    /// Steady (interactive) registrations shed by the admission gate.
    pub steady_rejected: u64,
    pub steady_backpressured: u64,
    pub max_steady_admission_wait_ns: u64,
    pub hog_batches: u64,
    pub hog_admitted: u64,
    /// Hog (bulk) registrations shed — lowest class sheds first, so this
    /// climbs while `steady_rejected` stays at zero.
    pub hog_rejected: u64,
    pub hog_aborted: u64,
    pub hog_gave_up: u64,
    pub hog_backpressured: u64,
    /// Peak hog-resident bytes while ≥ 1 steady execution was live — the
    /// fair-share cap in action.
    pub hog_peak_with_steady_bytes: u64,
    /// Peak hog-resident bytes overall (idle shares are borrowable, so
    /// this exceeds the with-steady peak once the steady population ends).
    pub hog_peak_ledger_bytes: u64,
    pub peak_resident: u64,
    pub dt_buffer_bytes: u64,
    pub overruns: u64,
    pub virtual_ns: u64,
    pub events: u64,
    pub trace_hash: u64,
}

/// Replay a misbehaving tenant against well-behaved ones at scale, through
/// the real admission gate ([`Admission::check_register_class`]), the real
/// [`TenantLedger`] fair-share gate and the real [`MemoryBudget`] — the
/// environment model (arrivals, sender pacing, backpressure deferral) is
/// the same as [`run_scale`]'s. Deterministic per seed.
pub fn run_multi_tenant(cfg: &MultiTenantConfig) -> MultiTenantReport {
    const STEADY: &str = "steady";
    const HOG: &str = "hog";
    let clock = VirtualClock::new();
    let metrics = GetBatchMetrics::new();
    let budget = MemoryBudget::with_clock(
        cfg.dt_buffer_bytes,
        cfg.chunk_bytes,
        cfg.patience,
        Some(Arc::clone(&metrics)),
        clock.clone(),
    );
    let gcfg = GetBatchConfig {
        mem_critical_bytes: cfg.mem_critical_bytes,
        dt_buffer_bytes: cfg.dt_buffer_bytes,
        chunk_bytes: cfg.chunk_bytes as usize,
        ..Default::default()
    };
    let adm = Admission::new(gcfg, Arc::clone(&metrics), clock.clone());
    let ledger = TenantLedger::new(
        cfg.dt_buffer_bytes,
        cfg.chunk_bytes,
        BTreeMap::new(), // equal weights
        Some(Arc::clone(&metrics)),
    );

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq: u64 = 0;
    let mut arrivals = Rng::new(mix64(cfg.seed ^ 0x7e4a_47));
    let mut at = 0u64;
    for c in 0..cfg.steady_clients {
        at += 1 + arrivals.below(cfg.arrival_gap_ns.max(1) * 2);
        heap.push(Ev { at, seq, client: c as u32, kind: EvKind::Arrive });
        seq += 1;
    }
    for k in 0..cfg.hog_batches {
        heap.push(Ev {
            at: cfg.hog_start_ns + k * cfg.hog_gap_ns,
            seq,
            client: (cfg.steady_clients + k) as u32,
            kind: EvKind::Arrive,
        });
        seq += 1;
    }

    let mut live: HashMap<u32, Live> = HashMap::new();
    let mut first_try: HashMap<u32, u64> = HashMap::new();
    let mut steady_live: u64 = 0;
    let mut report = MultiTenantReport {
        steady_clients: cfg.steady_clients,
        steady_completed: 0,
        steady_rejected: 0,
        steady_backpressured: 0,
        max_steady_admission_wait_ns: 0,
        hog_batches: cfg.hog_batches,
        hog_admitted: 0,
        hog_rejected: 0,
        hog_aborted: 0,
        hog_gave_up: 0,
        hog_backpressured: 0,
        hog_peak_with_steady_bytes: 0,
        hog_peak_ledger_bytes: 0,
        peak_resident: 0,
        dt_buffer_bytes: cfg.dt_buffer_bytes,
        overruns: 0,
        virtual_ns: 0,
        events: 0,
        trace_hash: mix64(cfg.seed ^ 0x9e5),
    };
    let fold = |h: &mut u64, x: u64| *h = mix64(*h ^ x);

    while let Some(ev) = heap.pop() {
        clock.advance_to(ev.at);
        report.events += 1;
        report.virtual_ns = ev.at;
        let cid = ev.client as u64;
        let hog = cid >= cfg.steady_clients;
        let (tenant, class) =
            if hog { (HOG, Priority::Bulk) } else { (STEADY, Priority::Interactive) };
        match ev.kind {
            EvKind::Arrive => {
                let t0 = *first_try.entry(ev.client).or_insert(ev.at);
                match adm.check_register_class(class) {
                    Admit::Ok => {
                        first_try.remove(&ev.client);
                        let sizes: Vec<u64> = if hog {
                            vec![cfg.hog_entry_bytes; cfg.hog_entries.max(1)]
                        } else {
                            vec![cfg.steady_entry_bytes; cfg.entries_per_client.max(1)]
                        };
                        let buf = Arc::new(OrderBuffer::with_budget_tenant(
                            sizes.len(),
                            Arc::clone(&budget),
                            ledger.handle(tenant),
                        ));
                        for (i, _) in sizes.iter().enumerate() {
                            heap.push(Ev {
                                at: ev.at + (i as u64 + 1) * cfg.deliver_gap_ns,
                                seq,
                                client: ev.client,
                                kind: EvKind::Deliver(i as u32),
                            });
                            seq += 1;
                        }
                        if hog {
                            report.hog_admitted += 1;
                            // The hog never drains: its execution sits on
                            // its resident bytes until reaped.
                            heap.push(Ev {
                                at: ev.at + cfg.hog_abort_ns,
                                seq,
                                client: ev.client,
                                kind: EvKind::Abort,
                            });
                        } else {
                            report.max_steady_admission_wait_ns =
                                report.max_steady_admission_wait_ns.max(ev.at - t0);
                            steady_live += 1;
                            heap.push(Ev {
                                at: ev.at + cfg.consume_ns,
                                seq,
                                client: ev.client,
                                kind: EvKind::Drain,
                            });
                        }
                        seq += 1;
                        let entries = sizes.iter().map(|&b| (0u32, b)).collect();
                        live.insert(ev.client, Live { buf, entries, next_take: 0 });
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 1);
                    }
                    Admit::RejectMemory { .. } | Admit::RejectOverrun { .. } => {
                        if hog {
                            report.hog_rejected += 1;
                            if ev.at - t0 >= cfg.hog_abort_ns {
                                // Even the misbehaving client times out its
                                // batch eventually.
                                first_try.remove(&ev.client);
                                report.hog_gave_up += 1;
                            } else {
                                heap.push(Ev {
                                    at: ev.at + cfg.retry_ns,
                                    seq,
                                    client: ev.client,
                                    kind: EvKind::Arrive,
                                });
                                seq += 1;
                            }
                        } else {
                            report.steady_rejected += 1;
                            if ev.at - t0 > cfg.starvation_bound_ns {
                                panic!(
                                    "steady client {cid} starved: first try {t0} ns, still \
                                     rejected at {} ns (bound {} ns, seed {})",
                                    ev.at, cfg.starvation_bound_ns, cfg.seed
                                );
                            }
                            heap.push(Ev {
                                at: ev.at + cfg.retry_ns,
                                seq,
                                client: ev.client,
                                kind: EvKind::Arrive,
                            });
                            seq += 1;
                        }
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 2);
                    }
                }
            }
            EvKind::Deliver(i) => {
                let Some(l) = live.get(&ev.client) else {
                    // Late frame against a reaped execution: dropped, like
                    // a closed reorder buffer drops late producers.
                    fold(&mut report.trace_hash, ev.at);
                    fold(&mut report.trace_hash, cid << 3);
                    continue;
                };
                let (_, bytes) = l.entries[i as usize];
                // Both real gates, checked the way a sender experiences
                // them: no budget room or no fair-share room ⇒ the chunk
                // stays in flight and retries later (TCP backpressure).
                if !budget.has_room(bytes) || !ledger.would_admit(tenant, bytes) {
                    if hog {
                        report.hog_backpressured += 1;
                    } else {
                        report.steady_backpressured += 1;
                    }
                    heap.push(Ev {
                        at: ev.at + cfg.backpressure_ns,
                        seq,
                        client: ev.client,
                        kind: EvKind::Deliver(i),
                    });
                    seq += 1;
                    fold(&mut report.trace_hash, ev.at);
                    fold(&mut report.trace_hash, (cid << 3) | 4);
                } else {
                    let fill = (mix64(cfg.seed ^ cid) & 0xff) as u8;
                    l.buf.fill(i, vec![fill; bytes as usize]);
                    let hog_used = ledger.used(HOG);
                    report.hog_peak_ledger_bytes = report.hog_peak_ledger_bytes.max(hog_used);
                    if steady_live > 0 {
                        report.hog_peak_with_steady_bytes =
                            report.hog_peak_with_steady_bytes.max(hog_used);
                    }
                    fold(&mut report.trace_hash, ev.at);
                    fold(&mut report.trace_hash, (cid << 3) | 3);
                }
            }
            EvKind::Drain => {
                let l = live.get_mut(&ev.client).expect("drain for a live steady client");
                match l.buf.wait_take(l.next_take, Duration::ZERO) {
                    SlotWait::Ready(data) => {
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 5);
                        fold(&mut report.trace_hash, data.len() as u64);
                        l.next_take += 1;
                        if l.next_take as usize == l.entries.len() {
                            let l = live.remove(&ev.client).expect("still live");
                            l.buf.close();
                            steady_live -= 1;
                            report.steady_completed += 1;
                        } else {
                            heap.push(Ev {
                                at: ev.at + cfg.consume_ns,
                                seq,
                                client: ev.client,
                                kind: EvKind::Drain,
                            });
                            seq += 1;
                        }
                    }
                    SlotWait::TimedOut => {
                        heap.push(Ev {
                            at: ev.at + cfg.poll_ns,
                            seq,
                            client: ev.client,
                            kind: EvKind::Drain,
                        });
                        seq += 1;
                        fold(&mut report.trace_hash, ev.at);
                        fold(&mut report.trace_hash, (cid << 3) | 6);
                    }
                    SlotWait::Failed(e) => {
                        panic!("steady slot failed: {e:?} (seed {})", cfg.seed)
                    }
                }
            }
            EvKind::Abort => {
                if let Some(l) = live.remove(&ev.client) {
                    // close() + drop releases every resident byte back to
                    // the budget AND the tenant ledger (production reap
                    // path semantics).
                    l.buf.close();
                    report.hog_aborted += 1;
                }
                fold(&mut report.trace_hash, ev.at);
                fold(&mut report.trace_hash, (cid << 3) | 7);
            }
        }
        let peak = budget.peak();
        assert!(
            peak <= cfg.dt_buffer_bytes,
            "resident peak {peak} exceeds dt_buffer_bytes {} (seed {})",
            cfg.dt_buffer_bytes,
            cfg.seed
        );
    }

    assert!(live.is_empty() && first_try.is_empty(), "no client left behind (seed {})", cfg.seed);
    report.peak_resident = budget.peak();
    report.overruns = budget.overruns();
    fold(&mut report.trace_hash, report.peak_resident);
    fold(&mut report.trace_hash, report.hog_peak_ledger_bytes);
    fold(&mut report.trace_hash, report.hog_peak_with_steady_bytes);
    fold(&mut report.trace_hash, report.steady_rejected);
    fold(&mut report.trace_hash, report.hog_rejected);
    fold(&mut report.trace_hash, report.hog_backpressured);
    fold(&mut report.trace_hash, report.events);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_small_population_is_deterministic_and_bounded() {
        let cfg = ScaleConfig::storm(2_000, 7);
        let a = run_scale(&cfg);
        let b = run_scale(&cfg);
        assert_eq!(a, b, "same seed ⇒ identical report incl. trace hash");
        assert_eq!(a.completed, 2_000);
        assert!(a.peak_resident <= a.dt_buffer_bytes);
        assert!(a.cache_peak <= a.cache_bytes);
        assert_eq!(a.overruns, 0, "backpressure defers before patience expires");
        let c = run_scale(&ScaleConfig::storm(2_000, 8));
        assert_ne!(a.trace_hash, c.trace_hash, "different seed ⇒ different trace");
    }

    #[test]
    fn zipf_mix_concentrates_cache_hits() {
        let r = run_scale(&ScaleConfig::zipf(2_000, 11));
        assert_eq!(r.completed, 2_000);
        assert!(r.cache_hits > r.cache_misses, "hot head must dominate: {r:?}");
        assert!(r.cache_peak <= r.cache_bytes);
    }

    #[test]
    fn epoch_replay_reads_exactly_the_plan_batches() {
        let cfg = ScaleConfig::epoch_replay(500, 3);
        let w = Workload::new(
            &cfg,
            MemBackend::new(4096, cfg.min_obj_bytes, cfg.max_obj_bytes, cfg.seed).sizes,
        );
        // Client 0 replays batch 0 of epoch 0's plan, verbatim and in order.
        let plan = EpochPlan::new(4096, 8, cfg.seed, 0);
        let want: Vec<u32> = plan.batch(0).unwrap().iter().map(|&i| i as u32).collect();
        let got: Vec<u32> = w.entries(0).iter().map(|&(o, _)| o).collect();
        assert_eq!(got, want);
        let r = run_scale(&cfg);
        assert_eq!(r.completed, 500);
    }

    #[test]
    fn zipf_sampler_prefers_the_head() {
        let cfg = ScaleConfig::zipf(0, 5);
        let w = Workload::new(
            &cfg,
            MemBackend::new(cfg.n_objects, cfg.min_obj_bytes, cfg.max_obj_bytes, cfg.seed).sizes,
        );
        let mut head = 0usize;
        let mut total = 0usize;
        for c in 0..2_000u64 {
            for (obj, _) in w.entries(c) {
                total += 1;
                if (obj as usize) < cfg.n_objects / 100 {
                    head += 1;
                }
            }
        }
        assert!(
            head * 2 > total,
            "top 1% of objects should absorb most draws ({head}/{total})"
        );
    }

    #[test]
    fn multi_tenant_hog_cannot_starve_steady_clients() {
        let cfg = MultiTenantConfig::hog_vs_steady(2_000, 17);
        let a = run_multi_tenant(&cfg);
        let b = run_multi_tenant(&cfg);
        assert_eq!(a, b, "same seed ⇒ identical multi-tenant report incl. trace hash");
        assert_eq!(a.steady_completed, 2_000, "every steady client finishes: {a:?}");
        assert_eq!(a.steady_rejected, 0, "interactive traffic is never shed by the hog: {a:?}");
        assert_eq!(a.overruns, 0, "fair-share backpressure defers before patience: {a:?}");
        assert!(a.peak_resident <= a.dt_buffer_bytes);
        assert!(a.hog_rejected > 0, "bulk hog re-registrations are shed first: {a:?}");
        assert_eq!(a.hog_aborted, a.hog_admitted, "hog batches never drain; all reaped: {a:?}");
        assert!(a.hog_backpressured > 0, "hog over-share deliveries defer: {a:?}");
        let fair_share = (cfg.dt_buffer_bytes - cfg.chunk_bytes) / 2;
        assert!(
            a.hog_peak_with_steady_bytes <= fair_share,
            "hog capped at its share while steady tenants are active: {a:?}"
        );
        assert!(
            a.hog_peak_ledger_bytes > fair_share,
            "idle shares are borrowable once the steady population drains: {a:?}"
        );
        assert!(
            a.max_steady_admission_wait_ns < 10_000_000,
            "steady admission waits stay bounded: {a:?}"
        );
        let c = run_multi_tenant(&MultiTenantConfig::hog_vs_steady(2_000, 18));
        assert_ne!(a.trace_hash, c.trace_hash, "different seed ⇒ different trace");
    }

    #[test]
    fn patience_valve_fires_deterministically_on_a_stuck_consumer() {
        // Direct valve exercise (the scale runs keep overruns at 0 by
        // design): a non-head producer on a saturated virtual budget waits
        // out patience in virtual time, then force-admits as an overrun.
        let clock = VirtualClock::new();
        let budget = MemoryBudget::with_clock(
            8 << 10,
            1 << 10,
            Duration::from_millis(50),
            None,
            clock.clone(),
        );
        assert!(budget.try_reserve(7 << 10)); // cap (8K - 1K) reached
        let buf = OrderBuffer::with_budget(4, Arc::clone(&budget));
        let t0 = std::time::Instant::now();
        buf.fill(2, vec![0u8; 512]); // not head-of-line: no exemption
        assert_eq!(budget.overruns(), 1, "patience expiry force-admits");
        assert!(clock.now_ns() >= 50_000_000, "patience elapsed virtually");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "virtual patience must not burn real time"
        );
        assert_eq!(buf.buffered_bytes(), 512);
    }
}
