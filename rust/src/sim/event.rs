//! Discrete-event primitives for the cluster-scale simulator: c-slot FIFO
//! resources (disks, CPUs) and serial pipes (NIC links, TCP streams). All
//! times are virtual nanoseconds.
//!
//! The simulator composes request paths as chains of `acquire`/`transfer`
//! calls; contention emerges from the shared next-free state, which is what
//! produces the paper's saturation and tail effects at cluster scale.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A resource with `c` parallel slots and FIFO discipline (e.g. 12 NVMe
/// drives, N CPU workers). `acquire(arrival, service)` returns the
/// completion time of a job arriving at `arrival` needing `service` ns.
#[derive(Debug, Clone)]
pub struct Resource {
    free_at: BinaryHeap<Reverse<u64>>,
    /// Cumulative busy time (utilization accounting).
    pub busy_ns: u64,
}

impl Resource {
    pub fn new(slots: usize) -> Resource {
        assert!(slots > 0);
        Resource { free_at: (0..slots).map(|_| Reverse(0)).collect(), busy_ns: 0 }
    }

    pub fn acquire(&mut self, arrival_ns: u64, service_ns: u64) -> u64 {
        let Reverse(earliest) = self.free_at.pop().expect("slots > 0");
        let start = arrival_ns.max(earliest);
        let done = start + service_ns;
        self.free_at.push(Reverse(done));
        self.busy_ns += service_ns;
        done
    }

    /// Earliest time any slot is free (diagnostics).
    pub fn earliest_free(&self) -> u64 {
        self.free_at.peek().map(|Reverse(t)| *t).unwrap_or(0)
    }
}

/// A serial pipe with fixed bandwidth — a NIC port or a single TCP stream.
/// Bytes are transmitted strictly in order; a transfer arriving while the
/// pipe is busy queues behind the earlier ones.
#[derive(Debug, Clone)]
pub struct Pipe {
    next_free: u64,
    pub bytes_per_sec: f64,
    pub bytes_moved: u64,
}

impl Pipe {
    pub fn new(bytes_per_sec: f64) -> Pipe {
        assert!(bytes_per_sec > 0.0);
        Pipe { next_free: 0, bytes_per_sec, bytes_moved: 0 }
    }

    pub fn transfer(&mut self, arrival_ns: u64, bytes: u64) -> u64 {
        let start = arrival_ns.max(self.next_free);
        let dur = (bytes as f64 / self.bytes_per_sec * 1e9) as u64;
        self.next_free = start + dur;
        self.bytes_moved += bytes;
        self.next_free
    }

    /// Duration a transfer of `bytes` would take unloaded.
    pub fn unloaded_ns(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.bytes_per_sec * 1e9) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_slot_serializes() {
        let mut r = Resource::new(1);
        assert_eq!(r.acquire(0, 100), 100);
        assert_eq!(r.acquire(0, 100), 200); // queued behind first
        assert_eq!(r.acquire(500, 100), 600); // idle gap respected
        assert_eq!(r.busy_ns, 300);
    }

    #[test]
    fn multi_slot_parallelism() {
        let mut r = Resource::new(3);
        assert_eq!(r.acquire(0, 100), 100);
        assert_eq!(r.acquire(0, 100), 100);
        assert_eq!(r.acquire(0, 100), 100);
        assert_eq!(r.acquire(0, 100), 200); // 4th job waits
    }

    #[test]
    fn pipe_bandwidth_and_queueing() {
        let mut p = Pipe::new(1e9); // 1 GB/s
        let t1 = p.transfer(0, 1_000_000); // 1 MB -> 1 ms
        assert_eq!(t1, 1_000_000);
        let t2 = p.transfer(0, 1_000_000); // queued
        assert_eq!(t2, 2_000_000);
        let t3 = p.transfer(5_000_000, 500_000);
        assert_eq!(t3, 5_500_000);
        assert_eq!(p.bytes_moved, 2_500_000);
    }

    #[test]
    fn pipe_unloaded_estimate() {
        let p = Pipe::new(2e9);
        assert_eq!(p.unloaded_ns(2_000_000_000), 1_000_000_000);
    }
}
