//! The remote tier: a [`Backend`] that fronts buckets whose objects live
//! on another node (or an S3-like endpoint speaking the same contract)
//! over the crate's internal HTTP object API. Reads ride the existing
//! Range support (`proto::http` 206 + `content-range`): a reader holds one
//! streaming ranged GET open and pulls `chunk_bytes` pieces off it, so
//! remote reads have the same O(chunk) residency as local ones; a seek
//! drops the stream and re-issues the range at the new offset. Metadata
//! (size, stored CRC-32 sidecar) comes from a 1-byte ranged probe — the
//! `content-range` total plus the `x-getbatch-crc32` response header.
//!
//! Point `addr` at a target for single-node buckets, or at a proxy to
//! front a whole remote cluster (object requests follow the proxy's 307
//! redirect to the HRW owner; `list` fans out proxy-side).

use std::io::{self, Read};
use std::sync::Arc;

use crate::metrics::GetBatchMetrics;
use crate::proto::http::{content_range_total, HttpClient};
use crate::proto::wire;

use super::engine::{Backend, ChunkSource, EntryReader, StoreError};

pub struct RemoteBackend {
    client: HttpClient,
    addr: String,
    metrics: Option<Arc<GetBatchMetrics>>,
}

impl RemoteBackend {
    pub fn new(addr: &str, metrics: Option<Arc<GetBatchMetrics>>) -> RemoteBackend {
        RemoteBackend { client: HttpClient::new(true), addr: addr.to_string(), metrics }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn pq(bucket: &str, obj: &str) -> String {
        format!("{}?local=true", wire::object_path(bucket, obj))
    }

    fn count_fetch(&self, bytes: u64) {
        if let Some(m) = &self.metrics {
            m.remote_fetches.inc();
            m.remote_fetch_bytes.add(bytes);
        }
    }

    /// 1-byte ranged probe: learns (total length, stored CRC-32 sidecar).
    fn probe(&self, bucket: &str, obj: &str) -> Result<(u64, Option<u32>), StoreError> {
        self.count_fetch(0);
        let pq = Self::pq(bucket, obj);
        let resp = self.client.get_range(&self.addr, &pq, 0, 1).map_err(StoreError::Io)?;
        match resp.status {
            206 => {
                let total = resp
                    .header("content-range")
                    .and_then(content_range_total)
                    .ok_or_else(|| {
                        StoreError::Io(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("remote {}: missing content-range", self.addr),
                        ))
                    })?;
                let crc = resp
                    .header(wire::HDR_OBJ_CRC)
                    .and_then(|h| u32::from_str_radix(h.trim(), 16).ok());
                let _ = resp.into_bytes(); // drain ≤ 1 byte; recycles the conn
                Ok((total, crc))
            }
            404 => Err(StoreError::NotFound(format!("{bucket}/{obj} @ {}", self.addr))),
            s => Err(StoreError::Io(io::Error::new(
                io::ErrorKind::Other,
                format!("remote {}: http {s}", self.addr),
            ))),
        }
    }

    fn open_span(
        &self,
        bucket: &str,
        obj: &str,
        base: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let src = RemoteSource {
            client: self.client.clone(),
            addr: self.addr.clone(),
            pq: Self::pq(bucket, obj),
            base,
            len,
            metrics: self.metrics.clone(),
            stream: None,
        };
        Ok(EntryReader::from_source(Box::new(src), len))
    }
}

impl Backend for RemoteBackend {
    fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let (total, _) = self.probe(bucket, obj)?;
        self.open_span(bucket, obj, 0, total)
    }

    fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let (total, _) = self.probe(bucket, obj)?;
        if offset.saturating_add(len) > total {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} past EOF ({total}) in {bucket}/{obj}"),
            )));
        }
        self.open_span(bucket, obj, offset, len)
    }

    fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        self.count_fetch(0);
        let resp = self.client.put(&self.addr, &Self::pq(bucket, obj), data).map_err(StoreError::Io)?;
        match resp.status {
            200 => Ok(()),
            s => Err(StoreError::Io(io::Error::new(
                io::ErrorKind::Other,
                format!("remote put {}: http {s}", self.addr),
            ))),
        }
    }

    fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.probe(bucket, obj).is_ok()
    }

    fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        Ok(self.probe(bucket, obj)?.0)
    }

    fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        self.count_fetch(0);
        let resp = self
            .client
            .request("DELETE", &self.addr, &Self::pq(bucket, obj), &[])
            .map_err(StoreError::Io)?;
        match resp.status {
            200 => Ok(()),
            404 => Err(StoreError::NotFound(format!("{bucket}/{obj} @ {}", self.addr))),
            s => Err(StoreError::Io(io::Error::new(
                io::ErrorKind::Other,
                format!("remote delete {}: http {s}", self.addr),
            ))),
        }
    }

    fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        self.count_fetch(0);
        let pq = format!("{}?bucket={bucket}", wire::paths::LIST);
        let resp = self.client.get(&self.addr, &pq).map_err(StoreError::Io)?;
        if resp.status != 200 {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::Other,
                format!("remote list {}: http {}", self.addr, resp.status),
            )));
        }
        let body = resp.into_bytes().map_err(StoreError::Io)?;
        Ok(String::from_utf8_lossy(&body)
            .lines()
            .filter(|l| !l.is_empty())
            .map(|l| l.to_string())
            .collect())
    }

    fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32> {
        self.probe(bucket, obj).ok().and_then(|(_, crc)| crc)
    }
}

/// Streaming source over one remote entry span: lazily opens a ranged GET
/// covering `[base+pos, base+len)` and reads sequentially off its chunked
/// body; a non-sequential `read_at` (seek) drops the stream and re-issues
/// the range at the new position.
struct RemoteSource {
    client: HttpClient,
    addr: String,
    pq: String,
    /// Entry span start within the remote object.
    base: u64,
    /// Entry span length.
    len: u64,
    metrics: Option<Arc<GetBatchMetrics>>,
    /// Open response body + the entry-relative position of its next byte.
    stream: Option<(crate::proto::http::BodyReader, u64)>,
}

impl ChunkSource for RemoteSource {
    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize> {
        if pos >= self.len || buf.is_empty() {
            return Ok(0);
        }
        if self.stream.as_ref().map(|(_, at)| *at) != Some(pos) {
            self.stream = None;
            if let Some(m) = &self.metrics {
                m.remote_fetches.inc();
            }
            let resp = self
                .client
                .get_range(&self.addr, &self.pq, self.base + pos, self.len - pos)?;
            if resp.status != 206 {
                return Err(io::Error::new(
                    io::ErrorKind::Other,
                    format!("remote read {}: http {}", self.addr, resp.status),
                ));
            }
            self.stream = Some((resp.body, pos));
        }
        let (body, at) = self.stream.as_mut().expect("stream just ensured");
        let n = body.read(buf)?;
        if n == 0 {
            // Server delivered fewer bytes than the advertised span (object
            // shrank / truncated response): drop the stream so a retry
            // re-issues the range; the reader surfaces UnexpectedEof.
            self.stream = None;
            return Ok(0);
        }
        *at += n as u64;
        if let Some(m) = &self.metrics {
            m.remote_fetch_bytes.add(n as u64);
        }
        Ok(n)
    }
}
