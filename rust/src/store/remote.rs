//! The remote tier: a [`Backend`] that fronts buckets whose objects live
//! on another node (or an S3-like endpoint speaking the same contract)
//! over the crate's internal HTTP object API. Reads ride the existing
//! Range support (`proto::http` 206 + `content-range`): a reader holds one
//! streaming ranged GET open and pulls `chunk_bytes` pieces off it, so
//! remote reads have the same O(chunk) residency as local ones; a seek
//! drops the stream and re-issues the range at the new offset. Metadata
//! (size, stored CRC-32 sidecar) comes from a 1-byte ranged probe — the
//! `content-range` total plus the `x-getbatch-crc32` response header.
//!
//! A bucket is served by an **endpoint set**, not a single trusted address:
//! every operation walks [`EndpointSet::plan`]'s candidates — ordered by
//! health, outstanding requests, and latency EWMA (see [`super::health`]) —
//! and fails over on endpoint faults (connect errors, 5xx), so one dead
//! host degrades to a retry instead of a hard `Io` error. Because a remote
//! read is a ranged stream, failover works **mid-stream** too: when the
//! endpoint serving an open stream dies, the source re-issues the range at
//! the current offset on the next healthy endpoint and keeps going — and a
//! whole-object stream that failed over is CRC-verified at EOF against the
//! object's `x-getbatch-crc32` sidecar (learned at open), failing closed if
//! the endpoints disagreed about the bytes. That check is defense in
//! depth, not a substitute for the contract: all endpoints must front the
//! **same underlying store** — a *ranged* span (cache fill, shard member,
//! GFN) has no per-range hash to verify against, so divergent replicas in
//! one endpoint set are unsupported on every path. `StoreError::Io`
//! surfaces only once *all* endpoints are down.
//!
//! **Hedged reads** (the tail-latency engine): a ranged read whose
//! response headers don't arrive within the serving endpoint's tracked
//! latency quantile ([`TailConfig::hedge_quantile`], floored by
//! `hedge_min_ms`) is raced against the second-best healthy endpoint — the
//! first usable response wins, the loser's connection is dropped (never
//! recycled into the pool), and concurrent hedges are capped by
//! `hedge_max_inflight` so hedging cannot amplify load during a brown-out.
//! A hedge can change which endpoint serves a stream mid-object, so every
//! (re-)opened stream is **version-gated**: once a source has delivered
//! bytes, a re-open whose `x-getbatch-version` stamp differs from the
//! pinned one fails closed instead of stitching bytes from two object
//! versions (the failover CRC check remains as the unversioned backstop).
//!
//! Point an endpoint at a target for single-node buckets, or at a proxy to
//! front a whole remote cluster (object requests follow the proxy's 307
//! redirect to the HRW owner; `list` fans out proxy-side). List several
//! endpoints (replicated fronts, multi-host gateways) to enable failover
//! and hedging.

use std::io::{self, Read};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::metrics::GetBatchMetrics;
use crate::proto::http::{content_range_total, BodyReader, HttpClient};
use crate::proto::wire;
use crate::util::crc32;

use super::engine::{Backend, ChunkSource, EntryReader, StoreError};
use super::health::{EndpointSet, Inflight, TailConfig};

/// How one endpoint's attempt at an operation failed.
enum Attempt {
    /// A definitive answer from a live endpoint (404, malformed request):
    /// returned as-is, no failover — retrying elsewhere cannot change it.
    Fatal(StoreError),
    /// The endpoint itself failed (connect error, 5xx): counts against its
    /// circuit breaker and the operation moves to the next candidate.
    Endpoint(io::Error),
}

/// One endpoint attempt, shareable across the hedge race threads.
type Op<T> = Arc<dyn Fn(&str) -> Result<T, Attempt> + Send + Sync>;

/// Shared hedging state of one backend: the policy plus the live count of
/// hedge attempts in flight (the `hedge_max_inflight` cap).
struct TailState {
    cfg: TailConfig,
    hedges_inflight: AtomicUsize,
}

impl TailState {
    /// Reserve one hedge slot, or `None` at the cap. The returned guard
    /// releases the slot on drop (it travels into the hedge thread, so the
    /// slot is held for the hedge attempt's full lifetime — including a
    /// canceled loser still waiting on its response).
    fn acquire(self: &Arc<TailState>) -> Option<HedgeSlot> {
        let mut cur = self.hedges_inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.cfg.hedge_max_inflight {
                return None;
            }
            match self.hedges_inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(HedgeSlot(Arc::clone(self))),
                Err(now) => cur = now,
            }
        }
    }
}

struct HedgeSlot(Arc<TailState>);

impl Drop for HedgeSlot {
    fn drop(&mut self) {
        self.0.hedges_inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Spawn one raced attempt against `addr`. The result goes back over `tx`
/// tagged with `hedge`; when the send fails the race is already decided —
/// a loser that produced a usable response counts `hedges_canceled` and
/// drops it (dropping an unconsumed response drops its connection instead
/// of recycling it, which is exactly the cancellation we want). Returns
/// false if the thread could not be spawned.
fn spawn_attempt<T: Send + 'static>(
    addr: &str,
    book: Op<T>,
    tx: mpsc::Sender<(bool, Result<T, Attempt>)>,
    metrics: Option<Arc<GetBatchMetrics>>,
    hedge: bool,
    slot: Option<HedgeSlot>,
) -> bool {
    let addr = addr.to_string();
    std::thread::Builder::new()
        .name("hedge-read".to_string())
        .stack_size(256 * 1024)
        .spawn(move || {
            let _slot = slot;
            let res = book(&addr);
            let usable = res.is_ok();
            if tx.send((hedge, res)).is_err() && usable {
                if let Some(m) = &metrics {
                    m.hedges_canceled.inc();
                }
            }
        })
        .is_ok()
}

/// Run the plan's *first* candidate with hedging: if its response headers
/// don't arrive within the endpoint's hedge deadline, race the same
/// attempt on the best other healthy endpoint and take whichever answers
/// first. Failover candidates after the first are not hedged — they are
/// already the fallback path.
fn race_first<T: Send + 'static>(
    endpoints: &Arc<EndpointSet>,
    tail: &Arc<TailState>,
    metrics: &Option<Arc<GetBatchMetrics>>,
    addr: &str,
    book: &Op<T>,
) -> Result<T, Attempt> {
    if !tail.cfg.hedging_enabled() || endpoints.len() < 2 {
        return book(addr);
    }
    let deadline = endpoints.hedge_deadline(addr, tail.cfg.hedge_quantile, tail.cfg.hedge_min);
    let (tx, rx) = mpsc::channel::<(bool, Result<T, Attempt>)>();
    if !spawn_attempt(addr, Arc::clone(book), tx.clone(), metrics.clone(), false, None) {
        // Thread exhaustion: degrade to the plain synchronous attempt.
        return book(addr);
    }
    let mut racing = 1usize;
    match rx.recv_timeout(deadline) {
        Ok((_, res)) => return res,
        Err(mpsc::RecvTimeoutError::Timeout) => {}
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            // Unreachable (the attempt thread sends exactly once), but
            // never hang an I/O path on a race invariant.
            return Err(Attempt::Endpoint(io::Error::new(
                io::ErrorKind::Other,
                "hedge race lost its attempt thread",
            )));
        }
    }
    // The primary outlived its deadline: launch the hedge if a peer and a
    // slot are available (at the cap, or alone, we just keep waiting).
    if let Some(peer) = endpoints.hedge_peer(addr) {
        if let Some(slot) = tail.acquire() {
            if spawn_attempt(&peer, Arc::clone(book), tx.clone(), metrics.clone(), true, Some(slot))
            {
                racing += 1;
                if let Some(m) = metrics {
                    m.hedges.inc();
                    m.remote_fetches.inc();
                }
            }
        }
    }
    drop(tx);
    // First usable response wins; a definitive Fatal outranks endpoint
    // faults once everyone has reported.
    let mut fatal: Option<StoreError> = None;
    let mut last_ep: Option<io::Error> = None;
    while racing > 0 {
        let (was_hedge, res) = rx.recv().expect("every racing attempt sends once");
        racing -= 1;
        match res {
            Ok(v) => {
                if was_hedge {
                    if let Some(m) = metrics {
                        m.hedge_wins.inc();
                    }
                }
                return Ok(v);
            }
            Err(Attempt::Fatal(e)) => fatal = Some(e),
            Err(Attempt::Endpoint(e)) => last_ep = Some(e),
        }
    }
    match fatal {
        Some(e) => Err(Attempt::Fatal(e)),
        None => Err(Attempt::Endpoint(last_ep.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::Other, "hedge race ended without a result")
        }))),
    }
}

/// Walk the endpoint set's candidates for one ranged operation: the first
/// (best) candidate runs under [`race_first`]'s hedging, later candidates
/// are the ordinary failover path. Every attempt is bracketed with the
/// per-endpoint bookkeeping — outstanding-count guard, circuit notes, and
/// a latency observation on success (response-header time, the
/// time-to-first-byte proxy the EWMA/quantile machinery tracks).
fn hedged_walk<T: Send + 'static>(
    client: &HttpClient,
    endpoints: &Arc<EndpointSet>,
    tail: &Arc<TailState>,
    metrics: &Option<Arc<GetBatchMetrics>>,
    exclude: Option<&str>,
    op: Op<T>,
) -> Result<T, StoreError> {
    EndpointSet::maybe_probe(endpoints, client);
    let book: Op<T> = {
        let endpoints = Arc::clone(endpoints);
        Arc::new(move |addr: &str| {
            let _inflight = endpoints.track(addr);
            let t0 = Instant::now();
            let res = op(addr);
            match &res {
                Ok(_) => {
                    endpoints.note_ok(addr);
                    endpoints.note_latency(addr, t0.elapsed());
                }
                // A definitive answer came from a live endpoint; its
                // latency is not a ranged-read sample, so only the
                // circuit learns from it.
                Err(Attempt::Fatal(_)) => endpoints.note_ok(addr),
                Err(Attempt::Endpoint(_)) => endpoints.note_err(addr),
            }
            res
        })
    };
    let mut last_io: Option<io::Error> = None;
    for (i, addr) in endpoints.plan(exclude).iter().enumerate() {
        if last_io.is_some() || exclude.is_some() {
            if let Some(m) = metrics {
                m.remote_failovers.inc();
            }
        }
        if let Some(m) = metrics {
            m.remote_fetches.inc();
        }
        let res = if i == 0 {
            race_first(endpoints, tail, metrics, addr, &book)
        } else {
            book(addr)
        };
        match res {
            Ok(v) => return Ok(v),
            Err(Attempt::Fatal(e)) => return Err(e),
            Err(Attempt::Endpoint(e)) => last_io = Some(e),
        }
    }
    Err(StoreError::Io(all_down(endpoints.len(), last_io)))
}

pub struct RemoteBackend {
    client: HttpClient,
    endpoints: Arc<EndpointSet>,
    tail: Arc<TailState>,
    metrics: Option<Arc<GetBatchMetrics>>,
}

impl RemoteBackend {
    /// Single-endpoint backend with default health and tail parameters
    /// (3-error circuit breaker, 1 s probe interval, default
    /// [`TailConfig`] — hedging is moot with one endpoint).
    pub fn new(addr: &str, metrics: Option<Arc<GetBatchMetrics>>) -> RemoteBackend {
        RemoteBackend::multi(&[addr], 3, Duration::from_millis(1000), metrics)
    }

    /// Backend over a health-tracked endpoint set with the default
    /// [`TailConfig`] — see `GetBatchConfig::endpoint_failure_limit` /
    /// `endpoint_probe_ms` for the knobs the cluster feeds in.
    pub fn multi(
        addrs: &[&str],
        failure_limit: u32,
        probe_interval: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> RemoteBackend {
        let tail = TailConfig::default();
        RemoteBackend::with_tail(addrs, failure_limit, probe_interval, tail, metrics)
    }

    /// Backend with an explicit tail-latency policy (`endpoint_slow_ms`,
    /// `hedge_quantile`, `hedge_min_ms`, `hedge_max_inflight`).
    pub fn with_tail(
        addrs: &[&str],
        failure_limit: u32,
        probe_interval: Duration,
        tail: TailConfig,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> RemoteBackend {
        RemoteBackend {
            client: HttpClient::new(true),
            endpoints: EndpointSet::new(
                addrs,
                failure_limit,
                probe_interval,
                tail.slow,
                metrics.clone(),
            ),
            tail: Arc::new(TailState { cfg: tail, hedges_inflight: AtomicUsize::new(0) }),
            metrics,
        }
    }

    /// The primary (first-configured) endpoint.
    pub fn addr(&self) -> &str {
        self.endpoints.primary()
    }

    /// The health-tracked endpoint set (tests and diagnostics).
    pub fn endpoints(&self) -> &Arc<EndpointSet> {
        &self.endpoints
    }

    fn pq(bucket: &str, obj: &str) -> String {
        format!("{}?local=true", wire::object_path(bucket, obj))
    }

    /// Run `f` against the endpoint set's candidates in health order,
    /// failing over past endpoint faults; `Io` only when every candidate
    /// failed. The non-hedged walk — control-plane operations (put,
    /// delete, list) where racing duplicates would be unsafe or useless.
    fn with_endpoints<T>(
        &self,
        mut f: impl FnMut(&str) -> Result<T, Attempt>,
    ) -> Result<T, StoreError> {
        EndpointSet::maybe_probe(&self.endpoints, &self.client);
        let mut last_io: Option<io::Error> = None;
        for addr in self.endpoints.plan(None) {
            if last_io.is_some() {
                if let Some(m) = &self.metrics {
                    m.remote_failovers.inc();
                }
            }
            if let Some(m) = &self.metrics {
                m.remote_fetches.inc();
            }
            match f(&addr) {
                Ok(v) => {
                    self.endpoints.note_ok(&addr);
                    return Ok(v);
                }
                Err(Attempt::Fatal(e)) => {
                    self.endpoints.note_ok(&addr);
                    return Err(e);
                }
                Err(Attempt::Endpoint(e)) => {
                    self.endpoints.note_err(&addr);
                    last_io = Some(e);
                }
            }
        }
        Err(StoreError::Io(all_down(self.endpoints.len(), last_io)))
    }

    /// 1-byte ranged probe: learns (total length, stored CRC-32 sidecar,
    /// write generation) — the CRC rides `x-getbatch-crc32`, the version
    /// `x-getbatch-version`; either may be absent (version-less server).
    /// Probes ride the hedged walk like byte reads do: they are on the
    /// per-entry hot path (every open probes first), and a straggling
    /// probe delays a batch exactly like a straggling read.
    ///
    /// Zero-length objects: a 0-byte object cannot satisfy `bytes=0-0`, so
    /// a strict server answers **416** with `content-range: bytes */0` (the
    /// crate's internal servers answer an empty 206 instead — both carry
    /// the total). Either shape resolves to `size == 0`, not an error.
    fn probe(&self, bucket: &str, obj: &str) -> Result<(u64, Option<u32>, Option<u64>), StoreError> {
        let pq = Self::pq(bucket, obj);
        let client = self.client.clone();
        let bucket = bucket.to_string();
        let obj = obj.to_string();
        let op: Op<(u64, Option<u32>, Option<u64>)> = Arc::new(move |addr: &str| {
            let resp = client.get_range(addr, &pq, 0, 1).map_err(Attempt::Endpoint)?;
            let crc = resp
                .header(wire::HDR_OBJ_CRC)
                .and_then(|h| u32::from_str_radix(h.trim(), 16).ok());
            let version = resp
                .header(wire::HDR_OBJ_VERSION)
                .and_then(|h| h.trim().parse::<u64>().ok());
            match resp.status {
                206 => {
                    let total = resp
                        .header("content-range")
                        .and_then(content_range_total)
                        .ok_or_else(|| {
                            Attempt::Endpoint(io::Error::new(
                                io::ErrorKind::InvalidData,
                                format!("remote {addr}: missing content-range"),
                            ))
                        })?;
                    let _ = resp.into_bytes(); // drain ≤ 1 byte; recycles the conn
                    Ok((total, crc, version))
                }
                // Empty object behind a strict-RFC server: the range is
                // unsatisfiable but the total (0) rides `content-range:
                // bytes */0` (RFC 9110 requires it on 416). No parseable
                // total means this is NOT that case — treat it as an
                // endpoint fault like the 206 branch does, never as a
                // 0-byte object (that would turn an unreadable object into
                // silent empty-entry "success").
                416 => {
                    let total = resp
                        .header("content-range")
                        .and_then(content_range_total)
                        .ok_or_else(|| {
                            Attempt::Endpoint(io::Error::new(
                                io::ErrorKind::InvalidData,
                                format!("remote {addr}: 416 without content-range total"),
                            ))
                        })?;
                    let _ = resp.into_bytes();
                    Ok((total, crc, version))
                }
                404 => Err(Attempt::Fatal(StoreError::NotFound(format!(
                    "{bucket}/{obj} @ {addr}"
                )))),
                s => Err(status_attempt(addr, "probe", s)),
            }
        });
        hedged_walk(&self.client, &self.endpoints, &self.tail, &self.metrics, None, op)
    }

    fn open_span(
        &self,
        bucket: &str,
        obj: &str,
        base: u64,
        len: u64,
        whole_object_crc: Option<u32>,
        probed_version: Option<u64>,
    ) -> Result<EntryReader, StoreError> {
        let src = RemoteSource {
            client: self.client.clone(),
            endpoints: Arc::clone(&self.endpoints),
            tail: Arc::clone(&self.tail),
            pq: Self::pq(bucket, obj),
            base,
            len,
            metrics: self.metrics.clone(),
            stream: None,
            expected_crc: whole_object_crc,
            hasher: if whole_object_crc.is_some() { Some(crc32::Hasher::new()) } else { None },
            hashed_to: 0,
            mixed: false,
            seen_version: probed_version,
            unstamped: false,
            delivered: false,
        };
        Ok(EntryReader::from_source(Box::new(src), len))
    }
}

/// The "every candidate failed" terminal error.
fn all_down(n: usize, last: Option<io::Error>) -> io::Error {
    match last {
        Some(e) => io::Error::new(e.kind(), format!("all {n} remote endpoints down: {e}")),
        None => io::Error::new(
            io::ErrorKind::Other,
            format!("all {n} remote endpoints down (circuits open)"),
        ),
    }
}

/// Classify an unexpected HTTP status: 5xx / 429 are endpoint faults
/// (fail over), other 4xx are definitive answers (don't).
fn status_attempt(addr: &str, op: &str, status: u16) -> Attempt {
    let e = io::Error::new(io::ErrorKind::Other, format!("remote {op} {addr}: http {status}"));
    if status >= 500 || status == 429 {
        Attempt::Endpoint(e)
    } else {
        Attempt::Fatal(StoreError::Io(e))
    }
}

impl Backend for RemoteBackend {
    fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let (total, crc, version) = self.probe(bucket, obj)?;
        self.open_span(bucket, obj, 0, total, crc, version)
    }

    fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let (total, _, version) = self.probe(bucket, obj)?;
        if offset.saturating_add(len) > total {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} past EOF ({total}) in {bucket}/{obj}"),
            )));
        }
        self.open_span(bucket, obj, offset, len, None, version)
    }

    /// Write-through PUT. Contract: every endpoint in the set fronts the
    /// **same underlying store** (multiple gateways/proxies of one
    /// cluster), so writing through any one endpoint is equivalent — the
    /// write is issued once, to the first healthy candidate. Endpoint
    /// lists over *independent* replicas are read-only territory: writes
    /// would land on one replica and diverge the others (which the read
    /// path's failover CRC check would then reject). Writes are never
    /// hedged — a raced duplicate PUT is a correctness hazard, not a
    /// latency fix.
    fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        let pq = Self::pq(bucket, obj);
        self.with_endpoints(|addr| {
            let resp = self.client.put(addr, &pq, data).map_err(Attempt::Endpoint)?;
            match resp.status {
                200 => Ok(()),
                s => Err(status_attempt(addr, "put", s)),
            }
        })
    }

    fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.probe(bucket, obj).is_ok()
    }

    fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        Ok(self.probe(bucket, obj)?.0)
    }

    /// Write-through DELETE — same single-store contract as [`Backend::put`]
    /// on this type, with at-least-once retry semantics: a failed attempt
    /// that *reached* the store may have been applied before the response
    /// was lost, so after such a failure a 404 from a later endpoint of
    /// the same store means "already deleted" and reports success. A
    /// refused connection never carried the request, so it keeps the
    /// definitive-`NotFound` semantics intact.
    fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        let pq = Self::pq(bucket, obj);
        let mut maybe_applied = false;
        self.with_endpoints(|addr| {
            let resp = match self.client.request("DELETE", addr, &pq, &[]) {
                Ok(r) => r,
                Err(e) => {
                    if e.kind() != io::ErrorKind::ConnectionRefused {
                        maybe_applied = true;
                    }
                    return Err(Attempt::Endpoint(e));
                }
            };
            match resp.status {
                200 => Ok(()),
                404 if maybe_applied => Ok(()),
                404 => Err(Attempt::Fatal(StoreError::NotFound(format!(
                    "{bucket}/{obj} @ {addr}"
                )))),
                s => {
                    let a = status_attempt(addr, "delete", s);
                    if matches!(a, Attempt::Endpoint(_)) {
                        maybe_applied = true;
                    }
                    Err(a)
                }
            }
        })
    }

    fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        let pq = format!("{}?bucket={bucket}", wire::paths::LIST);
        self.with_endpoints(|addr| {
            let resp = self.client.get(addr, &pq).map_err(Attempt::Endpoint)?;
            if resp.status != 200 {
                return Err(status_attempt(addr, "list", resp.status));
            }
            let body = resp.into_bytes().map_err(Attempt::Endpoint)?;
            Ok(String::from_utf8_lossy(&body)
                .lines()
                .filter(|l| !l.is_empty())
                .map(|l| l.to_string())
                .collect())
        })
    }

    fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32> {
        self.probe(bucket, obj).ok().and_then(|(_, crc, _)| crc)
    }

    fn content_version(&self, bucket: &str, obj: &str) -> Option<u64> {
        self.probe(bucket, obj).ok().and_then(|(_, _, version)| version)
    }

    /// One probe answers everything — overriding the default (which would
    /// issue three separate probes over the wire).
    fn stat(&self, bucket: &str, obj: &str) -> Result<super::engine::ObjectStat, StoreError> {
        let (len, crc, version) = self.probe(bucket, obj)?;
        Ok(super::engine::ObjectStat { len, version, crc })
    }
}

/// A successfully opened ranged stream, as produced by one (possibly
/// hedged) attempt: the body plus the version stamp the 206 carried.
struct Opened {
    body: BodyReader,
    version: Option<u64>,
    addr: String,
}

/// The open stream of a [`RemoteSource`].
struct Stream {
    body: BodyReader,
    /// Entry-relative position of the stream's next byte.
    at: u64,
    /// Endpoint serving the stream.
    addr: String,
    /// Holds the endpoint's outstanding count for the stream's lifetime —
    /// what makes least-outstanding selection see long-lived reads.
    _inflight: Option<Inflight>,
}

/// Streaming source over one remote entry span: lazily opens a ranged GET
/// covering `[base+pos, base+len)` and reads sequentially off its chunked
/// body; a non-sequential `read_at` (seek) drops the stream and re-issues
/// the range at the new position. Opens go through [`hedged_walk`], so a
/// straggling open is raced against the second-best endpoint.
///
/// Failover: when the endpoint serving the open stream dies mid-body, the
/// source marks it, drops the stream and **resumes the ranged fetch at the
/// current offset** on the next candidate from the endpoint set — invisible
/// to the reader above. Two guards keep stitched streams honest:
///
/// - **Version pin**: once any byte has been delivered, a re-opened stream
///   whose `x-getbatch-version` differs from the pinned version fails
///   closed (`InvalidData`) instead of mixing bytes of two object
///   versions — this is what makes hedged/failover re-opens safe against
///   concurrent overwrites.
/// - **CRC backstop**: a whole-object stream (base 0, full length) read
///   strictly sequentially keeps a running CRC-32; if a mid-stream
///   failover mixed bytes from more than one endpoint, the final CRC is
///   checked against the PUT-time sidecar learned at open (catches
///   divergent-replica misconfiguration even on version-less servers).
struct RemoteSource {
    client: HttpClient,
    endpoints: Arc<EndpointSet>,
    tail: Arc<TailState>,
    pq: String,
    /// Entry span start within the remote object.
    base: u64,
    /// Entry span length.
    len: u64,
    metrics: Option<Arc<GetBatchMetrics>>,
    stream: Option<Stream>,
    /// Whole-object sidecar CRC learned by the open-time probe.
    expected_crc: Option<u32>,
    /// Running CRC while reads stay strictly sequential from byte 0;
    /// dropped on the first seek (a partial hash proves nothing).
    hasher: Option<crc32::Hasher>,
    /// Bytes hashed so far (== pos while the hasher lives).
    hashed_to: u64,
    /// A mid-stream failover delivered bytes from more than one endpoint.
    mixed: bool,
    /// Latest `x-getbatch-version` observed — seeded by the open-time probe,
    /// updated by every 206 that opens a byte stream. Versions are
    /// monotonic per object, so "latest stamp == pin" implies every stream
    /// this source consumed was stamped with the pin, and (server-side
    /// open-then-stamp ordering over a stable file handle) every byte it
    /// delivered belongs to the pinned version.
    seen_version: Option<u64>,
    /// A byte-delivering 206 arrived without a version stamp (pre-coherence
    /// server, unversioned object): the observation is incomplete, so
    /// `observed_version` reports `None` and version-gated consumers fall
    /// back to their own probe.
    unstamped: bool,
    /// Any byte has been delivered to the reader: from here on the version
    /// pin is enforced on every re-open (before first delivery a version
    /// change is harmless — no bytes to stitch against).
    delivered: bool,
}

impl RemoteSource {
    /// (Re-)issue the ranged GET at entry-relative `pos` through the
    /// hedged walk; `exclude` is the endpoint that just failed mid-stream
    /// (tried again only as a last resort).
    fn open_at(&mut self, pos: u64, exclude: Option<&str>) -> io::Result<()> {
        self.stream = None;
        let client = self.client.clone();
        let pq = self.pq.clone();
        let start = self.base + pos;
        let want = self.len - pos;
        let op: Op<Opened> = Arc::new(move |addr: &str| {
            let resp = client.get_range(addr, &pq, start, want).map_err(Attempt::Endpoint)?;
            match resp.status {
                206 => {
                    let version = resp
                        .header(wire::HDR_OBJ_VERSION)
                        .and_then(|h| h.trim().parse::<u64>().ok());
                    Ok(Opened { body: resp.body, version, addr: addr.to_string() })
                }
                // A live endpoint says the object is gone: definitive.
                404 => Err(Attempt::Fatal(StoreError::Io(io::Error::new(
                    io::ErrorKind::NotFound,
                    format!("remote {addr}: object vanished mid-read"),
                )))),
                // Same classification as the non-stream paths: only
                // endpoint faults (5xx/429) burn the circuit and fail
                // over; a definitive per-object answer (e.g. 416 after
                // the object shrank under a resumed range) must not
                // poison every endpoint in the set.
                s => Err(status_attempt(addr, "read", s)),
            }
        });
        let opened =
            hedged_walk(&self.client, &self.endpoints, &self.tail, &self.metrics, exclude, op)
                .map_err(io::Error::from)?;
        self.admit(opened, pos)
    }

    /// Gate a freshly opened stream behind the version pin, then install
    /// it. Fail-closed rule: once bytes have been delivered, a stream
    /// stamped with a *different* version must not contribute — a
    /// concurrent overwrite raced the re-open (hedge or failover), and
    /// stitching the two versions would fabricate an object that never
    /// existed.
    fn admit(&mut self, opened: Opened, pos: u64) -> io::Result<()> {
        if self.delivered {
            if let (Some(pin), Some(v)) = (self.seen_version, opened.version) {
                if v != pin {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "object version changed mid-read (v{pin} -> v{v}) for {}: \
                             refusing to stitch bytes across versions",
                            self.pq
                        ),
                    ));
                }
            }
        }
        match opened.version {
            Some(v) => self.seen_version = Some(v),
            None => self.unstamped = true,
        }
        let inflight = self.endpoints.track(&opened.addr);
        self.stream =
            Some(Stream { body: opened.body, at: pos, addr: opened.addr, _inflight: inflight });
        Ok(())
    }

    /// Fold successfully delivered bytes into the sequential-stream CRC and
    /// verify against the sidecar once the whole object has streamed.
    fn digest(&mut self, pos: u64, bytes: &[u8]) -> io::Result<()> {
        if self.hasher.is_none() {
            return Ok(());
        }
        if pos != self.hashed_to {
            self.hasher = None; // seek: partial hash proves nothing
            return Ok(());
        }
        self.hasher.as_mut().expect("checked above").update(bytes);
        self.hashed_to += bytes.len() as u64;
        if self.hashed_to == self.len && self.mixed {
            let got = self.hasher.take().expect("checked above").finalize();
            if let Some(want) = self.expected_crc {
                if got != want {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "failover CRC mismatch: stream {got:08x} != sidecar {want:08x} \
                             (endpoints serve divergent bytes for {})",
                            self.pq
                        ),
                    ));
                }
            }
        }
        Ok(())
    }
}

impl ChunkSource for RemoteSource {
    fn observed_version(&self) -> Option<u64> {
        if self.unstamped {
            None
        } else {
            self.seen_version
        }
    }

    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize> {
        if pos >= self.len || buf.is_empty() {
            return Ok(0);
        }
        // Bound mid-stream retries: every endpoint gets at most one shot at
        // resuming this read (open_at itself walks all candidates per shot).
        let mut resumes = 0usize;
        loop {
            if self.stream.as_ref().map(|s| s.at) != Some(pos) {
                self.open_at(pos, None)?;
            }
            let r = {
                let s = self.stream.as_mut().expect("stream just ensured");
                s.body.read(buf)
            };
            match r {
                Ok(0) => {
                    // Clean short delivery (object shrank server-side): not
                    // an endpoint fault — drop the stream so a retry
                    // re-issues the range; the reader surfaces UnexpectedEof.
                    self.stream = None;
                    return Ok(0);
                }
                Ok(n) => {
                    let s = self.stream.as_mut().expect("stream open");
                    s.at += n as u64;
                    if let Some(m) = &self.metrics {
                        m.remote_fetch_bytes.add(n as u64);
                    }
                    self.delivered = true;
                    self.digest(pos, &buf[..n])?;
                    return Ok(n);
                }
                Err(e) => {
                    // The serving endpoint died mid-body: mark it, then
                    // resume the range at the current offset elsewhere.
                    let failed = self.stream.take().map(|s| s.addr);
                    if let Some(a) = &failed {
                        self.endpoints.note_err(a);
                    }
                    resumes += 1;
                    if resumes > self.endpoints.len() {
                        return Err(e);
                    }
                    if pos > 0 {
                        self.mixed = true;
                    }
                    // (open_at counts the failover once: `exclude` being
                    // set marks the first candidate as an after-failure
                    // switch — no second increment here.)
                    self.open_at(pos, failed.as_deref())?;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::http::{range_unsatisfiable, Handler, HttpServer, Request, Response};

    /// A strict-RFC endpoint: `bytes=0-0` against a 0-byte object answers
    /// 416 + `content-range: bytes */0` (S3 semantics), unlike the crate's
    /// internal servers which answer an empty 206.
    fn strict_empty_server() -> HttpServer {
        let handler: Handler = Arc::new(|req: Request| {
            if req.path.starts_with("/v1/objects/") && req.method == "GET" {
                let mut resp = range_unsatisfiable(0);
                resp = resp.with_header(wire::HDR_OBJ_CRC, "00000000");
                resp
            } else {
                Response::status(404)
            }
        });
        HttpServer::serve(handler, 2, "strict-empty").unwrap()
    }

    #[test]
    fn probe_resolves_strict_416_empty_object_as_size_zero() {
        let srv = strict_empty_server();
        let remote = RemoteBackend::new(&srv.addr.to_string(), None);
        assert_eq!(remote.size("b", "empty").unwrap(), 0, "416 resolved to size 0");
        assert!(remote.exists("b", "empty"));
        assert_eq!(remote.content_crc("b", "empty"), Some(0));
        let r = remote.open_entry("b", "empty").unwrap();
        assert!(r.is_empty());
        assert_eq!(r.read_all().unwrap(), b"");
    }

    #[test]
    fn all_endpoints_down_is_io() {
        // Nobody listens on either port: every operation must walk both
        // candidates and surface Io, never NotFound or a hang.
        let dead = RemoteBackend::multi(
            &["127.0.0.1:1", "127.0.0.1:2"],
            3,
            Duration::from_millis(50),
            None,
        );
        assert!(matches!(dead.open_entry("b", "o"), Err(StoreError::Io(_))));
        assert!(matches!(dead.size("b", "o"), Err(StoreError::Io(_))));
        assert!(matches!(dead.list("b"), Err(StoreError::Io(_))));
        assert!(!dead.exists("b", "o"));
    }

    #[test]
    fn hedge_slot_cap_is_enforced_and_released() {
        let tail = Arc::new(TailState {
            cfg: TailConfig { hedge_max_inflight: 2, ..TailConfig::default() },
            hedges_inflight: AtomicUsize::new(0),
        });
        let a = tail.acquire().expect("slot 1");
        let _b = tail.acquire().expect("slot 2");
        assert!(tail.acquire().is_none(), "cap reached");
        drop(a);
        assert!(tail.acquire().is_some(), "drop released the slot");
        let off = Arc::new(TailState {
            cfg: TailConfig::disabled(),
            hedges_inflight: AtomicUsize::new(0),
        });
        assert!(off.acquire().is_none(), "disabled policy has zero slots");
    }
}
