//! Endpoint health tracking for the remote tier: a per-endpoint
//! consecutive-error **circuit breaker** with half-open recovery and cheap
//! active re-probing, plus the **tail-latency signals** (latency EWMA,
//! live quantile histogram, outstanding-request counts) that drive
//! latency-aware selection and hedged reads — what turns a list of
//! `host:port` endpoints into a fault- and straggler-tolerant endpoint
//! *set* the [`RemoteBackend`](super::RemoteBackend) can fail over across.
//!
//! Mechanics:
//!
//! - **Passive marking** — every remote operation reports its outcome:
//!   [`EndpointSet::note_ok`] resets an endpoint's error streak,
//!   [`EndpointSet::note_err`] extends it. `endpoint_failure_limit`
//!   consecutive errors open the circuit (the endpoint is *unhealthy* and
//!   stops being selected while any healthy endpoint remains).
//! - **Half-open recovery** — an unhealthy endpoint becomes *eligible*
//!   again every `endpoint_probe_ms`: [`EndpointSet::plan`] leads with due
//!   broken endpoints, so live traffic doubles as the half-open trial (at
//!   most one request per window pays the failure latency; one success
//!   closes the circuit), and the set keeps working even when every
//!   endpoint is broken.
//! - **Active probing** — [`EndpointSet::maybe_probe`] (called on the
//!   selection path, so probing needs no dedicated scheduler thread)
//!   launches one short-lived background `GET /v1/health` per due broken
//!   endpoint; a 200 closes the circuit without risking a real read.
//! - **Latency tracking** — [`EndpointSet::note_latency`] folds each
//!   successful ranged read's time-to-first-byte into a per-endpoint EWMA
//!   and a [`LogHistogram`] whose live quantile estimate feeds the hedge
//!   trigger ([`EndpointSet::hedge_deadline`]). Open streams and in-flight
//!   attempts are counted via [`EndpointSet::track`] guards.
//!
//! Selection among healthy endpoints is **least-outstanding, tie-broken by
//! latency EWMA** (coarse log2 bands, so near-equal endpoints still share
//! load round-robin): under concurrency the outstanding counts spread work,
//! and a sequentially-probed set simply uses its fastest endpoint. An
//! endpoint whose EWMA exceeds the configured slow threshold is *soft*
//! deprioritized — never selected while a faster peer exists, its circuit
//! stays closed — but every `endpoint_probe_ms` one plan leads with it as a
//! **slow trial**, so its EWMA keeps getting samples and decays back down
//! when the endpoint speeds up (under hedging, that trial request is
//! hedged, so paying the slow endpoint's latency is bounded too). Health
//! and latency state are shared per backend instance — every reader opened
//! through one `RemoteBackend` observes (and contributes to) the same
//! state.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::GetBatchMetrics;
use crate::proto::http::HttpClient;
use crate::proto::wire::paths;
use crate::util::stats::LogHistogram;

/// EWMA smoothing factor for per-endpoint latency: `new = α·sample +
/// (1-α)·old`. 0.3 reacts to a 50x slowdown within one sample (the EWMA
/// lands well past any sane slow threshold) yet needs a handful of fast
/// samples to forgive it — brief hiccups don't flap the slow flag.
const EWMA_ALPHA: f64 = 0.3;

/// Minimum histogram samples before the live quantile estimate is trusted
/// as a hedge deadline; below this the configured floor (`hedge_min_ms`)
/// applies alone.
const HEDGE_MIN_SAMPLES: u64 = 16;

/// Tail-latency policy for one endpoint set: the slow-endpoint
/// deprioritization threshold plus the hedged-read trigger knobs. Carried
/// by `GetBatchConfig` (`endpoint_slow_ms`, `hedge_quantile`,
/// `hedge_min_ms`, `hedge_max_inflight`) and fed to
/// [`RemoteBackend::with_tail`](super::RemoteBackend::with_tail).
#[derive(Debug, Clone, PartialEq)]
pub struct TailConfig {
    /// Latency-EWMA threshold above which an endpoint is deprioritized
    /// (soft: circuit stays closed, periodic slow trials allow recovery).
    /// `Duration::ZERO` disables the slow flag.
    pub slow: Duration,
    /// Quantile of the endpoint's own latency histogram that triggers a
    /// hedge (0.95 → hedge once the attempt outlives the endpoint's P95).
    /// `0.0` disables hedging.
    pub hedge_quantile: f64,
    /// Floor under the quantile estimate: never hedge before this much
    /// wall time (guards against hedging every request while the
    /// histogram is still cold or the endpoint is genuinely fast).
    pub hedge_min: Duration,
    /// Cap on concurrent hedge attempts per backend — bounds the load
    /// amplification hedging can add during a brown-out. `0` disables
    /// hedging.
    pub hedge_max_inflight: usize,
}

impl Default for TailConfig {
    fn default() -> Self {
        TailConfig {
            slow: Duration::from_millis(500),
            hedge_quantile: 0.95,
            hedge_min: Duration::from_millis(25),
            hedge_max_inflight: 32,
        }
    }
}

impl TailConfig {
    /// Everything off: round-robin-era behavior for callers that opt out.
    pub fn disabled() -> TailConfig {
        TailConfig {
            slow: Duration::ZERO,
            hedge_quantile: 0.0,
            hedge_min: Duration::ZERO,
            hedge_max_inflight: 0,
        }
    }

    /// Whether hedged reads are on at all.
    pub fn hedging_enabled(&self) -> bool {
        self.hedge_quantile > 0.0 && self.hedge_max_inflight > 0
    }
}

/// Per-endpoint circuit state (under the endpoint's lock).
struct EpState {
    /// Consecutive failed operations (reset on any success).
    consec_errors: u32,
    /// Circuit open: the endpoint is skipped while healthy peers exist.
    unhealthy: bool,
    /// Last half-open trial admission by [`EndpointSet::plan`] (or failed
    /// operation). Rate-limits trials *independently* of probes — an
    /// endpoint whose server has no `/v1/health` route (S3-like front)
    /// must still recover through live-traffic trials.
    last_trial: Option<Instant>,
    /// Last active probe launch (rate-limits probes).
    last_probe: Option<Instant>,
    /// An active probe thread is in flight (don't stack probes).
    probe_inflight: bool,
    /// Last slow-trial admission: a slow-flagged (but healthy) endpoint is
    /// led with once per probe window so its EWMA keeps getting samples
    /// and can observe a recovery.
    last_slow_trial: Option<Instant>,
}

/// Per-endpoint latency signals (own lock — updated on every successful
/// ranged read, read on every plan).
struct LatStat {
    /// Decayed latency EWMA in µs; 0 until the first sample.
    ewma_us: f64,
    /// Log2-bucket histogram of per-ranged-read latency — the live
    /// quantile estimate behind the hedge deadline.
    hist: LogHistogram,
}

struct Endpoint {
    addr: String,
    state: Mutex<EpState>,
    lat: Mutex<LatStat>,
    /// Requests currently in flight against this endpoint (open streams +
    /// racing attempts), maintained by [`Inflight`] guards.
    outstanding: AtomicUsize,
}

/// RAII guard counting one in-flight request against an endpoint (see
/// [`EndpointSet::track`]); dropping it decrements the outstanding count
/// (and the per-endpoint in-flight gauge).
pub struct Inflight {
    ep: Arc<Endpoint>,
    metrics: Option<Arc<GetBatchMetrics>>,
}

impl Drop for Inflight {
    fn drop(&mut self) {
        self.ep.outstanding.fetch_sub(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.add_endpoint_inflight(&self.ep.addr, -1);
        }
    }
}

/// Coarse log2 band of a latency EWMA: endpoints within a ~2x band compare
/// equal, so modest differences still share load (round-robin rotation
/// breaks the tie) while a genuinely slower endpoint sorts after its peers.
fn ewma_band(ewma_us: f64) -> i64 {
    if ewma_us <= 0.0 {
        0
    } else {
        ewma_us.max(1.0).log2().round() as i64
    }
}

/// A health-tracked set of interchangeable endpoints serving the same
/// bucket data (replicated storage front, S3-like multi-host gateway).
pub struct EndpointSet {
    endpoints: Vec<Arc<Endpoint>>,
    rr: AtomicUsize,
    failure_limit: u32,
    probe_interval: Duration,
    /// Slow-flag threshold (see [`TailConfig::slow`]); ZERO disables.
    slow: Duration,
    metrics: Option<Arc<GetBatchMetrics>>,
}

impl EndpointSet {
    /// Track `addrs` with circuit-breaker parameters and the slow-endpoint
    /// threshold. `failure_limit` is clamped to ≥ 1 (a limit of 0 would
    /// open circuits spontaneously). Duplicate addresses are collapsed —
    /// health state is keyed by address, and a duplicate would shadow its
    /// twin's circuit (lookups resolve to the first instance, leaving the
    /// copy permanently "healthy" in rotation).
    pub fn new(
        addrs: &[&str],
        failure_limit: u32,
        probe_interval: Duration,
        slow: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> Arc<EndpointSet> {
        assert!(!addrs.is_empty(), "endpoint set needs at least one endpoint");
        let mut endpoints: Vec<Arc<Endpoint>> = Vec::with_capacity(addrs.len());
        for a in addrs {
            if endpoints.iter().any(|e| e.addr == *a) {
                continue;
            }
            if let Some(m) = &metrics {
                // Every endpoint gets its labeled health line the moment
                // it is configured (closed circuit), not at first failure.
                // Registration is refcounted per address, so sets sharing
                // an endpoint don't tear each other's line down on drop.
                m.register_endpoint(a);
            }
            endpoints.push(Arc::new(Endpoint {
                addr: a.to_string(),
                state: Mutex::new(EpState {
                    consec_errors: 0,
                    unhealthy: false,
                    last_trial: None,
                    last_probe: None,
                    probe_inflight: false,
                    last_slow_trial: None,
                }),
                lat: Mutex::new(LatStat { ewma_us: 0.0, hist: LogHistogram::new() }),
                outstanding: AtomicUsize::new(0),
            }));
        }
        Arc::new(EndpointSet {
            endpoints,
            rr: AtomicUsize::new(0),
            failure_limit: failure_limit.max(1),
            probe_interval,
            slow,
            metrics,
        })
    }

    pub fn len(&self) -> usize {
        self.endpoints.len()
    }

    pub fn is_empty(&self) -> bool {
        self.endpoints.is_empty()
    }

    /// All tracked endpoint addresses, in configuration order.
    pub fn addrs(&self) -> Vec<String> {
        self.endpoints.iter().map(|e| e.addr.clone()).collect()
    }

    /// The first configured endpoint (display / single-endpoint compat).
    pub fn primary(&self) -> &str {
        &self.endpoints[0].addr
    }

    /// Whether `addr`'s circuit is currently closed.
    pub fn is_healthy(&self, addr: &str) -> bool {
        self.endpoints
            .iter()
            .find(|e| e.addr == addr)
            .map(|e| !e.state.lock().unwrap().unhealthy)
            .unwrap_or(false)
    }

    /// Endpoints with an open circuit right now.
    pub fn unhealthy_count(&self) -> usize {
        self.endpoints.iter().filter(|e| e.state.lock().unwrap().unhealthy).count()
    }

    fn find(&self, addr: &str) -> Option<&Arc<Endpoint>> {
        self.endpoints.iter().find(|e| e.addr == addr)
    }

    /// Fold one successful ranged read's latency into `addr`'s EWMA and
    /// quantile histogram. The first sample seeds the EWMA directly, so a
    /// single pathological read is enough to flag a straggler.
    pub fn note_latency(&self, addr: &str, elapsed: Duration) {
        if let Some(ep) = self.find(addr) {
            let us = elapsed.as_secs_f64() * 1e6;
            let ewma_ms = {
                let mut lat = ep.lat.lock().unwrap();
                lat.ewma_us = if lat.hist.count() == 0 {
                    us
                } else {
                    EWMA_ALPHA * us + (1.0 - EWMA_ALPHA) * lat.ewma_us
                };
                lat.hist.record_us(us);
                lat.ewma_us / 1e3
            };
            if let Some(m) = &self.metrics {
                m.set_endpoint_latency(addr, ewma_ms);
            }
        }
    }

    /// Current latency EWMA for `addr` in milliseconds (`None` before the
    /// first sample). Tests and diagnostics.
    pub fn latency_ewma_ms(&self, addr: &str) -> Option<f64> {
        let ep = self.find(addr)?;
        let lat = ep.lat.lock().unwrap();
        (lat.hist.count() > 0).then_some(lat.ewma_us / 1e3)
    }

    /// Requests currently in flight against `addr`.
    pub fn outstanding(&self, addr: &str) -> usize {
        self.find(addr).map(|e| e.outstanding.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Count one in-flight request against `addr` for as long as the
    /// returned guard lives (attempt races, open streams).
    pub fn track(&self, addr: &str) -> Option<Inflight> {
        let ep = self.find(addr)?;
        ep.outstanding.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.add_endpoint_inflight(addr, 1);
        }
        Some(Inflight { ep: Arc::clone(ep), metrics: self.metrics.clone() })
    }

    /// How long an attempt against `addr` may run before a hedge fires:
    /// the `quantile` estimate from the endpoint's own latency histogram
    /// (once it has enough samples), floored by `floor` (`hedge_min_ms`).
    pub fn hedge_deadline(&self, addr: &str, quantile: f64, floor: Duration) -> Duration {
        let est = self
            .find(addr)
            .and_then(|ep| {
                let lat = ep.lat.lock().unwrap();
                if lat.hist.count() < HEDGE_MIN_SAMPLES {
                    return None;
                }
                let us = lat.hist.percentile_us((quantile * 100.0).clamp(0.0, 100.0));
                us.is_finite().then(|| Duration::from_micros(us as u64))
            })
            .unwrap_or(Duration::ZERO);
        est.max(floor)
    }

    /// The best healthy endpoint other than `exclude` to aim a hedge at:
    /// least outstanding, tie-broken by EWMA band, configuration order
    /// last. Slow-flagged endpoints are still eligible — with every faster
    /// peer excluded there may be nothing else, and a hedge against a slow
    /// endpoint can only improve on an attempt that already overran its
    /// deadline.
    pub fn hedge_peer(&self, exclude: &str) -> Option<String> {
        self.endpoints
            .iter()
            .filter(|e| e.addr != exclude && !e.state.lock().unwrap().unhealthy)
            .min_by_key(|e| {
                (
                    e.outstanding.load(Ordering::Relaxed),
                    ewma_band(e.lat.lock().unwrap().ewma_us),
                )
            })
            .map(|e| e.addr.clone())
    }

    /// Ordered candidate list for one operation. Leading the list (callers
    /// stop at the first success, so anything trailing a healthy peer never
    /// actually runs):
    ///
    /// 1. broken endpoints whose half-open window elapsed (live traffic is
    ///    the half-open trial; admission re-arms the window so trials don't
    ///    stampede),
    /// 2. slow-flagged endpoints whose slow-trial window elapsed (one real
    ///    request per window keeps the EWMA observable so the flag can
    ///    clear when the endpoint speeds up),
    /// 3. healthy endpoints, **least-outstanding first, tie-broken by
    ///    latency EWMA band**; endpoints flagged slow (EWMA above the slow
    ///    threshold) sort after every unflagged peer, and full ties keep a
    ///    round-robin rotation so cold or equal endpoints share load,
    /// 4. `last` (the endpoint the caller just watched fail) as the
    ///    absolute last resort.
    pub fn plan(&self, last: Option<&str>) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        let mut slow_trial: Vec<String> = Vec::new();
        let mut healthy: Vec<(String, u8, usize, i64)> = Vec::new();
        let now = Instant::now();
        let slow_us = self.slow.as_micros() as f64;
        for ep in &self.endpoints {
            let mut st = ep.state.lock().unwrap();
            if !st.unhealthy {
                if Some(ep.addr.as_str()) == last {
                    continue;
                }
                let ewma_us = ep.lat.lock().unwrap().ewma_us;
                let slow = slow_us > 0.0 && ewma_us > slow_us;
                if slow
                    && st
                        .last_slow_trial
                        .map(|t| now.duration_since(t) >= self.probe_interval)
                        .unwrap_or(true)
                {
                    // Slow trial: lead with the straggler once per window.
                    // Without this it would never see traffic again (its
                    // EWMA band sorts it last), freezing the EWMA at its
                    // worst and making the slow flag permanent.
                    st.last_slow_trial = Some(now);
                    slow_trial.push(ep.addr.clone());
                } else {
                    healthy.push((
                        ep.addr.clone(),
                        u8::from(slow),
                        ep.outstanding.load(Ordering::Relaxed),
                        ewma_band(ewma_us),
                    ));
                }
            } else if st
                .last_trial
                .map(|t| now.duration_since(t) >= self.probe_interval)
                .unwrap_or(true)
                && Some(ep.addr.as_str()) != last
            {
                st.last_trial = Some(now);
                out.push(ep.addr.clone());
            }
        }
        if !healthy.is_empty() {
            // Rotate before the (stable) sort: candidates whose keys tie —
            // cold starts, equal load — still spread round-robin.
            let k = self.rr.fetch_add(1, Ordering::Relaxed) % healthy.len();
            healthy.rotate_left(k);
            healthy.sort_by_key(|&(_, slow, outstanding, band)| (slow, outstanding, band));
        }
        out.extend(slow_trial);
        out.extend(healthy.into_iter().map(|(a, ..)| a));
        if let Some(l) = last {
            out.push(l.to_string());
        }
        out
    }

    /// Record a successful operation on `addr`: closes the circuit.
    pub fn note_ok(&self, addr: &str) {
        if let Some(ep) = self.find(addr) {
            let mut st = ep.state.lock().unwrap();
            st.consec_errors = 0;
            if st.unhealthy {
                st.unhealthy = false;
                if let Some(m) = &self.metrics {
                    m.endpoints_unhealthy.sub(1);
                    m.set_endpoint_health(addr, true);
                }
            }
        }
    }

    /// Record a failed operation on `addr`; `failure_limit` consecutive
    /// failures open the circuit.
    pub fn note_err(&self, addr: &str) {
        if let Some(ep) = self.find(addr) {
            let mut st = ep.state.lock().unwrap();
            st.consec_errors = st.consec_errors.saturating_add(1);
            // Failing (healthy or half-open trial) also re-arms the
            // trial window so back-to-back retries don't hammer it.
            st.last_trial = Some(Instant::now());
            if !st.unhealthy && st.consec_errors >= self.failure_limit {
                st.unhealthy = true;
                if let Some(m) = &self.metrics {
                    m.endpoints_unhealthy.add(1);
                    m.set_endpoint_health(addr, false);
                }
            }
        }
    }

    /// Launch an active `GET /v1/health` probe (detached thread, one per
    /// endpoint at a time) against every broken endpoint whose probe window
    /// has elapsed. Called from the selection path — probing is
    /// traffic-triggered, so an idle backend costs nothing. (Associated
    /// function because the probe thread needs an owned `Arc` of the set.)
    pub fn maybe_probe(set: &Arc<EndpointSet>, client: &HttpClient) {
        let now = Instant::now();
        for (i, ep) in set.endpoints.iter().enumerate() {
            let due = {
                let mut st = ep.state.lock().unwrap();
                // Probes run on their own timer (`last_probe`) so they can
                // never starve the live-traffic half-open trials that
                // `plan` admits on `last_trial` — against an endpoint with
                // no `/v1/health` route, trials are the only recovery path.
                let due = st.unhealthy
                    && !st.probe_inflight
                    && st
                        .last_probe
                        .map(|t| now.duration_since(t) >= set.probe_interval)
                        .unwrap_or(true);
                if due {
                    st.probe_inflight = true;
                    st.last_probe = Some(now);
                }
                due
            };
            if !due {
                continue;
            }
            let set2 = Arc::clone(set);
            let cl = client.clone();
            let idx = i;
            let spawned = std::thread::Builder::new()
                .name("ep-probe".to_string())
                .stack_size(128 * 1024)
                .spawn(move || {
                    let ep = &set2.endpoints[idx];
                    if let Some(m) = &set2.metrics {
                        m.endpoint_probes.inc();
                    }
                    let ok = cl
                        .get(&ep.addr, paths::HEALTH)
                        .map(|resp| {
                            let s = resp.status;
                            let _ = resp.into_bytes();
                            s == 200
                        })
                        .unwrap_or(false);
                    if ok {
                        set2.note_ok(&ep.addr);
                    }
                    ep.state.lock().unwrap().probe_inflight = false;
                });
            if spawned.is_err() {
                // Spawn failure (thread exhaustion): un-arm the flag so a
                // later call can retry instead of stranding the endpoint
                // with active probing permanently disabled.
                ep.state.lock().unwrap().probe_inflight = false;
            }
        }
    }
}

impl Drop for EndpointSet {
    /// Settle the node gauges: a set dropped with open circuits (bucket
    /// re-routed, cluster shutdown) must not leave `endpoints_unhealthy`
    /// inflated — or orphaned per-endpoint health lines — forever.
    fn drop(&mut self) {
        if let Some(m) = &self.metrics {
            let open = self
                .endpoints
                .iter()
                .filter(|e| e.state.lock().unwrap().unhealthy)
                .count();
            if open > 0 {
                m.endpoints_unhealthy.sub(open as i64);
            }
            for ep in &self.endpoints {
                m.drop_endpoint_health(&ep.addr);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(addrs: &[&str], limit: u32, probe: Duration) -> Arc<EndpointSet> {
        EndpointSet::new(addrs, limit, probe, Duration::ZERO, None)
    }

    fn set_slow(
        addrs: &[&str],
        probe: Duration,
        slow: Duration,
    ) -> Arc<EndpointSet> {
        EndpointSet::new(addrs, 3, probe, slow, None)
    }

    #[test]
    fn consecutive_errors_open_the_circuit() {
        let s = set(&["a:1", "b:2"], 3, Duration::from_secs(60));
        assert!(s.is_healthy("a:1"));
        s.note_err("a:1");
        s.note_err("a:1");
        assert!(s.is_healthy("a:1"), "below the limit");
        s.note_err("a:1");
        assert!(!s.is_healthy("a:1"), "limit reached");
        assert_eq!(s.unhealthy_count(), 1);
        // a success anywhere in the streak resets it
        s.note_err("b:2");
        s.note_err("b:2");
        s.note_ok("b:2");
        s.note_err("b:2");
        assert!(s.is_healthy("b:2"));
    }

    #[test]
    fn plan_skips_unhealthy_until_halfopen_window() {
        let s = set(&["a:1", "b:2"], 1, Duration::from_millis(40));
        s.note_err("a:1");
        assert!(!s.is_healthy("a:1"));
        // Broken endpoint excluded while fresh; note_err armed the window.
        assert_eq!(s.plan(None), vec!["b:2".to_string()]);
        std::thread::sleep(Duration::from_millis(60));
        // Window elapsed: it LEADS the plan as the half-open trial —
        // callers stop at the first success, so a trailing trial would
        // never actually run while the healthy peer keeps succeeding.
        let p = s.plan(None);
        assert_eq!(p.first().map(|x| x.as_str()), Some("a:1"), "{p:?}");
        assert!(p.contains(&"b:2".to_string()), "{p:?}");
        // ...and its admission re-armed the window immediately.
        assert_eq!(s.plan(None), vec!["b:2".to_string()]);
        // A trial success closes the circuit.
        s.note_ok("a:1");
        assert!(s.is_healthy("a:1"));
        assert_eq!(s.unhealthy_count(), 0);
    }

    #[test]
    fn plan_spreads_cold_endpoints_round_robin() {
        // No latency data, no load: all keys tie, so the rotation must
        // spread selection across the whole set (cold-start load sharing).
        let s = set(&["a:1", "b:2", "c:3"], 3, Duration::from_secs(60));
        let firsts: Vec<String> =
            (0..6).map(|_| s.plan(None).first().unwrap().clone()).collect();
        let distinct: std::collections::HashSet<&String> = firsts.iter().collect();
        assert_eq!(distinct.len(), 3, "{firsts:?}");
    }

    #[test]
    fn plan_prefers_lower_latency_ewma() {
        let s = set(&["a:1", "b:2"], 3, Duration::from_secs(60));
        for _ in 0..3 {
            s.note_latency("a:1", Duration::from_millis(50));
            s.note_latency("b:2", Duration::from_millis(1));
        }
        assert!(s.latency_ewma_ms("a:1").unwrap() > 40.0);
        assert!(s.latency_ewma_ms("b:2").unwrap() < 2.0);
        // EWMA bands differ by ~log2(50) ≈ 5.6: b always sorts first.
        for _ in 0..6 {
            assert_eq!(s.plan(None).first().map(|x| x.as_str()), Some("b:2"));
        }
    }

    #[test]
    fn plan_prefers_least_outstanding_over_ewma() {
        let s = set(&["a:1", "b:2"], 3, Duration::from_secs(60));
        for _ in 0..3 {
            s.note_latency("a:1", Duration::from_millis(8));
            s.note_latency("b:2", Duration::from_millis(1));
        }
        assert_eq!(s.plan(None).first().map(|x| x.as_str()), Some("b:2"));
        // Load b up: least-outstanding dominates the EWMA tie-break.
        let g1 = s.track("b:2").unwrap();
        let g2 = s.track("b:2").unwrap();
        assert_eq!(s.outstanding("b:2"), 2);
        assert_eq!(s.plan(None).first().map(|x| x.as_str()), Some("a:1"));
        drop(g1);
        drop(g2);
        assert_eq!(s.outstanding("b:2"), 0);
        assert_eq!(s.plan(None).first().map(|x| x.as_str()), Some("b:2"));
    }

    #[test]
    fn slow_endpoint_deprioritized_without_opening_circuit_and_recovers() {
        let s = set_slow(
            &["a:1", "b:2"],
            Duration::from_secs(60),
            Duration::from_millis(10),
        );
        s.note_latency("b:2", Duration::from_millis(1));
        // One pathological sample seeds the EWMA past the slow threshold.
        s.note_latency("a:1", Duration::from_millis(500));
        assert!(s.is_healthy("a:1"), "slowness must not open the circuit");
        assert_eq!(s.unhealthy_count(), 0);
        // First plan after flagging admits a leading slow trial...
        let p = s.plan(None);
        assert_eq!(p.first().map(|x| x.as_str()), Some("a:1"), "slow trial leads: {p:?}");
        // ...then the straggler sorts last for the rest of the window.
        for _ in 0..4 {
            let p = s.plan(None);
            assert_eq!(p, vec!["b:2".to_string(), "a:1".to_string()], "deprioritized");
        }
        // Fast samples (e.g. delivered by slow trials) decay the EWMA back
        // under the threshold: the flag clears and selection resumes.
        for _ in 0..12 {
            s.note_latency("a:1", Duration::from_millis(1));
        }
        assert!(s.latency_ewma_ms("a:1").unwrap() < 10.0, "EWMA recovered");
        let firsts: Vec<String> =
            (0..4).map(|_| s.plan(None).first().unwrap().clone()).collect();
        assert!(firsts.contains(&"a:1".to_string()), "recovered into rotation: {firsts:?}");
    }

    #[test]
    fn plan_deprioritizes_the_endpoint_that_just_failed() {
        // The just-failed endpoint is never first, but stays reachable as
        // the absolute last resort (a transient failure on it must not
        // abort the read when every other candidate is also failing).
        let s = set(&["a:1", "b:2"], 5, Duration::from_secs(60));
        for _ in 0..4 {
            let p = s.plan(Some("a:1"));
            assert_eq!(p, vec!["b:2".to_string(), "a:1".to_string()]);
        }
        // Sole endpoint: still offered.
        let lone = set(&["a:1"], 5, Duration::from_secs(60));
        assert_eq!(lone.plan(Some("a:1")), vec!["a:1".to_string()]);
    }

    #[test]
    fn hedge_peer_picks_best_other_healthy_endpoint() {
        let s = set(&["a:1", "b:2", "c:3"], 1, Duration::from_secs(60));
        for _ in 0..3 {
            s.note_latency("b:2", Duration::from_millis(20));
            s.note_latency("c:3", Duration::from_millis(1));
        }
        assert_eq!(s.hedge_peer("a:1").as_deref(), Some("c:3"), "fastest peer");
        assert_eq!(s.hedge_peer("c:3").as_deref(), Some("b:2"));
        s.note_err("c:3");
        assert_eq!(s.hedge_peer("a:1").as_deref(), Some("b:2"), "skips open circuits");
        let lone = set(&["a:1"], 1, Duration::from_secs(60));
        assert_eq!(lone.hedge_peer("a:1"), None, "nobody to hedge against");
    }

    #[test]
    fn hedge_deadline_floors_until_enough_samples() {
        let s = set(&["a:1"], 3, Duration::from_secs(60));
        let floor = Duration::from_millis(25);
        assert_eq!(s.hedge_deadline("a:1", 0.95, floor), floor, "cold histogram");
        for _ in 0..10 {
            s.note_latency("a:1", Duration::from_millis(200));
        }
        assert_eq!(
            s.hedge_deadline("a:1", 0.95, floor),
            floor,
            "still under the sample minimum"
        );
        for _ in 0..10 {
            s.note_latency("a:1", Duration::from_millis(200));
        }
        let d = s.hedge_deadline("a:1", 0.95, floor);
        assert!(d > Duration::from_millis(90) && d < Duration::from_millis(400), "{d:?}");
        // A fast endpoint's estimate never undercuts the floor.
        let f = set(&["f:1"], 3, Duration::from_secs(60));
        for _ in 0..20 {
            f.note_latency("f:1", Duration::from_micros(300));
        }
        assert_eq!(f.hedge_deadline("f:1", 0.95, floor), floor);
    }

    #[test]
    fn drop_settles_the_unhealthy_gauge() {
        let metrics = GetBatchMetrics::new();
        let s = EndpointSet::new(
            &["a:1", "b:2"],
            1,
            Duration::from_secs(60),
            Duration::ZERO,
            Some(Arc::clone(&metrics)),
        );
        s.note_err("a:1");
        s.note_err("b:2");
        assert_eq!(metrics.endpoints_unhealthy.get(), 2);
        drop(s);
        assert_eq!(metrics.endpoints_unhealthy.get(), 0, "drop paired the add");
    }

    #[test]
    fn per_endpoint_health_gauge_lines_track_the_circuit() {
        let metrics = GetBatchMetrics::new();
        let s = EndpointSet::new(
            &["a:1", "b:2"],
            1,
            Duration::from_secs(60),
            Duration::ZERO,
            Some(Arc::clone(&metrics)),
        );
        // One labeled line per configured endpoint, healthy at birth.
        let text = metrics.render("t0");
        let lines: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("ais_getbatch_remote_endpoint_healthy{"))
            .collect();
        assert_eq!(lines.len(), 2, "{lines:?}");
        assert!(text.contains("addr=\"a:1\"} 1") && text.contains("addr=\"b:2\"} 1"), "{text}");
        // Circuit opens → that endpoint's line flips to 0, the other stays 1.
        s.note_err("a:1");
        let text = metrics.render("t0");
        assert!(text.contains("addr=\"a:1\"} 0"), "{text}");
        assert!(text.contains("addr=\"b:2\"} 1"), "{text}");
        // Recovery flips it back.
        s.note_ok("a:1");
        assert!(metrics.render("t0").contains("addr=\"a:1\"} 1"));
        // Dropping the set removes its lines.
        drop(s);
        assert!(!metrics.render("t0").contains("remote_endpoint_healthy{"));
    }

    #[test]
    fn per_endpoint_latency_and_inflight_lines_render() {
        let metrics = GetBatchMetrics::new();
        let s = EndpointSet::new(
            &["a:1"],
            1,
            Duration::from_secs(60),
            Duration::ZERO,
            Some(Arc::clone(&metrics)),
        );
        let text = metrics.render("t0");
        assert!(
            text.contains("ais_getbatch_remote_endpoint_inflight{node=\"t0\",addr=\"a:1\"} 0"),
            "{text}"
        );
        s.note_latency("a:1", Duration::from_millis(12));
        let g = s.track("a:1").unwrap();
        let text = metrics.render("t0");
        assert!(
            text.contains("ais_getbatch_remote_endpoint_inflight{node=\"t0\",addr=\"a:1\"} 1"),
            "{text}"
        );
        let ewma_line = text
            .lines()
            .find(|l| l.starts_with("ais_getbatch_remote_endpoint_latency_ewma_ms{"))
            .expect("latency line rendered");
        let v: f64 = ewma_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((v - 12.0).abs() < 1.0, "{ewma_line}");
        drop(g);
        assert!(metrics
            .render("t0")
            .contains("ais_getbatch_remote_endpoint_inflight{node=\"t0\",addr=\"a:1\"} 0"));
        drop(s);
        assert!(!metrics.render("t0").contains("remote_endpoint_latency_ewma_ms{"));
    }

    #[test]
    fn duplicate_addrs_collapse() {
        // A duplicated address would shadow its twin's circuit (state is
        // keyed by addr): the set must collapse it.
        let s = set(&["a:1", "a:1", "b:2"], 1, Duration::from_secs(60));
        assert_eq!(s.len(), 2);
        s.note_err("a:1");
        assert!(!s.is_healthy("a:1"));
        assert_eq!(s.plan(None), vec!["b:2".to_string()], "no healthy ghost of a:1");
    }

    #[test]
    fn all_down_still_offers_halfopen_trials() {
        let s = set(&["a:1", "b:2"], 1, Duration::from_millis(0));
        s.note_err("a:1");
        s.note_err("b:2");
        assert_eq!(s.unhealthy_count(), 2);
        // Zero probe interval: every plan offers both as trials.
        let p = s.plan(None);
        assert_eq!(p.len(), 2, "{p:?}");
    }

    #[test]
    fn active_probe_recovers_endpoint_when_it_returns() {
        use crate::proto::http::{Handler, HttpServer, Request, Response};
        use std::sync::atomic::AtomicBool;

        let dead = Arc::new(AtomicBool::new(true));
        let dead2 = Arc::clone(&dead);
        let handler: Handler = Arc::new(move |req: Request| {
            if dead2.load(Ordering::Relaxed) {
                Response::text(500, "down")
            } else if req.path == paths::HEALTH {
                Response::ok(b"ok".to_vec())
            } else {
                Response::status(404)
            }
        });
        let srv = HttpServer::serve(handler, 2, "probe-test").unwrap();
        let addr = srv.addr.to_string();
        let metrics = GetBatchMetrics::new();
        let s = EndpointSet::new(
            &[addr.as_str()],
            1,
            Duration::from_millis(10),
            Duration::ZERO,
            Some(Arc::clone(&metrics)),
        );
        let cl = HttpClient::new(true);
        s.note_err(&addr);
        assert_eq!(metrics.endpoints_unhealthy.get(), 1);

        // While the endpoint is down, probes fire but the circuit stays open.
        std::thread::sleep(Duration::from_millis(20));
        EndpointSet::maybe_probe(&s, &cl);
        std::thread::sleep(Duration::from_millis(50));
        assert!(!s.is_healthy(&addr));

        // Endpoint comes back: the next due probe closes the circuit.
        dead.store(false, Ordering::Relaxed);
        for _ in 0..50 {
            EndpointSet::maybe_probe(&s, &cl);
            if s.is_healthy(&addr) {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(s.is_healthy(&addr), "probe recovered the endpoint");
        assert_eq!(metrics.endpoints_unhealthy.get(), 0);
        assert!(metrics.endpoint_probes.get() > 0);
    }
}
