//! Endpoint health tracking for the remote tier: a per-endpoint
//! consecutive-error **circuit breaker** with half-open recovery and cheap
//! active re-probing — what turns a list of `host:port` endpoints into a
//! fault-tolerant endpoint *set* the [`RemoteBackend`](super::RemoteBackend)
//! can fail over across.
//!
//! Mechanics:
//!
//! - **Passive marking** — every remote operation reports its outcome:
//!   [`EndpointSet::note_ok`] resets an endpoint's error streak,
//!   [`EndpointSet::note_err`] extends it. `endpoint_failure_limit`
//!   consecutive errors open the circuit (the endpoint is *unhealthy* and
//!   stops being selected while any healthy endpoint remains).
//! - **Half-open recovery** — an unhealthy endpoint becomes *eligible*
//!   again every `endpoint_probe_ms`: [`EndpointSet::plan`] leads with due
//!   broken endpoints, so live traffic doubles as the half-open trial (at
//!   most one request per window pays the failure latency; one success
//!   closes the circuit), and the set keeps working even when every
//!   endpoint is broken.
//! - **Active probing** — [`EndpointSet::maybe_probe`] (called on the
//!   selection path, so probing needs no dedicated scheduler thread)
//!   launches one short-lived background `GET /v1/health` per due broken
//!   endpoint; a 200 closes the circuit without risking a real read.
//!
//! Selection among healthy endpoints is round-robin. Health state is shared
//! per backend instance — every reader opened through one `RemoteBackend`
//! observes (and contributes to) the same circuit state.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::GetBatchMetrics;
use crate::proto::http::HttpClient;
use crate::proto::wire::paths;

/// Per-endpoint circuit state (under the endpoint's lock).
struct EpState {
    /// Consecutive failed operations (reset on any success).
    consec_errors: u32,
    /// Circuit open: the endpoint is skipped while healthy peers exist.
    unhealthy: bool,
    /// Last half-open trial admission by [`EndpointSet::plan`] (or failed
    /// operation). Rate-limits trials *independently* of probes — an
    /// endpoint whose server has no `/v1/health` route (S3-like front)
    /// must still recover through live-traffic trials.
    last_trial: Option<Instant>,
    /// Last active probe launch (rate-limits probes).
    last_probe: Option<Instant>,
    /// An active probe thread is in flight (don't stack probes).
    probe_inflight: bool,
}

struct Endpoint {
    addr: String,
    state: Mutex<EpState>,
}

/// A health-tracked set of interchangeable endpoints serving the same
/// bucket data (replicated storage front, S3-like multi-host gateway).
pub struct EndpointSet {
    endpoints: Vec<Arc<Endpoint>>,
    rr: AtomicUsize,
    failure_limit: u32,
    probe_interval: Duration,
    metrics: Option<Arc<GetBatchMetrics>>,
}

impl EndpointSet {
    /// Track `addrs` with circuit-breaker parameters. `failure_limit` is
    /// clamped to ≥ 1 (a limit of 0 would open circuits spontaneously).
    /// Duplicate addresses are collapsed — health state is keyed by
    /// address, and a duplicate would shadow its twin's circuit (lookups
    /// resolve to the first instance, leaving the copy permanently
    /// "healthy" in rotation).
    pub fn new(
        addrs: &[&str],
        failure_limit: u32,
        probe_interval: Duration,
        metrics: Option<Arc<GetBatchMetrics>>,
    ) -> Arc<EndpointSet> {
        assert!(!addrs.is_empty(), "endpoint set needs at least one endpoint");
        let mut endpoints: Vec<Arc<Endpoint>> = Vec::with_capacity(addrs.len());
        for a in addrs {
            if endpoints.iter().any(|e| e.addr == *a) {
                continue;
            }
            if let Some(m) = &metrics {
                // Every endpoint gets its labeled health line the moment
                // it is configured (closed circuit), not at first failure.
                // Registration is refcounted per address, so sets sharing
                // an endpoint don't tear each other's line down on drop.
                m.register_endpoint(a);
            }
            endpoints.push(Arc::new(Endpoint {
                addr: a.to_string(),
                state: Mutex::new(EpState {
                    consec_errors: 0,
                    unhealthy: false,
                    last_trial: None,
                    last_probe: None,
                    probe_inflight: false,
                }),
            }));
        }
        Arc::new(EndpointSet {
            endpoints,
            rr: AtomicUsize::new(0),
            failure_limit: failure_limit.max(1),
            probe_interval,
            metrics,
        })
    }

    pub fn len(&self) -> usize {
        self.endpoints.len()
    }

    pub fn is_empty(&self) -> bool {
        self.endpoints.is_empty()
    }

    /// All tracked endpoint addresses, in configuration order.
    pub fn addrs(&self) -> Vec<String> {
        self.endpoints.iter().map(|e| e.addr.clone()).collect()
    }

    /// The first configured endpoint (display / single-endpoint compat).
    pub fn primary(&self) -> &str {
        &self.endpoints[0].addr
    }

    /// Whether `addr`'s circuit is currently closed.
    pub fn is_healthy(&self, addr: &str) -> bool {
        self.endpoints
            .iter()
            .find(|e| e.addr == addr)
            .map(|e| !e.state.lock().unwrap().unhealthy)
            .unwrap_or(false)
    }

    /// Endpoints with an open circuit right now.
    pub fn unhealthy_count(&self) -> usize {
        self.endpoints.iter().filter(|e| e.state.lock().unwrap().unhealthy).count()
    }

    /// Ordered candidate list for one operation: broken endpoints whose
    /// half-open window has elapsed come **first** — callers stop at the
    /// first success, so a trailing trial would be admitted (window
    /// re-armed) yet never actually attempted while a healthy peer keeps
    /// succeeding, and an endpoint whose server has no `/v1/health` route
    /// could then never recover. Leading the list makes live traffic the
    /// real half-open trial: at most one request per `endpoint_probe_ms`
    /// pays the broken endpoint's failure latency (admission is recorded,
    /// so trials don't stampede), and its success closes the circuit.
    /// Healthy endpoints follow, round-robin rotated; `last` (the endpoint
    /// the caller just watched fail) is retried only as the absolute last
    /// resort. Callers walk the list in order and stop at the first
    /// success.
    pub fn plan(&self, last: Option<&str>) -> Vec<String> {
        let mut trial: Vec<String> = Vec::new();
        let mut healthy: Vec<String> = Vec::new();
        let now = Instant::now();
        for ep in &self.endpoints {
            let mut st = ep.state.lock().unwrap();
            if !st.unhealthy {
                if Some(ep.addr.as_str()) != last {
                    healthy.push(ep.addr.clone());
                }
            } else if st
                .last_trial
                .map(|t| now.duration_since(t) >= self.probe_interval)
                .unwrap_or(true)
                && Some(ep.addr.as_str()) != last
            {
                st.last_trial = Some(now);
                trial.push(ep.addr.clone());
            }
        }
        if !healthy.is_empty() {
            let k = self.rr.fetch_add(1, Ordering::Relaxed) % healthy.len();
            healthy.rotate_left(k);
        }
        trial.extend(healthy);
        if let Some(l) = last {
            trial.push(l.to_string());
        }
        trial
    }

    /// Record a successful operation on `addr`: closes the circuit.
    pub fn note_ok(&self, addr: &str) {
        if let Some(ep) = self.endpoints.iter().find(|e| e.addr == addr) {
            let mut st = ep.state.lock().unwrap();
            st.consec_errors = 0;
            if st.unhealthy {
                st.unhealthy = false;
                if let Some(m) = &self.metrics {
                    m.endpoints_unhealthy.sub(1);
                    m.set_endpoint_health(addr, true);
                }
            }
        }
    }

    /// Record a failed operation on `addr`; `failure_limit` consecutive
    /// failures open the circuit.
    pub fn note_err(&self, addr: &str) {
        if let Some(ep) = self.endpoints.iter().find(|e| e.addr == addr) {
            let mut st = ep.state.lock().unwrap();
            st.consec_errors = st.consec_errors.saturating_add(1);
            // Failing (healthy or half-open trial) also re-arms the
            // trial window so back-to-back retries don't hammer it.
            st.last_trial = Some(Instant::now());
            if !st.unhealthy && st.consec_errors >= self.failure_limit {
                st.unhealthy = true;
                if let Some(m) = &self.metrics {
                    m.endpoints_unhealthy.add(1);
                    m.set_endpoint_health(addr, false);
                }
            }
        }
    }

    /// Launch an active `GET /v1/health` probe (detached thread, one per
    /// endpoint at a time) against every broken endpoint whose probe window
    /// has elapsed. Called from the selection path — probing is
    /// traffic-triggered, so an idle backend costs nothing. (Associated
    /// function because the probe thread needs an owned `Arc` of the set.)
    pub fn maybe_probe(set: &Arc<EndpointSet>, client: &HttpClient) {
        let now = Instant::now();
        for (i, ep) in set.endpoints.iter().enumerate() {
            let due = {
                let mut st = ep.state.lock().unwrap();
                // Probes run on their own timer (`last_probe`) so they can
                // never starve the live-traffic half-open trials that
                // `plan` admits on `last_trial` — against an endpoint with
                // no `/v1/health` route, trials are the only recovery path.
                let due = st.unhealthy
                    && !st.probe_inflight
                    && st
                        .last_probe
                        .map(|t| now.duration_since(t) >= set.probe_interval)
                        .unwrap_or(true);
                if due {
                    st.probe_inflight = true;
                    st.last_probe = Some(now);
                }
                due
            };
            if !due {
                continue;
            }
            let set2 = Arc::clone(set);
            let cl = client.clone();
            let idx = i;
            let spawned = std::thread::Builder::new()
                .name("ep-probe".to_string())
                .stack_size(128 * 1024)
                .spawn(move || {
                    let ep = &set2.endpoints[idx];
                    if let Some(m) = &set2.metrics {
                        m.endpoint_probes.inc();
                    }
                    let ok = cl
                        .get(&ep.addr, paths::HEALTH)
                        .map(|resp| {
                            let s = resp.status;
                            let _ = resp.into_bytes();
                            s == 200
                        })
                        .unwrap_or(false);
                    if ok {
                        set2.note_ok(&ep.addr);
                    }
                    ep.state.lock().unwrap().probe_inflight = false;
                });
            if spawned.is_err() {
                // Spawn failure (thread exhaustion): un-arm the flag so a
                // later call can retry instead of stranding the endpoint
                // with active probing permanently disabled.
                ep.state.lock().unwrap().probe_inflight = false;
            }
        }
    }
}

impl Drop for EndpointSet {
    /// Settle the node gauges: a set dropped with open circuits (bucket
    /// re-routed, cluster shutdown) must not leave `endpoints_unhealthy`
    /// inflated — or orphaned per-endpoint health lines — forever.
    fn drop(&mut self) {
        if let Some(m) = &self.metrics {
            let open = self
                .endpoints
                .iter()
                .filter(|e| e.state.lock().unwrap().unhealthy)
                .count();
            if open > 0 {
                m.endpoints_unhealthy.sub(open as i64);
            }
            for ep in &self.endpoints {
                m.drop_endpoint_health(&ep.addr);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(addrs: &[&str], limit: u32, probe: Duration) -> Arc<EndpointSet> {
        EndpointSet::new(addrs, limit, probe, None)
    }

    #[test]
    fn consecutive_errors_open_the_circuit() {
        let s = set(&["a:1", "b:2"], 3, Duration::from_secs(60));
        assert!(s.is_healthy("a:1"));
        s.note_err("a:1");
        s.note_err("a:1");
        assert!(s.is_healthy("a:1"), "below the limit");
        s.note_err("a:1");
        assert!(!s.is_healthy("a:1"), "limit reached");
        assert_eq!(s.unhealthy_count(), 1);
        // a success anywhere in the streak resets it
        s.note_err("b:2");
        s.note_err("b:2");
        s.note_ok("b:2");
        s.note_err("b:2");
        assert!(s.is_healthy("b:2"));
    }

    #[test]
    fn plan_skips_unhealthy_until_halfopen_window() {
        let s = set(&["a:1", "b:2"], 1, Duration::from_millis(40));
        s.note_err("a:1");
        assert!(!s.is_healthy("a:1"));
        // Broken endpoint excluded while fresh; note_err armed the window.
        assert_eq!(s.plan(None), vec!["b:2".to_string()]);
        std::thread::sleep(Duration::from_millis(60));
        // Window elapsed: it LEADS the plan as the half-open trial —
        // callers stop at the first success, so a trailing trial would
        // never actually run while the healthy peer keeps succeeding.
        let p = s.plan(None);
        assert_eq!(p.first().map(|x| x.as_str()), Some("a:1"), "{p:?}");
        assert!(p.contains(&"b:2".to_string()), "{p:?}");
        // ...and its admission re-armed the window immediately.
        assert_eq!(s.plan(None), vec!["b:2".to_string()]);
        // A trial success closes the circuit.
        s.note_ok("a:1");
        assert!(s.is_healthy("a:1"));
        assert_eq!(s.unhealthy_count(), 0);
    }

    #[test]
    fn plan_round_robins_healthy_endpoints() {
        let s = set(&["a:1", "b:2", "c:3"], 3, Duration::from_secs(60));
        let firsts: Vec<String> =
            (0..6).map(|_| s.plan(None).first().unwrap().clone()).collect();
        let distinct: std::collections::HashSet<&String> = firsts.iter().collect();
        assert_eq!(distinct.len(), 3, "{firsts:?}");
    }

    #[test]
    fn plan_deprioritizes_the_endpoint_that_just_failed() {
        // The just-failed endpoint is never first, but stays reachable as
        // the absolute last resort (a transient failure on it must not
        // abort the read when every other candidate is also failing).
        let s = set(&["a:1", "b:2"], 5, Duration::from_secs(60));
        for _ in 0..4 {
            let p = s.plan(Some("a:1"));
            assert_eq!(p, vec!["b:2".to_string(), "a:1".to_string()]);
        }
        // Sole endpoint: still offered.
        let lone = set(&["a:1"], 5, Duration::from_secs(60));
        assert_eq!(lone.plan(Some("a:1")), vec!["a:1".to_string()]);
    }

    #[test]
    fn drop_settles_the_unhealthy_gauge() {
        let metrics = GetBatchMetrics::new();
        let s = EndpointSet::new(
            &["a:1", "b:2"],
            1,
            Duration::from_secs(60),
            Some(Arc::clone(&metrics)),
        );
        s.note_err("a:1");
        s.note_err("b:2");
        assert_eq!(metrics.endpoints_unhealthy.get(), 2);
        drop(s);
        assert_eq!(metrics.endpoints_unhealthy.get(), 0, "drop paired the add");
    }

    #[test]
    fn per_endpoint_health_gauge_lines_track_the_circuit() {
        let metrics = GetBatchMetrics::new();
        let s = EndpointSet::new(
            &["a:1", "b:2"],
            1,
            Duration::from_secs(60),
            Some(Arc::clone(&metrics)),
        );
        // One labeled line per configured endpoint, healthy at birth.
        let text = metrics.render("t0");
        let lines: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("ais_getbatch_remote_endpoint_healthy{"))
            .collect();
        assert_eq!(lines.len(), 2, "{lines:?}");
        assert!(text.contains("addr=\"a:1\"} 1") && text.contains("addr=\"b:2\"} 1"), "{text}");
        // Circuit opens → that endpoint's line flips to 0, the other stays 1.
        s.note_err("a:1");
        let text = metrics.render("t0");
        assert!(text.contains("addr=\"a:1\"} 0"), "{text}");
        assert!(text.contains("addr=\"b:2\"} 1"), "{text}");
        // Recovery flips it back.
        s.note_ok("a:1");
        assert!(metrics.render("t0").contains("addr=\"a:1\"} 1"));
        // Dropping the set removes its lines.
        drop(s);
        assert!(!metrics.render("t0").contains("remote_endpoint_healthy{"));
    }

    #[test]
    fn duplicate_addrs_collapse() {
        // A duplicated address would shadow its twin's circuit (state is
        // keyed by addr): the set must collapse it.
        let s = set(&["a:1", "a:1", "b:2"], 1, Duration::from_secs(60));
        assert_eq!(s.len(), 2);
        s.note_err("a:1");
        assert!(!s.is_healthy("a:1"));
        assert_eq!(s.plan(None), vec!["b:2".to_string()], "no healthy ghost of a:1");
    }

    #[test]
    fn all_down_still_offers_halfopen_trials() {
        let s = set(&["a:1", "b:2"], 1, Duration::from_millis(0));
        s.note_err("a:1");
        s.note_err("b:2");
        assert_eq!(s.unhealthy_count(), 2);
        // Zero probe interval: every plan offers both as trials.
        let p = s.plan(None);
        assert_eq!(p.len(), 2, "{p:?}");
    }

    #[test]
    fn active_probe_recovers_endpoint_when_it_returns() {
        use crate::proto::http::{Handler, HttpServer, Request, Response};
        use std::sync::atomic::AtomicBool;

        let dead = Arc::new(AtomicBool::new(true));
        let dead2 = Arc::clone(&dead);
        let handler: Handler = Arc::new(move |req: Request| {
            if dead2.load(Ordering::Relaxed) {
                Response::text(500, "down")
            } else if req.path == paths::HEALTH {
                Response::ok(b"ok".to_vec())
            } else {
                Response::status(404)
            }
        });
        let srv = HttpServer::serve(handler, 2, "probe-test").unwrap();
        let addr = srv.addr.to_string();
        let metrics = GetBatchMetrics::new();
        let s = EndpointSet::new(
            &[addr.as_str()],
            1,
            Duration::from_millis(10),
            Some(Arc::clone(&metrics)),
        );
        let cl = HttpClient::new(true);
        s.note_err(&addr);
        assert_eq!(metrics.endpoints_unhealthy.get(), 1);

        // While the endpoint is down, probes fire but the circuit stays open.
        std::thread::sleep(Duration::from_millis(20));
        EndpointSet::maybe_probe(&s, &cl);
        std::thread::sleep(Duration::from_millis(50));
        assert!(!s.is_healthy(&addr));

        // Endpoint comes back: the next due probe closes the circuit.
        dead.store(false, Ordering::Relaxed);
        for _ in 0..50 {
            EndpointSet::maybe_probe(&s, &cl);
            if s.is_healthy(&addr) {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(s.is_healthy(&addr), "probe recovered the endpoint");
        assert_eq!(metrics.endpoints_unhealthy.get(), 0);
        assert!(metrics.endpoint_probes.get() > 0);
    }
}
