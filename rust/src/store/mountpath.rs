//! Mountpaths: AIStore spreads each target's objects over its local disks
//! (the paper's testbed: 12 NVMe per node). Here each mountpath is a
//! directory; objects map to mountpaths by HRW so the layout is stable and
//! balanced, mirroring AIStore's per-disk distribution.

use std::path::{Path, PathBuf};

use crate::util::hrw;

#[derive(Debug, Clone)]
pub struct Mountpaths {
    roots: Vec<PathBuf>,
    hashes: Vec<u64>,
}

impl Mountpaths {
    /// Create `n` mountpath directories under `base` (mp0..mpN-1).
    pub fn create(base: &Path, n: usize) -> std::io::Result<Mountpaths> {
        assert!(n > 0);
        let mut roots = Vec::with_capacity(n);
        let mut hashes = Vec::with_capacity(n);
        for i in 0..n {
            let p = base.join(format!("mp{i}"));
            std::fs::create_dir_all(&p)?;
            hashes.push(hrw::fnv1a(format!("mp{i}").as_bytes()));
            roots.push(p);
        }
        Ok(Mountpaths { roots, hashes })
    }

    pub fn len(&self) -> usize {
        self.roots.len()
    }
    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }

    /// The mountpath that owns `key` (bucket/objname).
    pub fn resolve(&self, key: &str) -> &Path {
        &self.roots[hrw::pick(key, &self.hashes)]
    }

    /// Full filesystem path for an object.
    pub fn object_path(&self, bucket: &str, obj: &str) -> PathBuf {
        let key = format!("{bucket}/{obj}");
        // Objects may contain '/' — nest them as directories.
        self.resolve(&key).join(bucket).join(obj)
    }

    pub fn all_roots(&self) -> &[PathBuf] {
        &self.roots
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("gbmp-{}-{}", std::process::id(), name));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn stable_resolution() {
        let base = tmp("stable");
        let mp = Mountpaths::create(&base, 4).unwrap();
        for k in 0..50 {
            let key = format!("b/o{k}");
            assert_eq!(mp.resolve(&key), mp.resolve(&key));
        }
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn spreads_over_disks() {
        let base = tmp("spread");
        let mp = Mountpaths::create(&base, 4).unwrap();
        let mut used = std::collections::HashSet::new();
        for k in 0..200 {
            used.insert(mp.resolve(&format!("b/o{k}")).to_path_buf());
        }
        assert_eq!(used.len(), 4, "all mountpaths should receive objects");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn object_path_nests_bucket() {
        let base = tmp("nest");
        let mp = Mountpaths::create(&base, 2).unwrap();
        let p = mp.object_path("audio", "shards/s-1.tar");
        assert!(p.ends_with("audio/shards/s-1.tar"));
        std::fs::remove_dir_all(&base).unwrap();
    }
}
