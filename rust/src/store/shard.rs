//! Shard member extraction (§2.2): senders resolve `archpath` entries by
//! reading exactly the member's payload out of a locally stored TAR shard.
//! A per-node LRU-ish index cache avoids re-scanning shard headers on every
//! extraction — the paper's colocation discussion calls out "shard re-open
//! costs" as one of the overheads batching amortizes.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::tar;

use super::engine::{EntryReader, ObjectStore, StoreError};

#[derive(Debug)]
pub enum ShardError {
    Store(StoreError),
    Tar(tar::TarError),
    MemberNotFound { shard: String, member: String },
}

crate::impl_error! {
    ShardError {
        display {
            ShardError::Store(e) => "{e}", // transparent
            ShardError::Tar(e) => "tar: {e}",
            ShardError::MemberNotFound { shard, member } => "member not found: {shard}!{member}",
        }
        source {
            ShardError::Store(e) => e,
            ShardError::Tar(e) => e,
        }
        from {
            StoreError => Store,
            tar::TarError => Tar,
        }
    }
}

type Index = Arc<HashMap<String, (u64, u64)>>;

/// Cached shard indices: shard key → member → (payload offset, size).
pub struct ShardIndexCache {
    cache: Mutex<HashMap<String, Index>>,
    max_shards: usize,
    pub hits: crate::metrics::Counter,
    pub misses: crate::metrics::Counter,
}

impl ShardIndexCache {
    pub fn new(max_shards: usize) -> ShardIndexCache {
        ShardIndexCache {
            cache: Mutex::new(HashMap::new()),
            max_shards,
            hits: Default::default(),
            misses: Default::default(),
        }
    }

    fn index(&self, store: &ObjectStore, bucket: &str, shard: &str) -> Result<Index, ShardError> {
        // Key by the shard object's current write generation: an overwrite
        // makes the stale index unreachable immediately, even when the
        // explicit invalidation (local write hook or `/v1/invalidate`
        // broadcast) was missed — the same versioned-key backstop the chunk
        // cache uses. Generation 0 = unversioned (legacy sidecar), which
        // degrades to the old name-only behavior. The generation is read
        // BEFORE the shard is opened, so under a racing overwrite the skew
        // lands on (older generation, newer members): a key the very next
        // lookup — which sees the bumped generation — can no longer reach.
        let gen = store.content_version(bucket, shard).unwrap_or(0);
        let key = format!("{bucket}/{shard}@{gen}");
        if let Some(idx) = self.cache.lock().unwrap().get(&key) {
            self.hits.inc();
            return Ok(Arc::clone(idx));
        }
        self.misses.inc();
        // Scan headers via streaming read — does not load payloads.
        let f = store.open_read(bucket, shard)?;
        let members = tar::scan_members(std::io::BufReader::with_capacity(256 * 1024, f))?;
        let idx: Index =
            Arc::new(members.into_iter().map(|m| (m.name, (m.offset, m.size))).collect());
        let mut cache = self.cache.lock().unwrap();
        if cache.len() >= self.max_shards {
            // Simple clock-free eviction: drop an arbitrary entry. Shard
            // working sets are small and re-scan is cheap; LRU bookkeeping
            // on the hot path isn't worth it.
            if let Some(k) = cache.keys().next().cloned() {
                cache.remove(&k);
            }
        }
        cache.insert(key, Arc::clone(&idx));
        Ok(idx)
    }

    /// Open one member's payload as a range-bounded streaming
    /// [`EntryReader`] over the shard file — extraction never materializes
    /// the member; consumers pull it in `chunk_bytes` pieces.
    pub fn extract(
        &self,
        store: &ObjectStore,
        bucket: &str,
        shard: &str,
        member: &str,
    ) -> Result<EntryReader, ShardError> {
        let idx = self.index(store, bucket, shard)?;
        let &(off, size) = idx.get(member).ok_or_else(|| ShardError::MemberNotFound {
            shard: shard.to_string(),
            member: member.to_string(),
        })?;
        Ok(store.open_entry_range(bucket, shard, off, size)?)
    }

    /// List members of a shard (data-loader manifest construction).
    pub fn members(
        &self,
        store: &ObjectStore,
        bucket: &str,
        shard: &str,
    ) -> Result<Vec<(String, u64)>, ShardError> {
        let idx = self.index(store, bucket, shard)?;
        let mut v: Vec<(String, u64)> = idx.iter().map(|(k, &(_, s))| (k.clone(), s)).collect();
        v.sort();
        Ok(v)
    }

    /// Drop a shard's cached indices, all generations (after
    /// overwrite/delete). With generation-keyed entries this narrows the
    /// staleness window and frees memory early; reachability correctness is
    /// carried by the keys themselves.
    pub fn invalidate(&self, bucket: &str, shard: &str) {
        let prefix = format!("{bucket}/{shard}@");
        self.cache.lock().unwrap().retain(|k, _| !k.starts_with(&prefix));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tar::Entry;
    use std::path::PathBuf;

    fn setup(name: &str) -> (ObjectStore, ShardIndexCache, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbshard-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let store = ObjectStore::open(&base, 2).unwrap();
        (store, ShardIndexCache::new(8), base)
    }

    fn mkshard(n: usize) -> Vec<u8> {
        let entries: Vec<Entry> = (0..n)
            .map(|i| Entry { name: format!("utt/{i:04}.wav"), data: vec![i as u8; 100 + i * 7] })
            .collect();
        tar::write_archive(&entries).unwrap()
    }

    #[test]
    fn extract_members() {
        let (store, cache, base) = setup("extract");
        store.put("b", "s.tar", &mkshard(10)).unwrap();
        for i in [0usize, 3, 9] {
            let r = cache.extract(&store, "b", "s.tar", &format!("utt/{i:04}.wav")).unwrap();
            assert_eq!(r.len(), (100 + i * 7) as u64, "length known before streaming");
            assert_eq!(r.read_all().unwrap(), vec![i as u8; 100 + i * 7]);
        }
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn index_cached_after_first_extract() {
        let (store, cache, base) = setup("cachehit");
        store.put("b", "s.tar", &mkshard(5)).unwrap();
        cache.extract(&store, "b", "s.tar", "utt/0000.wav").unwrap();
        cache.extract(&store, "b", "s.tar", "utt/0001.wav").unwrap();
        cache.extract(&store, "b", "s.tar", "utt/0002.wav").unwrap();
        assert_eq!(cache.misses.get(), 1);
        assert_eq!(cache.hits.get(), 2);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn missing_member_error() {
        let (store, cache, base) = setup("nomember");
        store.put("b", "s.tar", &mkshard(2)).unwrap();
        assert!(matches!(
            cache.extract(&store, "b", "s.tar", "nope.wav"),
            Err(ShardError::MemberNotFound { .. })
        ));
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn missing_shard_error() {
        let (store, cache, base) = setup("noshard");
        assert!(matches!(
            cache.extract(&store, "b", "absent.tar", "m"),
            Err(ShardError::Store(StoreError::NotFound(_)))
        ));
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn invalidate_after_overwrite() {
        let (store, cache, base) = setup("inval");
        store.put("b", "s.tar", &mkshard(3)).unwrap();
        cache.extract(&store, "b", "s.tar", "utt/0000.wav").unwrap();
        // Overwrite with a different shard; stale index must be dropped.
        let entries = vec![Entry { name: "new/member.bin".into(), data: vec![7; 42] }];
        store.put("b", "s.tar", &tar::write_archive(&entries).unwrap()).unwrap();
        cache.invalidate("b", "s.tar");
        let data = cache.extract(&store, "b", "s.tar", "new/member.bin").unwrap().read_all().unwrap();
        assert_eq!(data, vec![7; 42]);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn generation_keys_survive_missed_invalidation() {
        let (store, cache, base) = setup("genkey");
        store.put("b", "s.tar", &mkshard(3)).unwrap();
        cache.extract(&store, "b", "s.tar", "utt/0000.wav").unwrap();
        let entries = vec![Entry { name: "new/member.bin".into(), data: vec![7; 42] }];
        store.put("b", "s.tar", &tar::write_archive(&entries).unwrap()).unwrap();
        // Deliberately NO invalidate(): the bumped write generation alone
        // must make the stale index unreachable.
        let data =
            cache.extract(&store, "b", "s.tar", "new/member.bin").unwrap().read_all().unwrap();
        assert_eq!(data, vec![7; 42]);
        assert_eq!(cache.misses.get(), 2, "overwrite forced a re-scan");
        assert!(
            matches!(
                cache.extract(&store, "b", "s.tar", "utt/0000.wav"),
                Err(ShardError::MemberNotFound { .. })
            ),
            "old member list is gone with the old generation"
        );
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn members_listing_sorted() {
        let (store, cache, base) = setup("list");
        store.put("b", "s.tar", &mkshard(4)).unwrap();
        let m = cache.members(&store, "b", "s.tar").unwrap();
        assert_eq!(m.len(), 4);
        assert_eq!(m[0].0, "utt/0000.wav");
        assert_eq!(m[0].1, 100);
        std::fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn eviction_bounded() {
        let (store, _cache, base) = setup("evict");
        let cache = ShardIndexCache::new(2);
        for i in 0..5 {
            store.put("b", &format!("s{i}.tar"), &mkshard(2)).unwrap();
            cache.extract(&store, "b", &format!("s{i}.tar"), "utt/0000.wav").unwrap();
        }
        assert!(cache.cache.lock().unwrap().len() <= 2);
        std::fs::remove_dir_all(base).unwrap();
    }
}
