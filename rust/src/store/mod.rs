//! Per-node storage substrate, tiered: the [`Backend`] trait every tier
//! implements, the local mountpath backend, a remote HTTP backend (objects
//! living on another node / S3-like endpoint, served by a health-tracked
//! endpoint *set* with transparent failover — see [`health`]), a
//! read-through LRU chunk cache with sequential read-ahead, and the
//! [`ObjectStore`] router mapping bucket → backend stack. TAR-shard member
//! extraction rides the same streaming [`EntryReader`] seam on every tier.
//!
//! Cross-node cache coherence: the local tier stamps every object with a
//! monotonic write generation (surfaced as `x-getbatch-version`), the
//! cache keys chunks by it ([`cache`]), and PUT/DELETE through any node
//! broadcasts a best-effort `/v1/invalidate` — versioned keys stay the
//! correctness backstop when a node misses the broadcast.

pub mod cache;
pub mod engine;
pub mod health;
pub mod local;
pub mod mountpath;
pub mod remote;
pub mod shard;

pub use cache::{CachedBackend, ChunkCache};
pub use engine::{Backend, ChunkSource, EntryReader, ObjectStat, ObjectStore, StoreError};
pub use health::{EndpointSet, TailConfig};
pub use local::LocalBackend;
pub use remote::RemoteBackend;
pub use shard::ShardIndexCache;
