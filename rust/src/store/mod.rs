//! Per-node object store substrate: buckets + objects on a local filesystem
//! spread over simulated mountpaths (disks), with TAR-shard member
//! extraction backed by a cached shard index.

pub mod engine;
pub mod mountpath;
pub mod shard;

pub use engine::{EntryReader, ObjectStore, StoreError};
pub use shard::ShardIndexCache;
