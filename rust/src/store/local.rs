//! The local-disk tier: bucket/object CRUD on local mountpaths — the
//! monolithic `ObjectStore` of earlier revisions, extracted behind the
//! [`Backend`] trait. PUTs are atomic (temp file + rename) and leave a
//! CRC-32 sidecar next to the object so recovery paths can verify content
//! identity without re-reading it; GETs hand out streaming entry readers
//! (whole object or shard-member span).
//!
//! **Versioning (cache coherence):** every PUT stamps the object with a
//! monotonic write generation, stored alongside the CRC in the sidecar
//! (`"{crc:08x} {version}"`). The caching tier keys chunks by this version,
//! so a stale cached chunk becomes unreachable the moment a newer version
//! is observed. The authoritative version lives in an in-memory map whose
//! update happens in the *same critical section* as the object rename —
//! the invariant consumers rely on is: bytes read from any file handle are
//! never **newer** than the version a later [`Backend::content_version`]
//! call reports (version visibility is monotonic w.r.t. content
//! visibility). Fresh objects (and objects recreated after a delete) seed
//! their version from the wall clock in nanoseconds, so a delete + re-PUT
//! can never reuse a version an overwrite chain already consumed.

use std::collections::HashMap;
use std::fs::{self, File};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::engine::{Backend, ChunkSource, EntryReader, StoreError};
use super::mountpath::Mountpaths;

/// Sidecar suffix carrying an object's PUT-time CRC-32 (8 hex chars) and,
/// since the coherence revision, its write generation (decimal, space
/// separated; older single-field sidecars still parse, version `None`).
/// Sidecars are internal: hidden from `list`, replaced on overwrite,
/// removed on delete.
const CRC_SUFFIX: &str = ".#crc32";

/// Seed version for an object with no prior generation: wall-clock
/// nanoseconds. Overwrites bump by 1, and any two filesystem writes are
/// far more than a nanosecond apart, so a recreated object's seed is
/// always past every version its previous incarnation reached.
fn fresh_version() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(1)
        .max(1)
}

/// Positioned reads over one entry's span of a local file. Keeps the OS
/// cursor aligned with the last read so the sequential hot path never pays
/// for a redundant seek.
struct FileSource {
    file: File,
    /// Absolute file offset where the entry begins.
    base: u64,
    /// Entry-relative position the OS cursor currently sits at.
    cursor: u64,
    /// Write generation read *after* the file handle was opened. The handle
    /// pins one inode (an overwrite renames a new file into place), so
    /// every byte this source delivers is at most this version — the upper
    /// bound consumers need to gate version-pinned fills without another
    /// probe (see module docs).
    version: Option<u64>,
}

impl ChunkSource for FileSource {
    fn observed_version(&self) -> Option<u64> {
        self.version
    }

    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize> {
        if pos != self.cursor {
            self.file.seek(SeekFrom::Start(self.base + pos))?;
            self.cursor = pos;
        }
        let n = self.file.read(buf)?;
        self.cursor += n as u64;
        Ok(n)
    }
}

/// One node's local mountpath store (see module docs).
pub struct LocalBackend {
    mounts: Mountpaths,
    tmp_seq: AtomicU64,
    tmp_dir: PathBuf,
    /// Injected read fault rate (failure testing); 0.0 in production.
    fault_rate: std::sync::Mutex<f64>,
    fault_rng: std::sync::Mutex<crate::util::rng::Rng>,
    /// Injected read latency (tail-latency testing): (delay, rate). A
    /// read sleeps `delay` with probability `rate` — the "slow-not-dead
    /// disk" the hedging/selection machinery is built against.
    latency: std::sync::Mutex<(Duration, f64)>,
    /// Authoritative per-object write generations, lazily seeded from
    /// sidecars. Each object has its own slot mutex: PUT/DELETE mutate the
    /// slot in the same critical section as the object rename/unlink (see
    /// module docs for the visibility invariant) and writers to one object
    /// serialize on it — while writes and version lookups of *unrelated*
    /// objects never contend (the outer map lock is held only for the
    /// entry lookup, never across filesystem I/O). Slots are not reclaimed
    /// on delete (`None` = "consult the sidecar"); the map is bounded by
    /// distinct objects touched, like the cache's metadata map.
    versions: Mutex<HashMap<(String, String), VersionSlot>>,
}

/// One object's write-generation slot: `None` = not loaded (consult the
/// sidecar), `Some(v)` = authoritative in-memory generation.
type VersionSlot = Arc<Mutex<Option<u64>>>;

impl LocalBackend {
    pub fn open(base: &Path, mountpaths: usize) -> Result<LocalBackend, StoreError> {
        let mounts = Mountpaths::create(base, mountpaths)?;
        let tmp_dir = base.join(".tmp");
        fs::create_dir_all(&tmp_dir)?;
        Ok(LocalBackend {
            mounts,
            tmp_seq: AtomicU64::new(0),
            tmp_dir,
            fault_rate: std::sync::Mutex::new(0.0),
            fault_rng: std::sync::Mutex::new(crate::util::rng::Rng::new(0xFA01)),
            latency: std::sync::Mutex::new((Duration::ZERO, 0.0)),
            versions: Mutex::new(HashMap::new()),
        })
    }

    /// Injected read fault rate (failure testing); 0.0 disables.
    pub fn set_fault_rate(&self, rate: f64) {
        *self.fault_rate.lock().unwrap() = rate;
    }

    /// Injected read latency (tail-latency testing): each read sleeps
    /// `delay` with probability `rate`. `rate` 1.0 makes every read slow
    /// (the deterministic 50x-slower-endpoint scenario); 0.0 disables.
    /// Unlike `set_fault_rate` this never errors — the backend is slow,
    /// not broken, which is exactly the case circuit breakers can't see.
    pub fn set_latency(&self, delay: Duration, rate: f64) {
        *self.latency.lock().unwrap() = (delay, rate);
    }

    fn maybe_fault(&self) -> Result<(), StoreError> {
        let rate = *self.fault_rate.lock().unwrap();
        if rate > 0.0 && self.fault_rng.lock().unwrap().bool(rate) {
            return Err(StoreError::Io(io::Error::new(io::ErrorKind::Other, "injected EIO")));
        }
        let (delay, lrate) = *self.latency.lock().unwrap();
        if !delay.is_zero()
            && lrate > 0.0
            && (lrate >= 1.0 || self.fault_rng.lock().unwrap().bool(lrate))
        {
            std::thread::sleep(delay);
        }
        Ok(())
    }

    fn path(&self, bucket: &str, obj: &str) -> PathBuf {
        self.mounts.object_path(bucket, obj)
    }

    fn sidecar_path(&self, bucket: &str, obj: &str) -> PathBuf {
        self.mounts.object_path(bucket, &format!("{obj}{CRC_SUFFIX}"))
    }

    /// Parse a sidecar into (crc, version). The pre-coherence format held
    /// only the CRC; such objects report `version: None` until their next
    /// PUT stamps one.
    fn read_sidecar(&self, bucket: &str, obj: &str) -> Option<(u32, Option<u64>)> {
        let text = fs::read_to_string(self.sidecar_path(bucket, obj)).ok()?;
        let mut fields = text.split_whitespace();
        let crc = u32::from_str_radix(fields.next()?, 16).ok()?;
        let version = fields.next().and_then(|v| v.parse().ok());
        Some((crc, version))
    }

    /// The object's version slot (created on first touch). The outer map
    /// lock is released before the caller locks the slot.
    fn version_slot(&self, bucket: &str, obj: &str) -> VersionSlot {
        let mut m = self.versions.lock().unwrap();
        Arc::clone(m.entry((bucket.to_string(), obj.to_string())).or_default())
    }

    /// Load a slot's version, falling back to the sidecar (process
    /// restart). Must be called with the slot locked.
    fn load_version(&self, slot: &mut Option<u64>, bucket: &str, obj: &str) -> Option<u64> {
        if slot.is_none() {
            *slot = self.read_sidecar(bucket, obj).and_then(|(_, v)| v);
        }
        *slot
    }

    /// Whole-object read convenience (tests/staging; streaming paths use
    /// [`Backend::open_entry`]).
    pub fn get(&self, bucket: &str, obj: &str) -> Result<Vec<u8>, StoreError> {
        self.open_entry(bucket, obj)?.read_all()
    }

    fn open_with_size(&self, bucket: &str, obj: &str) -> Result<(File, u64), StoreError> {
        self.maybe_fault()?;
        let p = self.path(bucket, obj);
        let f = File::open(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        let size = f.metadata()?.len();
        Ok((f, size))
    }

    fn reader(
        &self,
        bucket: &str,
        obj: &str,
        file: File,
        base: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        // Stamp order matters: the version is looked up only now, after the
        // handle was opened, so it upper-bounds the bytes the handle holds.
        let version = self.content_version(bucket, obj);
        let mut src = FileSource { file, base, cursor: 0, version };
        if base > 0 {
            src.file.seek(SeekFrom::Start(base))?;
        }
        Ok(EntryReader::from_source(Box::new(src), len))
    }

    pub fn mountpath_count(&self) -> usize {
        self.mounts.len()
    }
}

impl Backend for LocalBackend {
    /// Atomic PUT: write to a temp file on the same filesystem, then
    /// rename. The CRC-32 + version sidecar is written (atomically, tmp +
    /// rename) only *after* the object rename succeeded, so a failed PUT
    /// leaves the previous object/sidecar pair intact; if the sidecar
    /// itself cannot be written, any stale one is removed — recovery then
    /// sees "no hash" rather than a wrong hash and falls back to prefix
    /// verification instead of failing closed.
    ///
    /// The version bump and the object rename share one critical section of
    /// the object's version-slot lock: a reader that opened a file handle
    /// holding the *new* bytes can only have opened it after the rename, so
    /// any [`Backend::content_version`] lookup it performs afterwards
    /// observes at least the new version — the caching tier's fill check
    /// ("re-read the version after reading the bytes; insert only if it
    /// still equals the pinned one") is sound because bytes can never be
    /// newer than the reported version. The lock is per object: writes and
    /// version lookups of unrelated objects never wait on this PUT's
    /// filesystem I/O.
    fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        let dst = self.path(bucket, obj);
        if let Some(parent) = dst.parent() {
            fs::create_dir_all(parent)?;
        }
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self.tmp_dir.join(format!("put-{seq}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(data)?;
            f.sync_data().ok(); // best-effort durability; tmpfs in CI
        }
        let side = self.sidecar_path(bucket, obj);
        let stmp = self.tmp_dir.join(format!("crc-{seq}.tmp"));

        let slot = self.version_slot(bucket, obj);
        let mut ver = slot.lock().unwrap();
        let next = match self.load_version(&mut ver, bucket, obj) {
            Some(v) => v.wrapping_add(1),
            None => fresh_version(),
        };
        // Stage the sidecar before the object rename so the two renames are
        // back to back inside the critical section.
        let staged = (|| -> io::Result<()> {
            if let Some(parent) = side.parent() {
                fs::create_dir_all(parent)?;
            }
            fs::write(&stmp, format!("{:08x} {next}", crate::util::crc32::hash(data)))
        })()
        .is_ok();
        if let Err(e) = fs::rename(&tmp, &dst) {
            let _ = fs::remove_file(&stmp); // don't leak the staged sidecar
            return Err(e.into());
        }
        if !staged || fs::rename(&stmp, &side).is_err() {
            let _ = fs::remove_file(&side); // never advertise a stale hash
        }
        *ver = Some(next);
        Ok(())
    }

    fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.path(bucket, obj).is_file()
    }

    fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        let p = self.path(bucket, obj);
        // Only a true NotFound maps to NotFound — permission and I/O errors
        // must surface as Io so callers don't misclassify them (and, e.g.,
        // GFN doesn't treat a sick disk as a clean miss).
        let md = fs::metadata(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        Ok(md.len())
    }

    fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let (file, size) = self.open_with_size(bucket, obj)?;
        self.reader(bucket, obj, file, 0, size)
    }

    fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let (file, size) = self.open_with_size(bucket, obj)?;
        if offset.saturating_add(len) > size {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} past EOF ({size}) in {bucket}/{obj}"),
            )));
        }
        self.reader(bucket, obj, file, offset, len)
    }

    fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        let p = self.path(bucket, obj);
        let slot = self.version_slot(bucket, obj);
        let mut ver = slot.lock().unwrap();
        fs::remove_file(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        let _ = fs::remove_file(self.sidecar_path(bucket, obj));
        // Back to "consult the sidecar": the sidecar is gone, so lookups
        // report no version and a re-PUT reseeds from the clock (past every
        // version this incarnation consumed).
        *ver = None;
        Ok(())
    }

    /// List objects of a bucket (admin/debug; walks all mountpaths,
    /// skipping internal CRC sidecars).
    fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        let mut out = Vec::new();
        for root in self.mounts.all_roots() {
            let bdir = root.join(bucket);
            if bdir.is_dir() {
                walk(&bdir, &bdir, &mut out)?;
            }
        }
        out.retain(|n| !n.ends_with(CRC_SUFFIX));
        out.sort();
        Ok(out)
    }

    fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32> {
        Some(self.read_sidecar(bucket, obj)?.0)
    }

    fn content_version(&self, bucket: &str, obj: &str) -> Option<u64> {
        let slot = self.version_slot(bucket, obj);
        let mut ver = slot.lock().unwrap();
        self.load_version(&mut ver, bucket, obj)
    }
}

fn walk(base: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk(base, &p, out)?;
        } else {
            out.push(p.strip_prefix(base).unwrap().to_string_lossy().into_owned());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend(name: &str) -> (LocalBackend, PathBuf) {
        let base = std::env::temp_dir().join(format!("gblocal-{}-{}", std::process::id(), name));
        let _ = fs::remove_dir_all(&base);
        fs::create_dir_all(&base).unwrap();
        (LocalBackend::open(&base, 3).unwrap(), base)
    }

    #[test]
    fn crc_sidecar_written_and_replaced() {
        let (b, base) = backend("crc");
        b.put("b", "o", b"hello").unwrap();
        assert_eq!(b.content_crc("b", "o"), Some(crate::util::crc32::hash(b"hello")));
        b.put("b", "o", b"other-bytes").unwrap();
        assert_eq!(b.content_crc("b", "o"), Some(crate::util::crc32::hash(b"other-bytes")));
        assert_eq!(b.content_crc("b", "nope"), None);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn sidecars_hidden_from_list_and_removed_on_delete() {
        let (b, base) = backend("side");
        b.put("b", "a", b"1").unwrap();
        b.put("b", "dir/nested", b"2").unwrap();
        assert_eq!(b.list("b").unwrap(), vec!["a", "dir/nested"]);
        b.delete("b", "a").unwrap();
        assert_eq!(b.content_crc("b", "a"), None, "sidecar removed with object");
        assert_eq!(b.list("b").unwrap(), vec!["dir/nested"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn size_maps_only_true_notfound_to_notfound() {
        let base = std::env::temp_dir()
            .join(format!("gblocal-{}-sizemap", std::process::id()));
        let _ = fs::remove_dir_all(&base);
        fs::create_dir_all(&base).unwrap();
        // Single mountpath so the colliding paths share a root.
        let b = LocalBackend::open(&base, 1).unwrap();
        assert!(matches!(b.size("b", "absent"), Err(StoreError::NotFound(_))));
        // A path through a *file* component fails with ENOTDIR — an I/O
        // error, not a clean miss; it must not be reported as NotFound.
        b.put("b", "o", b"x").unwrap();
        assert!(matches!(b.size("b", "o/sub"), Err(StoreError::Io(_))));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn versions_bump_monotonically_and_survive_delete_recreate() {
        let (b, base) = backend("ver");
        assert_eq!(b.content_version("b", "o"), None, "no version before first PUT");
        b.put("b", "o", b"v1").unwrap();
        let v1 = b.content_version("b", "o").expect("stamped");
        b.put("b", "o", b"v2").unwrap();
        let v2 = b.content_version("b", "o").expect("stamped");
        assert!(v2 > v1, "overwrite bumps: {v1} -> {v2}");
        assert_eq!(v2, v1 + 1, "overwrite is prev + 1");
        // Version rides the sidecar: a fresh backend over the same dir
        // reloads it.
        let reopened = LocalBackend::open(&base, 3).unwrap();
        assert_eq!(reopened.content_version("b", "o"), Some(v2));
        // Delete + recreate must never land inside the consumed range
        // [v1, v2] — a remote cache still holding v1/v2 chunks would
        // otherwise serve resurrected stale bytes.
        b.delete("b", "o").unwrap();
        assert_eq!(b.content_version("b", "o"), None);
        b.put("b", "o", b"reborn").unwrap();
        let v3 = b.content_version("b", "o").expect("stamped");
        assert!(v3 > v2, "recreated version past the old chain: {v2} vs {v3}");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn stat_bundles_len_version_crc() {
        let (b, base) = backend("stat");
        b.put("b", "o", b"hello").unwrap();
        let s = b.stat("b", "o").unwrap();
        assert_eq!(s.len, 5);
        assert_eq!(s.crc, Some(crate::util::crc32::hash(b"hello")));
        assert_eq!(s.version, b.content_version("b", "o"));
        assert!(matches!(b.stat("b", "nope"), Err(StoreError::NotFound(_))));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn legacy_crc_only_sidecar_still_parses() {
        let (b, base) = backend("legacy");
        b.put("b", "o", b"payload").unwrap();
        // Rewrite the sidecar in the pre-coherence single-field format.
        let side = b.sidecar_path("b", "o");
        fs::write(&side, format!("{:08x}", crate::util::crc32::hash(b"payload"))).unwrap();
        let fresh = LocalBackend::open(&base, 3).unwrap();
        assert_eq!(fresh.content_crc("b", "o"), Some(crate::util::crc32::hash(b"payload")));
        assert_eq!(fresh.content_version("b", "o"), None, "legacy sidecar is unversioned");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn readers_carry_the_open_time_version() {
        let (b, base) = backend("obsver");
        assert_eq!(
            b.open_entry("b", "o").err().map(|e| matches!(e, StoreError::NotFound(_))),
            Some(true)
        );
        b.put("b", "o", b"payload").unwrap();
        let v = b.content_version("b", "o").expect("stamped");
        let r = b.open_entry("b", "o").unwrap();
        assert_eq!(r.observed_version(), Some(v), "whole-object reader stamped at open");
        let rr = b.open_entry_range("b", "o", 1, 3).unwrap();
        assert_eq!(rr.observed_version(), Some(v), "ranged reader stamped at open");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn fault_injection_on_reads() {
        let (b, base) = backend("fault");
        b.put("b", "o", b"x").unwrap();
        b.set_fault_rate(1.0);
        assert!(b.open_entry("b", "o").is_err());
        b.set_fault_rate(0.0);
        assert_eq!(b.get("b", "o").unwrap(), b"x");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn latency_injection_delays_reads_without_erroring() {
        let (b, base) = backend("latency");
        b.put("b", "o", b"payload").unwrap();
        b.set_latency(Duration::from_millis(40), 1.0);
        let t0 = std::time::Instant::now();
        assert_eq!(b.get("b", "o").unwrap(), b"payload", "slow, not broken");
        assert!(t0.elapsed() >= Duration::from_millis(40), "delay applied");
        b.set_latency(Duration::ZERO, 0.0);
        let t0 = std::time::Instant::now();
        assert_eq!(b.get("b", "o").unwrap(), b"payload");
        assert!(t0.elapsed() < Duration::from_millis(40), "delay cleared");
        fs::remove_dir_all(base).unwrap();
    }
}
