//! The local-disk tier: bucket/object CRUD on local mountpaths — the
//! monolithic `ObjectStore` of earlier revisions, extracted behind the
//! [`Backend`] trait. PUTs are atomic (temp file + rename) and leave a
//! CRC-32 sidecar next to the object so recovery paths can verify content
//! identity without re-reading it; GETs hand out streaming entry readers
//! (whole object or shard-member span).

use std::fs::{self, File};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::engine::{Backend, ChunkSource, EntryReader, StoreError};
use super::mountpath::Mountpaths;

/// Sidecar suffix carrying an object's PUT-time CRC-32 (8 hex chars).
/// Sidecars are internal: hidden from `list`, replaced on overwrite,
/// removed on delete.
const CRC_SUFFIX: &str = ".#crc32";

/// Positioned reads over one entry's span of a local file. Keeps the OS
/// cursor aligned with the last read so the sequential hot path never pays
/// for a redundant seek.
struct FileSource {
    file: File,
    /// Absolute file offset where the entry begins.
    base: u64,
    /// Entry-relative position the OS cursor currently sits at.
    cursor: u64,
}

impl ChunkSource for FileSource {
    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize> {
        if pos != self.cursor {
            self.file.seek(SeekFrom::Start(self.base + pos))?;
            self.cursor = pos;
        }
        let n = self.file.read(buf)?;
        self.cursor += n as u64;
        Ok(n)
    }
}

/// One node's local mountpath store (see module docs).
pub struct LocalBackend {
    mounts: Mountpaths,
    tmp_seq: AtomicU64,
    tmp_dir: PathBuf,
    /// Injected read fault rate (failure testing); 0.0 in production.
    fault_rate: std::sync::Mutex<f64>,
    fault_rng: std::sync::Mutex<crate::util::rng::Rng>,
}

impl LocalBackend {
    pub fn open(base: &Path, mountpaths: usize) -> Result<LocalBackend, StoreError> {
        let mounts = Mountpaths::create(base, mountpaths)?;
        let tmp_dir = base.join(".tmp");
        fs::create_dir_all(&tmp_dir)?;
        Ok(LocalBackend {
            mounts,
            tmp_seq: AtomicU64::new(0),
            tmp_dir,
            fault_rate: std::sync::Mutex::new(0.0),
            fault_rng: std::sync::Mutex::new(crate::util::rng::Rng::new(0xFA01)),
        })
    }

    /// Injected read fault rate (failure testing); 0.0 disables.
    pub fn set_fault_rate(&self, rate: f64) {
        *self.fault_rate.lock().unwrap() = rate;
    }

    fn maybe_fault(&self) -> Result<(), StoreError> {
        let rate = *self.fault_rate.lock().unwrap();
        if rate > 0.0 && self.fault_rng.lock().unwrap().bool(rate) {
            return Err(StoreError::Io(io::Error::new(io::ErrorKind::Other, "injected EIO")));
        }
        Ok(())
    }

    fn path(&self, bucket: &str, obj: &str) -> PathBuf {
        self.mounts.object_path(bucket, obj)
    }

    fn sidecar_path(&self, bucket: &str, obj: &str) -> PathBuf {
        self.mounts.object_path(bucket, &format!("{obj}{CRC_SUFFIX}"))
    }

    /// Whole-object read convenience (tests/staging; streaming paths use
    /// [`Backend::open_entry`]).
    pub fn get(&self, bucket: &str, obj: &str) -> Result<Vec<u8>, StoreError> {
        self.open_entry(bucket, obj)?.read_all()
    }

    fn open_with_size(&self, bucket: &str, obj: &str) -> Result<(File, u64), StoreError> {
        self.maybe_fault()?;
        let p = self.path(bucket, obj);
        let f = File::open(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        let size = f.metadata()?.len();
        Ok((f, size))
    }

    fn reader(file: File, base: u64, len: u64) -> Result<EntryReader, StoreError> {
        let mut src = FileSource { file, base, cursor: 0 };
        if base > 0 {
            src.file.seek(SeekFrom::Start(base))?;
        }
        Ok(EntryReader::from_source(Box::new(src), len))
    }

    pub fn mountpath_count(&self) -> usize {
        self.mounts.len()
    }
}

impl Backend for LocalBackend {
    /// Atomic PUT: write to a temp file on the same filesystem, then
    /// rename. The CRC-32 sidecar is written (atomically, tmp + rename)
    /// only *after* the object rename succeeded, so a failed PUT leaves
    /// the previous object/sidecar pair intact; if the sidecar itself
    /// cannot be written, any stale one is removed — recovery then sees
    /// "no hash" rather than a wrong hash and falls back to prefix
    /// verification instead of failing closed.
    fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        let dst = self.path(bucket, obj);
        if let Some(parent) = dst.parent() {
            fs::create_dir_all(parent)?;
        }
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self.tmp_dir.join(format!("put-{seq}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(data)?;
            f.sync_data().ok(); // best-effort durability; tmpfs in CI
        }
        fs::rename(&tmp, &dst)?;
        let side = self.sidecar_path(bucket, obj);
        let write_sidecar = || -> io::Result<()> {
            if let Some(parent) = side.parent() {
                fs::create_dir_all(parent)?;
            }
            let stmp = self.tmp_dir.join(format!("crc-{seq}.tmp"));
            fs::write(&stmp, format!("{:08x}", crate::util::crc32::hash(data)))?;
            fs::rename(&stmp, &side)?;
            Ok(())
        };
        if write_sidecar().is_err() {
            let _ = fs::remove_file(&side); // never advertise a stale hash
        }
        Ok(())
    }

    fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.path(bucket, obj).is_file()
    }

    fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        let p = self.path(bucket, obj);
        // Only a true NotFound maps to NotFound — permission and I/O errors
        // must surface as Io so callers don't misclassify them (and, e.g.,
        // GFN doesn't treat a sick disk as a clean miss).
        let md = fs::metadata(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        Ok(md.len())
    }

    fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let (file, size) = self.open_with_size(bucket, obj)?;
        Self::reader(file, 0, size)
    }

    fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let (file, size) = self.open_with_size(bucket, obj)?;
        if offset.saturating_add(len) > size {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} past EOF ({size}) in {bucket}/{obj}"),
            )));
        }
        Self::reader(file, offset, len)
    }

    fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        let p = self.path(bucket, obj);
        fs::remove_file(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        let _ = fs::remove_file(self.sidecar_path(bucket, obj));
        Ok(())
    }

    /// List objects of a bucket (admin/debug; walks all mountpaths,
    /// skipping internal CRC sidecars).
    fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        let mut out = Vec::new();
        for root in self.mounts.all_roots() {
            let bdir = root.join(bucket);
            if bdir.is_dir() {
                walk(&bdir, &bdir, &mut out)?;
            }
        }
        out.retain(|n| !n.ends_with(CRC_SUFFIX));
        out.sort();
        Ok(out)
    }

    fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32> {
        let text = fs::read_to_string(self.sidecar_path(bucket, obj)).ok()?;
        u32::from_str_radix(text.trim(), 16).ok()
    }
}

fn walk(base: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk(base, &p, out)?;
        } else {
            out.push(p.strip_prefix(base).unwrap().to_string_lossy().into_owned());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend(name: &str) -> (LocalBackend, PathBuf) {
        let base = std::env::temp_dir().join(format!("gblocal-{}-{}", std::process::id(), name));
        let _ = fs::remove_dir_all(&base);
        fs::create_dir_all(&base).unwrap();
        (LocalBackend::open(&base, 3).unwrap(), base)
    }

    #[test]
    fn crc_sidecar_written_and_replaced() {
        let (b, base) = backend("crc");
        b.put("b", "o", b"hello").unwrap();
        assert_eq!(b.content_crc("b", "o"), Some(crate::util::crc32::hash(b"hello")));
        b.put("b", "o", b"other-bytes").unwrap();
        assert_eq!(b.content_crc("b", "o"), Some(crate::util::crc32::hash(b"other-bytes")));
        assert_eq!(b.content_crc("b", "nope"), None);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn sidecars_hidden_from_list_and_removed_on_delete() {
        let (b, base) = backend("side");
        b.put("b", "a", b"1").unwrap();
        b.put("b", "dir/nested", b"2").unwrap();
        assert_eq!(b.list("b").unwrap(), vec!["a", "dir/nested"]);
        b.delete("b", "a").unwrap();
        assert_eq!(b.content_crc("b", "a"), None, "sidecar removed with object");
        assert_eq!(b.list("b").unwrap(), vec!["dir/nested"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn size_maps_only_true_notfound_to_notfound() {
        let base = std::env::temp_dir()
            .join(format!("gblocal-{}-sizemap", std::process::id()));
        let _ = fs::remove_dir_all(&base);
        fs::create_dir_all(&base).unwrap();
        // Single mountpath so the colliding paths share a root.
        let b = LocalBackend::open(&base, 1).unwrap();
        assert!(matches!(b.size("b", "absent"), Err(StoreError::NotFound(_))));
        // A path through a *file* component fails with ENOTDIR — an I/O
        // error, not a clean miss; it must not be reported as NotFound.
        b.put("b", "o", b"x").unwrap();
        assert!(matches!(b.size("b", "o/sub"), Err(StoreError::Io(_))));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn fault_injection_on_reads() {
        let (b, base) = backend("fault");
        b.put("b", "o", b"x").unwrap();
        b.set_fault_rate(1.0);
        assert!(b.open_entry("b", "o").is_err());
        b.set_fault_rate(0.0);
        assert_eq!(b.get("b", "o").unwrap(), b"x");
        fs::remove_dir_all(base).unwrap();
    }
}
