//! The storage-layer seam: the [`Backend`] trait every tier implements
//! (local disk, remote HTTP, read-through cache), the streaming
//! [`EntryReader`] all read paths consume, and the [`ObjectStore`] router
//! that maps buckets onto backend stacks.
//!
//! `ObjectStore` used to *be* the local-disk store; that implementation now
//! lives in [`super::local::LocalBackend`] and this type is reduced to
//! routing: every bucket resolves to a backend stack (the per-node local
//! backend by default; remote and cached stacks are installed per bucket
//! from `GetBatchConfig` or at runtime). Call sites — senders, DT-local
//! resolution, the HTTP object handler, shard extraction, GFN recovery —
//! are unchanged: they keep asking the store for readers and the router
//! hands them whichever tier owns the bucket.

use std::collections::HashMap;
use std::io::{self, Read};
use std::path::Path;
use std::sync::{Arc, RwLock};

use super::local::LocalBackend;

#[derive(Debug)]
pub enum StoreError {
    NotFound(String),
    Io(io::Error),
}

crate::impl_error! {
    StoreError {
        display {
            StoreError::NotFound(k) => "object not found: {k}",
            StoreError::Io(e) => "io: {e}",
        }
        source {
            StoreError::Io(e) => e,
        }
        from {
            io::Error => Io,
        }
    }
}

impl From<StoreError> for io::Error {
    fn from(e: StoreError) -> io::Error {
        match e {
            StoreError::NotFound(k) => {
                io::Error::new(io::ErrorKind::NotFound, format!("object not found: {k}"))
            }
            StoreError::Io(e) => e,
        }
    }
}

/// Object metadata learned in one operation: length plus the coherence
/// fields the caching tier keys on. `version` is the object's monotonic
/// write generation (stamped in the local tier's CRC sidecar, carried over
/// HTTP via `x-getbatch-version`); `None` means the tier has no version for
/// the object (pre-versioning sidecar, version-less remote) and cached
/// reads degrade to unversioned (LRU-convergent) behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObjectStat {
    pub len: u64,
    pub version: Option<u64>,
    pub crc: Option<u32>,
}

/// What a tier must provide to serve a bucket (§2.2's store substrate,
/// generalized): streaming entry readers plus object CRUD. Every
/// implementation is positionable behind every other — the read-through
/// cache wraps a local or remote backend, the remote backend fronts
/// another node's whole stack over HTTP (across a health-tracked endpoint
/// set with transparent failover — callers see one logical backend
/// whether it is one disk or N interchangeable hosts).
pub trait Backend: Send + Sync {
    /// Open a whole object as a streaming [`EntryReader`].
    fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError>;
    /// Open a byte span of an object as a streaming [`EntryReader`] (shard
    /// member extraction). The span must lie inside the object.
    fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError>;
    fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError>;
    fn exists(&self, bucket: &str, obj: &str) -> bool;
    fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError>;
    fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError>;
    fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError>;
    /// The object's PUT-time CRC-32 sidecar, when one is stored — GFN
    /// splice recovery uses it to verify an already-emitted prefix without
    /// re-downloading it. `None` when absent or unsupported by the tier.
    fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32>;
    /// The object's monotonic write generation (see [`ObjectStat`]). Every
    /// PUT bumps it; the caching tier keys chunks by it so a stale version
    /// becomes unreachable the moment a newer one is observed. `None` when
    /// the tier has no version for the object.
    fn content_version(&self, _bucket: &str, _obj: &str) -> Option<u64> {
        None
    }
    /// Length + coherence metadata in one call. The default composes
    /// [`Backend::size`] / [`Backend::content_version`] /
    /// [`Backend::content_crc`]; tiers that can answer from a single round
    /// trip (the remote backend's 1-byte probe) override it.
    ///
    /// Ordering matters: the **version is read before the length**. Under
    /// a concurrent overwrite the skew then lands on (newer len, older
    /// version) — a read pinned on that stat fails the cache's fill-time
    /// version gate and retries at the new version. The reverse order
    /// could yield (older len, newer version), which *passes* the gate
    /// and would serve a silently truncated read as complete.
    fn stat(&self, bucket: &str, obj: &str) -> Result<ObjectStat, StoreError> {
        let version = self.content_version(bucket, obj);
        let len = self.size(bucket, obj)?;
        Ok(ObjectStat { len, version, crc: self.content_crc(bucket, obj) })
    }
    /// Warm the object's bytes ahead of a predicted read, returning how
    /// many cache chunks were newly admitted. Only the caching tier has
    /// anywhere to put warmth, so the default is a no-op — a prefetch
    /// against a local or remote tier costs nothing and fills nothing.
    /// Prefetched chunks reserve capacity against `cache_bytes` only,
    /// never against `dt_buffer_bytes` (the data-plane budget).
    fn prefetch(&self, _bucket: &str, _obj: &str) -> Result<u64, StoreError> {
        Ok(0)
    }
}

/// The byte source behind an [`EntryReader`]: positioned reads over one
/// entry's span. `pos` is entry-relative (0 = first byte of the entry);
/// implementations may optimize the sequential case (the file source keeps
/// the OS cursor, the remote source keeps a streaming HTTP body open) and
/// only pay for repositioning on an actual seek.
pub trait ChunkSource: Send {
    /// Read up to `buf.len()` bytes at entry-relative `pos`. Returns 0 only
    /// at (or past) the end of the source's bytes.
    fn read_at(&mut self, pos: u64, buf: &mut [u8]) -> io::Result<usize>;

    /// The write generation of the object these bytes came from, when the
    /// source learned one while opening (the remote source reads it off the
    /// response's `x-getbatch-version` header). Lets consumers — the cache
    /// fill gate, the HTTP object handler — reuse the version the read
    /// itself pinned instead of paying a separate metadata probe.
    fn observed_version(&self) -> Option<u64> {
        None
    }
}

/// A seekable, length-known streaming source over one entry's bytes — the
/// read-side seam of the streaming data path. Producers (senders, the HTTP
/// object handler, DT-local resolution) pull `chunk_bytes`-sized pieces
/// instead of materializing whole objects, so read-side residency is
/// O(chunk), not O(entry). The entry may be a whole object, a byte span
/// inside one (shard member extraction), a remote object pulled over HTTP
/// Range requests, or a cached-chunk view — the tier decides by handing the
/// reader its [`ChunkSource`].
pub struct EntryReader {
    src: Box<dyn ChunkSource>,
    /// Entry length in bytes.
    len: u64,
    /// Cursor relative to the entry start (bytes already consumed).
    pos: u64,
}

impl EntryReader {
    /// Reader over an arbitrary source with a known length.
    pub fn from_source(src: Box<dyn ChunkSource>, len: u64) -> EntryReader {
        EntryReader { src, len, pos: 0 }
    }

    /// Declared entry length (known up front — the TAR header and the
    /// FIRST chunk frame both need it before the payload streams).
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> u64 {
        self.len - self.pos
    }

    /// The object write generation the underlying source observed while
    /// opening, if any (see [`ChunkSource::observed_version`]).
    pub fn observed_version(&self) -> Option<u64> {
        self.src.observed_version()
    }

    /// Current cursor (bytes consumed so far).
    pub fn pos(&self) -> u64 {
        self.pos
    }

    /// Reposition the cursor (clamped to the entry length) — ranged reads
    /// and GFN splice resume use this. The source pays for the
    /// discontinuity lazily on the next read.
    pub fn seek_to(&mut self, pos: u64) -> Result<(), StoreError> {
        self.pos = pos.min(self.len);
        Ok(())
    }

    /// Read the next `min(max, remaining)` bytes. Returns an empty vec at
    /// the end of the entry; errors if the source ends before the declared
    /// length (concurrent truncation).
    pub fn read_chunk(&mut self, max: usize) -> Result<Vec<u8>, StoreError> {
        let mut buf = Vec::new();
        self.read_chunk_into(&mut buf, max)?;
        Ok(buf)
    }

    /// [`EntryReader::read_chunk`] into a caller-owned buffer: appends the
    /// next `min(max, remaining)` bytes to `buf`, returning the count
    /// (append — not replace — so a frame prefix already in the buffer is
    /// preserved; callers clear between frames). The sender hot loop reuses
    /// one buffer across every chunk frame of a burst instead of allocating
    /// a fresh `Vec` per chunk.
    pub fn read_chunk_into(&mut self, buf: &mut Vec<u8>, max: usize) -> Result<usize, StoreError> {
        let want = self.remaining().min(max.max(1) as u64) as usize;
        let start = buf.len();
        buf.resize(start + want, 0);
        Read::read_exact(self, &mut buf[start..])?;
        Ok(want)
    }

    /// Drain the rest of the entry into one buffer (tests and small-object
    /// conveniences; the streaming paths use `read_chunk`).
    pub fn read_all(mut self) -> Result<Vec<u8>, StoreError> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        Read::read_to_end(&mut self, &mut out)?;
        Ok(out)
    }
}

impl Read for EntryReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let want = self.remaining().min(buf.len() as u64) as usize;
        if want == 0 {
            return Ok(0);
        }
        let n = self.src.read_at(self.pos, &mut buf[..want])?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("entry truncated at {}/{}", self.pos, self.len),
            ));
        }
        self.pos += n as u64;
        Ok(n)
    }
}

/// One node's store: a thin router from bucket to backend stack. The
/// per-node [`LocalBackend`] serves every bucket that has no explicit
/// route; remote and cache-fronted stacks are installed per bucket (from
/// `GetBatchConfig` at boot, or at runtime once late-bound addresses are
/// known).
pub struct ObjectStore {
    local: Arc<LocalBackend>,
    routes: RwLock<HashMap<String, Arc<dyn Backend>>>,
}

impl ObjectStore {
    /// Open a store whose default (and initially only) tier is the local
    /// mountpath backend under `base`.
    pub fn open(base: &Path, mountpaths: usize) -> Result<ObjectStore, StoreError> {
        Ok(ObjectStore {
            local: Arc::new(LocalBackend::open(base, mountpaths)?),
            routes: RwLock::new(HashMap::new()),
        })
    }

    /// The node's local-disk tier (bulk staging, replica planting, fault
    /// injection — paths that must bypass bucket routing).
    pub fn local(&self) -> &Arc<LocalBackend> {
        &self.local
    }

    /// Install (or replace) the backend stack serving `bucket`.
    pub fn route_bucket(&self, bucket: &str, backend: Arc<dyn Backend>) {
        self.routes.write().unwrap().insert(bucket.to_string(), backend);
    }

    /// Remove a bucket's explicit route (falls back to the local tier).
    pub fn unroute_bucket(&self, bucket: &str) {
        self.routes.write().unwrap().remove(bucket);
    }

    /// The backend stack serving `bucket`.
    pub fn backend_for(&self, bucket: &str) -> Arc<dyn Backend> {
        if let Some(b) = self.routes.read().unwrap().get(bucket) {
            return Arc::clone(b);
        }
        Arc::clone(&self.local) as Arc<dyn Backend>
    }

    /// Injected read-fault rate on the local tier (failure testing).
    pub fn set_fault_rate(&self, rate: f64) {
        self.local.set_fault_rate(rate);
    }

    pub fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        self.backend_for(bucket).put(bucket, obj, data)
    }

    pub fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.backend_for(bucket).exists(bucket, obj)
    }

    pub fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        self.backend_for(bucket).size(bucket, obj)
    }

    /// Whole-object read (convenience over [`ObjectStore::open_entry`] —
    /// the streaming paths use the reader directly).
    pub fn get(&self, bucket: &str, obj: &str) -> Result<Vec<u8>, StoreError> {
        self.open_entry(bucket, obj)?.read_all()
    }

    /// Range read — convenience over [`ObjectStore::open_entry_range`].
    pub fn get_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<Vec<u8>, StoreError> {
        self.open_entry_range(bucket, obj, offset, len)?.read_all()
    }

    /// Open a whole object as a streaming [`EntryReader`].
    pub fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        self.backend_for(bucket).open_entry(bucket, obj)
    }

    /// Open a byte span of an object as a streaming [`EntryReader`] — shard
    /// member extraction reads exactly the member's payload without
    /// touching the rest of the archive.
    pub fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        self.backend_for(bucket).open_entry_range(bucket, obj, offset, len)
    }

    /// Open for sequential streaming (shard index scans) — the whole
    /// object as a reader, whatever tier serves the bucket.
    pub fn open_read(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        self.open_entry(bucket, obj)
    }

    pub fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        self.backend_for(bucket).delete(bucket, obj)
    }

    /// List objects of a bucket (admin/debug).
    pub fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        self.backend_for(bucket).list(bucket)
    }

    /// The object's PUT-time CRC-32 sidecar, if stored.
    pub fn content_crc(&self, bucket: &str, obj: &str) -> Option<u32> {
        self.backend_for(bucket).content_crc(bucket, obj)
    }

    /// The object's monotonic write generation, if the serving tier has one.
    pub fn content_version(&self, bucket: &str, obj: &str) -> Option<u64> {
        self.backend_for(bucket).content_version(bucket, obj)
    }

    /// Length + coherence metadata in one call (see [`Backend::stat`]).
    pub fn stat(&self, bucket: &str, obj: &str) -> Result<ObjectStat, StoreError> {
        self.backend_for(bucket).stat(bucket, obj)
    }

    /// Warm an object into the bucket's caching tier ahead of a predicted
    /// read (see [`Backend::prefetch`]); a no-op for uncached buckets.
    pub fn prefetch(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        self.backend_for(bucket).prefetch(bucket, obj)
    }

    pub fn mountpath_count(&self) -> usize {
        self.local.mountpath_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    fn store(name: &str) -> (ObjectStore, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbstore-{}-{}", std::process::id(), name));
        let _ = fs::remove_dir_all(&base);
        fs::create_dir_all(&base).unwrap();
        (ObjectStore::open(&base, 3).unwrap(), base)
    }

    #[test]
    fn put_get_roundtrip() {
        let (s, base) = store("rt");
        s.put("b", "o1", b"hello").unwrap();
        assert_eq!(s.get("b", "o1").unwrap(), b"hello");
        assert!(s.exists("b", "o1"));
        assert_eq!(s.size("b", "o1").unwrap(), 5);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn nested_object_names() {
        let (s, base) = store("nested");
        s.put("b", "shards/train/s-0001.tar", b"x").unwrap();
        assert_eq!(s.get("b", "shards/train/s-0001.tar").unwrap(), b"x");
        assert_eq!(s.list("b").unwrap(), vec!["shards/train/s-0001.tar"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn missing_is_not_found() {
        let (s, base) = store("missing");
        assert!(matches!(s.get("b", "nope"), Err(StoreError::NotFound(_))));
        assert!(matches!(s.size("b", "nope"), Err(StoreError::NotFound(_))));
        assert!(matches!(s.delete("b", "nope"), Err(StoreError::NotFound(_))));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn overwrite_is_atomic_replace() {
        let (s, base) = store("ow");
        s.put("b", "o", b"v1").unwrap();
        s.put("b", "o", b"v2-longer").unwrap();
        assert_eq!(s.get("b", "o").unwrap(), b"v2-longer");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn range_reads() {
        let (s, base) = store("range");
        s.put("b", "o", b"0123456789").unwrap();
        assert_eq!(s.get_range("b", "o", 3, 4).unwrap(), b"3456");
        assert_eq!(s.get_range("b", "o", 0, 0).unwrap(), b"");
        assert!(s.get_range("b", "o", 8, 5).is_err()); // past EOF
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn delete_removes() {
        let (s, base) = store("del");
        s.put("b", "o", b"x").unwrap();
        s.delete("b", "o").unwrap();
        assert!(!s.exists("b", "o"));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn list_multiple_buckets_disjoint() {
        let (s, base) = store("buckets");
        for i in 0..20 {
            s.put("b1", &format!("o{i}"), b"x").unwrap();
        }
        s.put("b2", "only", b"y").unwrap();
        assert_eq!(s.list("b1").unwrap().len(), 20);
        assert_eq!(s.list("b2").unwrap(), vec!["only"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn entry_reader_streams_in_chunks() {
        let (s, base) = store("rdr");
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        s.put("b", "o", &data).unwrap();
        let mut r = s.open_entry("b", "o").unwrap();
        assert_eq!(r.len(), data.len() as u64);
        assert!(!r.is_empty());
        let mut rebuilt = Vec::new();
        loop {
            let c = r.read_chunk(1024).unwrap();
            if c.is_empty() {
                break;
            }
            assert!(c.len() <= 1024);
            rebuilt.extend_from_slice(&c);
        }
        assert_eq!(rebuilt, data);
        assert_eq!(r.remaining(), 0);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn entry_reader_seek_and_range() {
        let (s, base) = store("seek");
        s.put("b", "o", b"0123456789").unwrap();
        // whole-object reader repositioned mid-entry
        let mut r = s.open_entry("b", "o").unwrap();
        r.seek_to(6).unwrap();
        assert_eq!(r.remaining(), 4);
        assert_eq!(r.read_chunk(64).unwrap(), b"6789");
        // range-bounded reader sees only its span
        let mut r = s.open_entry_range("b", "o", 3, 4).unwrap();
        assert_eq!(r.len(), 4);
        assert_eq!(r.read_chunk(2).unwrap(), b"34");
        assert_eq!(r.read_chunk(64).unwrap(), b"56");
        assert_eq!(r.read_chunk(64).unwrap(), b"");
        // span past EOF rejected at open
        assert!(s.open_entry_range("b", "o", 8, 5).is_err());
        // zero-length entries stream cleanly
        s.put("b", "empty", b"").unwrap();
        let r = s.open_entry("b", "empty").unwrap();
        assert!(r.is_empty());
        assert_eq!(r.read_all().unwrap(), b"");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn read_chunk_into_reuses_one_buffer() {
        let (s, base) = store("into");
        let data: Vec<u8> = (0..5000u32).map(|i| (i % 241) as u8).collect();
        s.put("b", "o", &data).unwrap();
        let mut r = s.open_entry("b", "o").unwrap();
        let mut buf = Vec::new();
        let mut rebuilt = Vec::new();
        loop {
            buf.clear();
            let n = r.read_chunk_into(&mut buf, 512).unwrap();
            assert_eq!(n, buf.len());
            if n == 0 {
                break;
            }
            rebuilt.extend_from_slice(&buf);
        }
        assert_eq!(rebuilt, data);
        // append semantics: a non-empty buffer keeps its prefix
        let mut r = s.open_entry("b", "o").unwrap();
        buf.clear();
        buf.extend_from_slice(b"PFX");
        let n = r.read_chunk_into(&mut buf, 4).unwrap();
        assert_eq!(n, 4);
        assert_eq!(&buf[..3], b"PFX");
        assert_eq!(&buf[3..], &data[..4]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn fault_injection_fails_reads() {
        let (s, base) = store("fault");
        s.put("b", "o", b"x").unwrap();
        s.set_fault_rate(1.0);
        assert!(s.get("b", "o").is_err());
        s.set_fault_rate(0.0);
        assert!(s.get("b", "o").is_ok());
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn router_dispatches_per_bucket() {
        // A second LocalBackend standing in for a "remote" tier: routed
        // buckets hit it, unrouted buckets keep hitting the default tier.
        let (s, base) = store("router");
        let other_base = base.join("other-tier");
        fs::create_dir_all(&other_base).unwrap();
        let other = Arc::new(LocalBackend::open(&other_base, 1).unwrap());
        other.put("routed", "o", b"from-other-tier").unwrap();
        s.put("plain", "o", b"from-default").unwrap();

        s.route_bucket("routed", Arc::clone(&other) as Arc<dyn Backend>);
        assert_eq!(s.get("routed", "o").unwrap(), b"from-other-tier");
        assert_eq!(s.get("plain", "o").unwrap(), b"from-default");
        // writes route too
        s.put("routed", "w", b"write-through").unwrap();
        assert_eq!(other.get("routed", "w").unwrap(), b"write-through");
        assert!(!s.local().exists("routed", "w"), "default tier untouched");
        // dropping the route falls back to the local tier
        s.unroute_bucket("routed");
        assert!(matches!(s.get("routed", "w"), Err(StoreError::NotFound(_))));
        fs::remove_dir_all(base).unwrap();
    }
}
