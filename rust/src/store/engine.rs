//! The per-target object store: bucket/object CRUD on local mountpaths.
//! PUTs are atomic (temp file + rename); GETs support whole-object reads,
//! range reads (shard member pread), and streaming. This is the substrate
//! the paper assumes from AIStore — enough of it, faithfully shaped.

use std::fs::{self, File};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::mountpath::Mountpaths;

#[derive(Debug)]
pub enum StoreError {
    NotFound(String),
    Io(io::Error),
}

crate::impl_error! {
    StoreError {
        display {
            StoreError::NotFound(k) => "object not found: {k}",
            StoreError::Io(e) => "io: {e}",
        }
        source {
            StoreError::Io(e) => e,
        }
        from {
            io::Error => Io,
        }
    }
}

/// A seekable, length-known streaming source over one entry's bytes — the
/// read-side seam of the streaming data path. Producers (senders, the HTTP
/// object handler, DT-local resolution) pull `chunk_bytes`-sized pieces
/// instead of materializing whole objects, so read-side residency is
/// O(chunk), not O(entry). The entry may be a whole object
/// ([`ObjectStore::open_entry`]) or a byte span inside one (shard member
/// extraction via [`ObjectStore::open_entry_range`]); a future remote
/// backend plugs in at exactly this seam.
pub struct EntryReader {
    file: File,
    /// Absolute file offset where the entry begins.
    base: u64,
    /// Entry length in bytes.
    len: u64,
    /// Cursor relative to `base` (bytes already consumed).
    pos: u64,
}

impl EntryReader {
    fn new(mut file: File, base: u64, len: u64) -> Result<EntryReader, StoreError> {
        if base > 0 {
            file.seek(SeekFrom::Start(base))?;
        }
        Ok(EntryReader { file, base, len, pos: 0 })
    }

    /// Declared entry length (known up front — the TAR header and the
    /// FIRST chunk frame both need it before the payload streams).
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> u64 {
        self.len - self.pos
    }

    /// Current cursor (bytes consumed so far).
    pub fn pos(&self) -> u64 {
        self.pos
    }

    /// Reposition the cursor (clamped to the entry length) — ranged reads
    /// and GFN splice resume use this.
    pub fn seek_to(&mut self, pos: u64) -> Result<(), StoreError> {
        let pos = pos.min(self.len);
        self.file.seek(SeekFrom::Start(self.base + pos))?;
        self.pos = pos;
        Ok(())
    }

    /// Read the next `min(max, remaining)` bytes. Returns an empty vec at
    /// the end of the entry; errors if the file ends before the declared
    /// length (concurrent truncation).
    pub fn read_chunk(&mut self, max: usize) -> Result<Vec<u8>, StoreError> {
        let want = self.remaining().min(max.max(1) as u64) as usize;
        let mut buf = vec![0u8; want];
        Read::read_exact(self, &mut buf)?;
        Ok(buf)
    }

    /// Drain the rest of the entry into one buffer (tests and small-object
    /// conveniences; the streaming paths use `read_chunk`).
    pub fn read_all(mut self) -> Result<Vec<u8>, StoreError> {
        let mut out = Vec::with_capacity(self.remaining() as usize);
        Read::read_to_end(&mut self, &mut out)?;
        Ok(out)
    }
}

impl Read for EntryReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let want = self.remaining().min(buf.len() as u64) as usize;
        if want == 0 {
            return Ok(0);
        }
        let n = self.file.read(&mut buf[..want])?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("entry truncated at {}/{}", self.pos, self.len),
            ));
        }
        self.pos += n as u64;
        Ok(n)
    }
}

/// One node's store.
pub struct ObjectStore {
    mounts: Mountpaths,
    tmp_seq: AtomicU64,
    tmp_dir: PathBuf,
    /// Injected read fault rate (failure testing); 0.0 in production.
    pub fault_rate: std::sync::Mutex<f64>,
    fault_rng: std::sync::Mutex<crate::util::rng::Rng>,
}

impl ObjectStore {
    pub fn open(base: &Path, mountpaths: usize) -> Result<ObjectStore, StoreError> {
        let mounts = Mountpaths::create(base, mountpaths)?;
        let tmp_dir = base.join(".tmp");
        fs::create_dir_all(&tmp_dir)?;
        Ok(ObjectStore {
            mounts,
            tmp_seq: AtomicU64::new(0),
            tmp_dir,
            fault_rate: std::sync::Mutex::new(0.0),
            fault_rng: std::sync::Mutex::new(crate::util::rng::Rng::new(0xFA01)),
        })
    }

    fn maybe_fault(&self) -> Result<(), StoreError> {
        let rate = *self.fault_rate.lock().unwrap();
        if rate > 0.0 && self.fault_rng.lock().unwrap().bool(rate) {
            return Err(StoreError::Io(io::Error::new(io::ErrorKind::Other, "injected EIO")));
        }
        Ok(())
    }

    fn path(&self, bucket: &str, obj: &str) -> PathBuf {
        self.mounts.object_path(bucket, obj)
    }

    /// Atomic PUT: write to a temp file on the same mountpath, then rename.
    pub fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        let dst = self.path(bucket, obj);
        if let Some(parent) = dst.parent() {
            fs::create_dir_all(parent)?;
        }
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self.tmp_dir.join(format!("put-{seq}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(data)?;
            f.sync_data().ok(); // best-effort durability; tmpfs in CI
        }
        fs::rename(&tmp, &dst)?;
        Ok(())
    }

    pub fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.path(bucket, obj).is_file()
    }

    pub fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        let p = self.path(bucket, obj);
        let md = fs::metadata(&p)
            .map_err(|_| StoreError::NotFound(format!("{bucket}/{obj}")))?;
        Ok(md.len())
    }

    /// Whole-object read (convenience over [`ObjectStore::open_entry`] —
    /// the streaming paths use the reader directly).
    pub fn get(&self, bucket: &str, obj: &str) -> Result<Vec<u8>, StoreError> {
        self.open_entry(bucket, obj)?.read_all()
    }

    /// Range read (pread) — convenience over
    /// [`ObjectStore::open_entry_range`].
    pub fn get_range(&self, bucket: &str, obj: &str, offset: u64, len: u64) -> Result<Vec<u8>, StoreError> {
        self.open_entry_range(bucket, obj, offset, len)?.read_all()
    }

    /// Open a whole object as a streaming [`EntryReader`].
    pub fn open_entry(&self, bucket: &str, obj: &str) -> Result<EntryReader, StoreError> {
        let (file, size) = self.open_with_size(bucket, obj)?;
        EntryReader::new(file, 0, size)
    }

    /// Open a byte span of an object as a streaming [`EntryReader`] — shard
    /// member extraction reads exactly the member's payload without touching
    /// the rest of the archive. The span must lie inside the object.
    pub fn open_entry_range(
        &self,
        bucket: &str,
        obj: &str,
        offset: u64,
        len: u64,
    ) -> Result<EntryReader, StoreError> {
        let (file, size) = self.open_with_size(bucket, obj)?;
        if offset.saturating_add(len) > size {
            return Err(StoreError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("range {offset}+{len} past EOF ({size}) in {bucket}/{obj}"),
            )));
        }
        EntryReader::new(file, offset, len)
    }

    fn open_with_size(&self, bucket: &str, obj: &str) -> Result<(File, u64), StoreError> {
        self.maybe_fault()?;
        let p = self.path(bucket, obj);
        let f = File::open(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        let size = f.metadata()?.len();
        Ok((f, size))
    }

    /// Open for streaming (sequential shard loads).
    pub fn open_read(&self, bucket: &str, obj: &str) -> Result<File, StoreError> {
        self.maybe_fault()?;
        File::open(self.path(bucket, obj)).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })
    }

    pub fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        let p = self.path(bucket, obj);
        fs::remove_file(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })
    }

    /// List objects of a bucket (admin/debug; walks all mountpaths).
    pub fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        let mut out = Vec::new();
        for root in self.mounts.all_roots() {
            let bdir = root.join(bucket);
            if bdir.is_dir() {
                walk(&bdir, &bdir, &mut out)?;
            }
        }
        out.sort();
        Ok(out)
    }

    pub fn mountpath_count(&self) -> usize {
        self.mounts.len()
    }
}

fn walk(base: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk(base, &p, out)?;
        } else {
            out.push(p.strip_prefix(base).unwrap().to_string_lossy().into_owned());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(name: &str) -> (ObjectStore, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbstore-{}-{}", std::process::id(), name));
        let _ = fs::remove_dir_all(&base);
        fs::create_dir_all(&base).unwrap();
        (ObjectStore::open(&base, 3).unwrap(), base)
    }

    #[test]
    fn put_get_roundtrip() {
        let (s, base) = store("rt");
        s.put("b", "o1", b"hello").unwrap();
        assert_eq!(s.get("b", "o1").unwrap(), b"hello");
        assert!(s.exists("b", "o1"));
        assert_eq!(s.size("b", "o1").unwrap(), 5);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn nested_object_names() {
        let (s, base) = store("nested");
        s.put("b", "shards/train/s-0001.tar", b"x").unwrap();
        assert_eq!(s.get("b", "shards/train/s-0001.tar").unwrap(), b"x");
        assert_eq!(s.list("b").unwrap(), vec!["shards/train/s-0001.tar"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn missing_is_not_found() {
        let (s, base) = store("missing");
        assert!(matches!(s.get("b", "nope"), Err(StoreError::NotFound(_))));
        assert!(matches!(s.size("b", "nope"), Err(StoreError::NotFound(_))));
        assert!(matches!(s.delete("b", "nope"), Err(StoreError::NotFound(_))));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn overwrite_is_atomic_replace() {
        let (s, base) = store("ow");
        s.put("b", "o", b"v1").unwrap();
        s.put("b", "o", b"v2-longer").unwrap();
        assert_eq!(s.get("b", "o").unwrap(), b"v2-longer");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn range_reads() {
        let (s, base) = store("range");
        s.put("b", "o", b"0123456789").unwrap();
        assert_eq!(s.get_range("b", "o", 3, 4).unwrap(), b"3456");
        assert_eq!(s.get_range("b", "o", 0, 0).unwrap(), b"");
        assert!(s.get_range("b", "o", 8, 5).is_err()); // past EOF
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn delete_removes() {
        let (s, base) = store("del");
        s.put("b", "o", b"x").unwrap();
        s.delete("b", "o").unwrap();
        assert!(!s.exists("b", "o"));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn list_multiple_buckets_disjoint() {
        let (s, base) = store("buckets");
        for i in 0..20 {
            s.put("b1", &format!("o{i}"), b"x").unwrap();
        }
        s.put("b2", "only", b"y").unwrap();
        assert_eq!(s.list("b1").unwrap().len(), 20);
        assert_eq!(s.list("b2").unwrap(), vec!["only"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn entry_reader_streams_in_chunks() {
        let (s, base) = store("rdr");
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        s.put("b", "o", &data).unwrap();
        let mut r = s.open_entry("b", "o").unwrap();
        assert_eq!(r.len(), data.len() as u64);
        assert!(!r.is_empty());
        let mut rebuilt = Vec::new();
        loop {
            let c = r.read_chunk(1024).unwrap();
            if c.is_empty() {
                break;
            }
            assert!(c.len() <= 1024);
            rebuilt.extend_from_slice(&c);
        }
        assert_eq!(rebuilt, data);
        assert_eq!(r.remaining(), 0);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn entry_reader_seek_and_range() {
        let (s, base) = store("seek");
        s.put("b", "o", b"0123456789").unwrap();
        // whole-object reader repositioned mid-entry
        let mut r = s.open_entry("b", "o").unwrap();
        r.seek_to(6).unwrap();
        assert_eq!(r.remaining(), 4);
        assert_eq!(r.read_chunk(64).unwrap(), b"6789");
        // range-bounded reader sees only its span
        let mut r = s.open_entry_range("b", "o", 3, 4).unwrap();
        assert_eq!(r.len(), 4);
        assert_eq!(r.read_chunk(2).unwrap(), b"34");
        assert_eq!(r.read_chunk(64).unwrap(), b"56");
        assert_eq!(r.read_chunk(64).unwrap(), b"");
        // span past EOF rejected at open
        assert!(s.open_entry_range("b", "o", 8, 5).is_err());
        // zero-length entries stream cleanly
        s.put("b", "empty", b"").unwrap();
        let r = s.open_entry("b", "empty").unwrap();
        assert!(r.is_empty());
        assert_eq!(r.read_all().unwrap(), b"");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn fault_injection_fails_reads() {
        let (s, base) = store("fault");
        s.put("b", "o", b"x").unwrap();
        *s.fault_rate.lock().unwrap() = 1.0;
        assert!(s.get("b", "o").is_err());
        *s.fault_rate.lock().unwrap() = 0.0;
        assert!(s.get("b", "o").is_ok());
        fs::remove_dir_all(base).unwrap();
    }
}
