//! The per-target object store: bucket/object CRUD on local mountpaths.
//! PUTs are atomic (temp file + rename); GETs support whole-object reads,
//! range reads (shard member pread), and streaming. This is the substrate
//! the paper assumes from AIStore — enough of it, faithfully shaped.

use std::fs::{self, File};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::mountpath::Mountpaths;

#[derive(Debug)]
pub enum StoreError {
    NotFound(String),
    Io(io::Error),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::NotFound(k) => write!(f, "object not found: {k}"),
            StoreError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for StoreError {
    fn from(e: io::Error) -> StoreError {
        StoreError::Io(e)
    }
}

/// One node's store.
pub struct ObjectStore {
    mounts: Mountpaths,
    tmp_seq: AtomicU64,
    tmp_dir: PathBuf,
    /// Injected read fault rate (failure testing); 0.0 in production.
    pub fault_rate: std::sync::Mutex<f64>,
    fault_rng: std::sync::Mutex<crate::util::rng::Rng>,
}

impl ObjectStore {
    pub fn open(base: &Path, mountpaths: usize) -> Result<ObjectStore, StoreError> {
        let mounts = Mountpaths::create(base, mountpaths)?;
        let tmp_dir = base.join(".tmp");
        fs::create_dir_all(&tmp_dir)?;
        Ok(ObjectStore {
            mounts,
            tmp_seq: AtomicU64::new(0),
            tmp_dir,
            fault_rate: std::sync::Mutex::new(0.0),
            fault_rng: std::sync::Mutex::new(crate::util::rng::Rng::new(0xFA01)),
        })
    }

    fn maybe_fault(&self) -> Result<(), StoreError> {
        let rate = *self.fault_rate.lock().unwrap();
        if rate > 0.0 && self.fault_rng.lock().unwrap().bool(rate) {
            return Err(StoreError::Io(io::Error::new(io::ErrorKind::Other, "injected EIO")));
        }
        Ok(())
    }

    fn path(&self, bucket: &str, obj: &str) -> PathBuf {
        self.mounts.object_path(bucket, obj)
    }

    /// Atomic PUT: write to a temp file on the same mountpath, then rename.
    pub fn put(&self, bucket: &str, obj: &str, data: &[u8]) -> Result<(), StoreError> {
        let dst = self.path(bucket, obj);
        if let Some(parent) = dst.parent() {
            fs::create_dir_all(parent)?;
        }
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self.tmp_dir.join(format!("put-{seq}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(data)?;
            f.sync_data().ok(); // best-effort durability; tmpfs in CI
        }
        fs::rename(&tmp, &dst)?;
        Ok(())
    }

    pub fn exists(&self, bucket: &str, obj: &str) -> bool {
        self.path(bucket, obj).is_file()
    }

    pub fn size(&self, bucket: &str, obj: &str) -> Result<u64, StoreError> {
        let p = self.path(bucket, obj);
        let md = fs::metadata(&p)
            .map_err(|_| StoreError::NotFound(format!("{bucket}/{obj}")))?;
        Ok(md.len())
    }

    /// Whole-object read.
    pub fn get(&self, bucket: &str, obj: &str) -> Result<Vec<u8>, StoreError> {
        self.maybe_fault()?;
        let p = self.path(bucket, obj);
        fs::read(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })
    }

    /// Range read (pread) — shard member extraction reads exactly the member
    /// payload without touching the rest of the archive.
    pub fn get_range(&self, bucket: &str, obj: &str, offset: u64, len: u64) -> Result<Vec<u8>, StoreError> {
        self.maybe_fault()?;
        let p = self.path(bucket, obj);
        let mut f = File::open(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })?;
        f.seek(SeekFrom::Start(offset))?;
        let mut buf = vec![0u8; len as usize];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    /// Open for streaming (sequential shard loads).
    pub fn open_read(&self, bucket: &str, obj: &str) -> Result<File, StoreError> {
        self.maybe_fault()?;
        File::open(self.path(bucket, obj)).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })
    }

    pub fn delete(&self, bucket: &str, obj: &str) -> Result<(), StoreError> {
        let p = self.path(bucket, obj);
        fs::remove_file(&p).map_err(|e| {
            if e.kind() == io::ErrorKind::NotFound {
                StoreError::NotFound(format!("{bucket}/{obj}"))
            } else {
                StoreError::Io(e)
            }
        })
    }

    /// List objects of a bucket (admin/debug; walks all mountpaths).
    pub fn list(&self, bucket: &str) -> Result<Vec<String>, StoreError> {
        let mut out = Vec::new();
        for root in self.mounts.all_roots() {
            let bdir = root.join(bucket);
            if bdir.is_dir() {
                walk(&bdir, &bdir, &mut out)?;
            }
        }
        out.sort();
        Ok(out)
    }

    pub fn mountpath_count(&self) -> usize {
        self.mounts.len()
    }
}

fn walk(base: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk(base, &p, out)?;
        } else {
            out.push(p.strip_prefix(base).unwrap().to_string_lossy().into_owned());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(name: &str) -> (ObjectStore, PathBuf) {
        let base = std::env::temp_dir().join(format!("gbstore-{}-{}", std::process::id(), name));
        let _ = fs::remove_dir_all(&base);
        fs::create_dir_all(&base).unwrap();
        (ObjectStore::open(&base, 3).unwrap(), base)
    }

    #[test]
    fn put_get_roundtrip() {
        let (s, base) = store("rt");
        s.put("b", "o1", b"hello").unwrap();
        assert_eq!(s.get("b", "o1").unwrap(), b"hello");
        assert!(s.exists("b", "o1"));
        assert_eq!(s.size("b", "o1").unwrap(), 5);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn nested_object_names() {
        let (s, base) = store("nested");
        s.put("b", "shards/train/s-0001.tar", b"x").unwrap();
        assert_eq!(s.get("b", "shards/train/s-0001.tar").unwrap(), b"x");
        assert_eq!(s.list("b").unwrap(), vec!["shards/train/s-0001.tar"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn missing_is_not_found() {
        let (s, base) = store("missing");
        assert!(matches!(s.get("b", "nope"), Err(StoreError::NotFound(_))));
        assert!(matches!(s.size("b", "nope"), Err(StoreError::NotFound(_))));
        assert!(matches!(s.delete("b", "nope"), Err(StoreError::NotFound(_))));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn overwrite_is_atomic_replace() {
        let (s, base) = store("ow");
        s.put("b", "o", b"v1").unwrap();
        s.put("b", "o", b"v2-longer").unwrap();
        assert_eq!(s.get("b", "o").unwrap(), b"v2-longer");
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn range_reads() {
        let (s, base) = store("range");
        s.put("b", "o", b"0123456789").unwrap();
        assert_eq!(s.get_range("b", "o", 3, 4).unwrap(), b"3456");
        assert_eq!(s.get_range("b", "o", 0, 0).unwrap(), b"");
        assert!(s.get_range("b", "o", 8, 5).is_err()); // past EOF
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn delete_removes() {
        let (s, base) = store("del");
        s.put("b", "o", b"x").unwrap();
        s.delete("b", "o").unwrap();
        assert!(!s.exists("b", "o"));
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn list_multiple_buckets_disjoint() {
        let (s, base) = store("buckets");
        for i in 0..20 {
            s.put("b1", &format!("o{i}"), b"x").unwrap();
        }
        s.put("b2", "only", b"y").unwrap();
        assert_eq!(s.list("b1").unwrap().len(), 20);
        assert_eq!(s.list("b2").unwrap(), vec!["only"]);
        fs::remove_dir_all(base).unwrap();
    }

    #[test]
    fn fault_injection_fails_reads() {
        let (s, base) = store("fault");
        s.put("b", "o", b"x").unwrap();
        *s.fault_rate.lock().unwrap() = 1.0;
        assert!(s.get("b", "o").is_err());
        *s.fault_rate.lock().unwrap() = 0.0;
        assert!(s.get("b", "o").is_ok());
        fs::remove_dir_all(base).unwrap();
    }
}
